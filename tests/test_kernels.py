"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Hypothesis property sweeps live in `test_kernels_properties.py` (skipped
cleanly when hypothesis is not installed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pareto
from repro.core.acim_spec import MacroSpec
from repro.kernels.acim_matmul import (acim_matmul, acim_matmul_ref,
                                       acim_matmul_ste, mismatch_weights)
from repro.kernels.maze_route import (INF, wavefront_distance,
                                      wavefront_distance_ref)
from repro.kernels.maze_route.ref import relax_once
from repro.kernels.pareto_dom import (dominance_matrix, dominance_matrix_ref,
                                      non_dominated_rank, rank_and_crowd)


def _pm1(key, shape):
    return jnp.where(jax.random.bernoulli(jax.random.key(key), 0.5, shape),
                     1.0, -1.0)


SHAPES = [(16, 64, 16, 64, 3), (7, 100, 33, 64, 3), (128, 512, 64, 128, 5),
          (1, 64, 1, 64, 1), (4, 1000, 20, 256, 6), (5, 64, 130, 32, 4),
          (2, 3, 2, 64, 2)]


class TestAcimMatmul:
    @pytest.mark.parametrize("m,k,c,n,b", SHAPES)
    def test_kernel_matches_ref(self, m, k, c, n, b):
        x = _pm1(m * 7 + k, (m, k))
        w = _pm1(k * 5 + c, (k, c))
        spec = MacroSpec(h=2 * n, w=max(c, 1), l=2, b_adc=b)
        y_k = acim_matmul(x, w, spec)
        y_r = acim_matmul_ref(x, w, n=n, b_adc=b)
        np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))

    def test_batched_leading_dims(self):
        x = _pm1(1, (2, 3, 64))
        w = _pm1(2, (64, 8))
        spec = MacroSpec(128, 8, 2, 3)
        y = acim_matmul(x, w, spec)
        assert y.shape == (2, 3, 8)
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(acim_matmul_ref(x, w, n=64, b_adc=3)))

    def test_exact_at_high_precision(self):
        # N=128, B=7 -> delta=2: even +-1 sums are exact (no clip at |s|<128)
        x = _pm1(3, (8, 256))
        w = _pm1(4, (256, 16))
        spec = MacroSpec(256, 16, 2, 7)
        y = acim_matmul(x, w, spec)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x @ w))

    def test_ste_gradients(self):
        spec = MacroSpec(128, 16, 2, 4)
        x = _pm1(5, (4, 64))
        w = _pm1(6, (64, 16))
        gx, gw = jax.grad(
            lambda x, w: jnp.sum(acim_matmul_ste(x, w, spec)), argnums=(0, 1)
        )(x, w)
        # STE: gradient of the ideal matmul
        np.testing.assert_allclose(np.asarray(gw),
                                   np.asarray(x.T @ jnp.ones((4, 16))), rtol=1e-6)
        assert bool(jnp.all(jnp.isfinite(gx)))

    def test_mismatch_fold_changes_results_slightly(self):
        from repro.core.acim_numerics import NoiseParams

        spec = MacroSpec(128, 16, 2, 6)
        x = _pm1(7, (16, 64))
        w = _pm1(8, (64, 16))
        w2 = mismatch_weights(w, spec, jax.random.key(0), NoiseParams.from_cal())
        y1 = acim_matmul(x, w, spec)
        y2 = acim_matmul(x, w2, spec)
        rel = float(jnp.mean(jnp.abs(y2 - y1))) / float(jnp.mean(jnp.abs(y1)) + 1e-9)
        assert rel < 0.2   # small static perturbation, not catastrophic


class TestParetoDom:
    @pytest.mark.parametrize("p", [3, 8, 100, 256, 513])
    def test_matches_ref(self, p):
        f = jax.random.normal(jax.random.key(p), (p, 4))
        np.testing.assert_array_equal(np.asarray(dominance_matrix(f)),
                                      np.asarray(dominance_matrix_ref(f)))

    def test_duplicate_rows_dont_dominate(self):
        f = jnp.asarray(np.array([[1., 2.], [1., 2.]], np.float32))
        d = np.asarray(dominance_matrix(f))
        assert not d.any()


class TestFusedRank:
    """Fused dominance + bit-pack + peel kernel vs the jnp oracles."""

    @pytest.mark.parametrize("p,m", [(3, 2), (17, 4), (100, 4), (256, 4),
                                     (300, 3), (512, 4)])
    def test_rank_matches_oracle(self, p, m):
        f = jax.random.normal(jax.random.key(p * 31 + m), (p, m))
        np.testing.assert_array_equal(
            np.asarray(non_dominated_rank(f)),
            np.asarray(pareto.non_dominated_rank(f)))

    def test_rank_with_duplicates_and_chain(self):
        # a strict chain: rank == index; plus duplicated rows sharing a rank
        base = np.arange(6, dtype=np.float32)[:, None] * np.ones((1, 3), np.float32)
        f = jnp.asarray(np.concatenate([base, base[2:3]], 0))
        ranks = np.asarray(non_dominated_rank(f))
        assert (ranks[:6] == np.arange(6)).all()
        assert ranks[6] == ranks[2]

    def test_rank_and_crowd_matches_oracles(self):
        f = jax.random.normal(jax.random.key(9), (130, 4))
        ranks, crowd = rank_and_crowd(f)
        ranks_ref = pareto.non_dominated_rank(f)
        crowd_ref = pareto.crowding_distance(f, ranks_ref)
        np.testing.assert_array_equal(np.asarray(ranks), np.asarray(ranks_ref))
        np.testing.assert_allclose(np.asarray(crowd), np.asarray(crowd_ref))


class TestMazeRoute:
    """Wavefront (parallel BFS) kernel vs the sweeping jnp oracle."""

    def _random_case(self, key, h, w, p_occ=0.3, n_seeds=1):
        ko, ks = jax.random.split(jax.random.key(key))
        occ = jax.random.uniform(ko, (h, w)) < p_occ
        flat = jax.random.choice(ks, h * w, (n_seeds,), replace=False)
        seed = jnp.zeros((h, w), bool).at[flat // w, flat % w].set(True)
        return occ, seed

    @pytest.mark.parametrize("h,w", [(2, 2), (5, 9), (16, 128), (23, 40),
                                     (8, 200)])
    def test_kernel_matches_ref(self, h, w):
        occ, seed = self._random_case(h * 131 + w, h, w)
        np.testing.assert_array_equal(
            np.asarray(wavefront_distance(occ, seed, use_kernel=True)),
            np.asarray(wavefront_distance_ref(occ, seed)))

    def test_batched_grids(self):
        occ = jax.random.uniform(jax.random.key(0), (4, 11, 19)) < 0.25
        seed = jnp.zeros((4, 11, 19), bool).at[:, 0, 0].set(True)
        np.testing.assert_array_equal(
            np.asarray(wavefront_distance(occ, seed, use_kernel=True)),
            np.asarray(wavefront_distance_ref(occ, seed)))

    def test_sweeping_fixed_point_is_relaxation_fixed_point(self):
        # BFS distances are the unique fixed point of the Jacobi step the
        # Pallas kernel iterates; the sweeping oracle must land on it.
        occ, seed = self._random_case(7, 13, 17, p_occ=0.4)
        dist = wavefront_distance_ref(occ, seed)
        free = ~occ & ~seed
        np.testing.assert_array_equal(np.asarray(relax_once(dist, free)),
                                      np.asarray(dist))

    def test_walled_off_region_unreachable(self):
        occ = jnp.zeros((7, 7), bool).at[:, 3].set(True)
        seed = jnp.zeros((7, 7), bool).at[3, 0].set(True)
        d = np.asarray(wavefront_distance(occ, seed, use_kernel=True))
        assert (d[:, 4:] == INF).all()          # right of the wall
        assert (d[:, :3] < INF).all()           # left side fully reached
        assert d[3, 0] == 0

    def test_occupied_seed_still_expands(self):
        # a router hub on a full track is enterable (distance 0) and the
        # wavefront still leaves it — matching the old host BFS
        occ = jnp.zeros((4, 6), bool).at[1, 1].set(True)
        seed = jnp.zeros((4, 6), bool).at[1, 1].set(True)
        d = np.asarray(wavefront_distance(occ, seed, use_kernel=True))
        assert d[1, 1] == 0 and d[1, 2] == 1 and d[0, 1] == 1

    def test_multi_source(self):
        occ, seed = self._random_case(21, 12, 18, p_occ=0.2, n_seeds=3)
        d = np.asarray(wavefront_distance(occ, seed, use_kernel=True))
        np.testing.assert_array_equal(
            d, np.asarray(wavefront_distance_ref(occ, seed)))
        assert (d[np.asarray(seed)] == 0).all()
