"""Template-based hierarchical placer (paper Sec. 3.3, Fig. 7).

Bottom-up, per the paper: inside each hierarchy level only the child
blocks are placed (their internals are opaque); the final macro layout
composes pre-placed templates.

  L0  local array:  L SRAM cells in a vertical strip + CAPLC alongside
  L1  column:       H/L local arrays stacked; ADC periphery (switches,
                    comparator, SAR logic, DFFs) at the column foot —
                    the peripheral ORDER is optimized (exhaustive/greedy
                    HPWL over the RBL/SAR nets, standing in for the
                    grid-based optimization of [25-27])
  L2  macro:        W columns abutted; row drivers on the left edge

Every placement is returned as absolute rectangles on the F grid.
"""
from __future__ import annotations

import dataclasses
import itertools

from repro.core.acim_spec import MacroSpec
from repro.eda.cells import Cell, library


@dataclasses.dataclass(frozen=True)
class Placed:
    name: str
    cell: str
    x: int
    y: int
    w: int
    h: int

    @property
    def cx(self) -> float:
        return self.x + self.w / 2

    @property
    def cy(self) -> float:
        return self.y + self.h / 2


@dataclasses.dataclass
class Placement:
    spec: MacroSpec
    rects: list[Placed]
    width: int
    height: int

    @property
    def area_f2(self) -> int:
        return self.width * self.height

    def area_f2_per_bit(self) -> float:
        return self.area_f2 / self.spec.array_size


def _local_array_template(lib: dict[str, Cell], l: int):
    """L SRAM cells stacked + cap beside; returns (rects, w, h)."""
    s = lib["SRAM8T"]
    c = lib["CAPLC"]
    h = max(l * s.height, c.height)
    rects = [("s%d" % k, "SRAM8T", 0, k * s.height) for k in range(l)]
    rects.append(("cap", "CAPLC", s.width, (h - c.height) // 2))
    return rects, s.width + c.width, h


def _periph_order(lib: dict[str, Cell], spec: MacroSpec) -> list[str]:
    """Order the column periphery to minimize RBL/SAR-bus HPWL.

    The RBL enters from the top (array side): switches must sit nearest,
    then comparator, SAR logic, DFF chain.  We search all orders of the 4
    kinds (4! = 24) and keep the HPWL-minimal one — a miniature of the
    paper's grid-based placement optimization, with the interconnection
    model: RBL touches SW+COMP from the top; CMP->SAR; SAR->DFFs.
    """
    kinds = ["RBLSW", "COMP", "SARLOGIC", "DFF"]
    best, best_cost = None, None
    for order in itertools.permutations(kinds):
        y, pos = 0, {}
        for k in order:
            pos[k] = y
            y += lib[k].height
        # HPWL of: RBL (top=0 to SW and COMP), COMP->SAR, SAR->DFF
        cost = (pos["RBLSW"] + lib["RBLSW"].height
                + pos["COMP"] + lib["COMP"].height
                + abs(pos["COMP"] - pos["SARLOGIC"])
                + abs(pos["SARLOGIC"] - pos["DFF"]))
        if best_cost is None or cost < best_cost:
            best, best_cost = order, cost
    return list(best)


def place(spec: MacroSpec) -> Placement:
    """Pitch-matched composition: the column periphery (switches,
    comparator+SAR, DFFs) is reshaped to the array column width — the
    standard CIM pitch-matching discipline; Eq. 10's A_COMP/H amortization
    is exactly this geometry."""
    lib = library()
    la_rects, la_w, la_h = _local_array_template(lib, spec.l)
    n_la = spec.n_caps
    order = _periph_order(lib, spec)

    rects: list[Placed] = []
    col_w = la_w
    array_h = n_la * la_h

    def pitch_h(kind: str, count: int = 1) -> int:
        """height of `count` cells of `kind` reshaped to the column pitch."""
        return max(1, (lib[kind].area * count + col_w - 1) // col_w)

    n_sw = len(spec.sar_groups()) - 1
    periph_y, y = {}, 0
    counts = {"RBLSW": n_sw, "COMP": 1, "SARLOGIC": 1, "DFF": spec.b_adc}
    for k in order:
        periph_y[k] = y
        y += counts[k] * pitch_h(k) + 1
    periph_h = y

    for j in range(spec.w):
        x0 = j * col_w
        for i in range(n_la):
            y0 = i * la_h
            for name, cellk, dx, dy in la_rects:
                c = lib[cellk]
                rects.append(Placed(f"c{j}_la{i}_{name}", cellk,
                                    x0 + dx, y0 + dy, c.width, c.height))
        ybase = array_h
        for g in range(n_sw):
            rects.append(Placed(f"c{j}_sw{g}", "RBLSW", x0,
                                ybase + periph_y["RBLSW"] + g * pitch_h("RBLSW"),
                                col_w, pitch_h("RBLSW")))
        rects.append(Placed(f"c{j}_comp", "COMP", x0,
                            ybase + periph_y["COMP"], col_w, pitch_h("COMP")))
        rects.append(Placed(f"c{j}_sar", "SARLOGIC", x0,
                            ybase + periph_y["SARLOGIC"], col_w,
                            pitch_h("SARLOGIC")))
        for b in range(spec.b_adc):
            rects.append(Placed(f"c{j}_dff{b}", "DFF", x0,
                                ybase + periph_y["DFF"] + b * pitch_h("DFF"),
                                col_w, pitch_h("DFF")))

    # row drivers on the left edge
    drv = lib["ROWDRV"]
    for r in range(min(spec.h, 64)):
        rects.append(Placed(f"rd{r}", "ROWDRV", 0,
                            r * max(la_h // max(spec.l, 1), drv.height),
                            drv.width, drv.height))

    total_h = array_h + periph_h
    total_w = spec.w * col_w + drv.width + 2
    # shift columns right of the driver strip
    rects = [Placed(r.name, r.cell, r.x + drv.width + 2 if not
                    r.name.startswith("rd") else r.x, r.y, r.w, r.h)
             for r in rects]
    return Placement(spec, rects, total_w, total_h)
