"""Batched serving engine: slot-based continuous batching over the
single-token `decode_step`.

A fixed pool of B slots holds independent sequences; finished slots are
refilled from the request queue without stopping the decode loop
(lightweight continuous batching).  Per-slot position/active masks live on
host; the cache tensor is the jitted step's donated state.  Sampling:
greedy or temperature top-k, deterministic per request id.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.registry import build_model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new: int = 16
    temperature: float = 0.0


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: list[int]


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Any, *, slots: int = 4,
                 max_seq: int = 256, seed: int = 0):
        self.cfg = cfg
        self.api = build_model(cfg)
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.state = self.api.init_decode_state(slots, max_seq)
        self._step = jax.jit(self.api.decode_step)
        self.key = jax.random.key(seed)
        # host-side slot bookkeeping
        self.slot_req: list[Request | None] = [None] * slots
        self.slot_out: list[list[int]] = [[] for _ in range(slots)]
        self.slot_remaining_prompt: list[list[int]] = [[] for _ in range(slots)]
        self.queue: list[Request] = []
        self.done: list[Completion] = []

    # NOTE: positions are global (shared `pos` counter), so slots admitted
    # later simply start deeper in the cache — correct for causal decode
    # since their earlier cache rows are zero-masked by position validity.
    # For strict per-slot positions a per-slot pos vector would be threaded
    # through decode_step; kept scalar to match the serve_step dry-run cell.

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[s] = req
                self.slot_out[s] = []
                self.slot_remaining_prompt[s] = list(req.prompt)

    def _next_tokens(self, logits: np.ndarray) -> np.ndarray:
        toks = np.zeros((self.slots,), np.int32)
        for s in range(self.slots):
            req = self.slot_req[s]
            if req is None:
                continue
            if self.slot_remaining_prompt[s]:
                toks[s] = self.slot_remaining_prompt[s][0]
            elif req.temperature <= 0.0:
                toks[s] = int(np.argmax(logits[s]))
            else:
                self.key, sub = jax.random.split(self.key)
                z = logits[s] / req.temperature
                toks[s] = int(jax.random.categorical(sub, jnp.asarray(z)))
        return toks

    def run(self, max_steps: int = 512) -> list[Completion]:
        """Drive the loop until queue + slots drain (or step budget)."""
        self._admit()
        feed = np.zeros((self.slots,), np.int32)
        for s in range(self.slots):
            if self.slot_req[s] and self.slot_remaining_prompt[s]:
                feed[s] = self.slot_remaining_prompt[s].pop(0)
        for _ in range(max_steps):
            if all(r is None for r in self.slot_req) and not self.queue:
                break
            logits, self.state = self._step(self.params, self.state,
                                            jnp.asarray(feed))
            logits_np = np.asarray(logits)
            nxt = np.zeros((self.slots,), np.int32)
            for s in range(self.slots):
                req = self.slot_req[s]
                if req is None:
                    continue
                if self.slot_remaining_prompt[s]:
                    nxt[s] = self.slot_remaining_prompt[s].pop(0)
                else:
                    if req.temperature <= 0.0:
                        tok = int(np.argmax(logits_np[s]))
                    else:
                        self.key, sub = jax.random.split(self.key)
                        tok = int(jax.random.categorical(
                            sub, jnp.asarray(logits_np[s] / req.temperature)))
                    self.slot_out[s].append(tok)
                    nxt[s] = tok
                    if len(self.slot_out[s]) >= req.max_new:
                        self.done.append(Completion(req.uid, self.slot_out[s]))
                        self.slot_req[s] = None
            self._admit()
            for s in range(self.slots):
                if self.slot_req[s] and self.slot_out[s] == [] \
                        and self.slot_remaining_prompt[s] and nxt[s] == 0:
                    nxt[s] = self.slot_remaining_prompt[s].pop(0)
            feed = nxt
        return self.done
