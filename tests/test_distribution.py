"""Distribution: sharding policy rules, multi-device equivalence
(subprocess with forced host devices), dry-run artifact schema, and the
trip-count-aware collective parser."""
import json
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import registry as creg
from repro.launch.dryrun import collective_bytes
from repro.parallel.sharding import make_policy

REPO = pathlib.Path(__file__).resolve().parents[1]


class TestShardingPolicy:
    def test_divisibility_guards(self):
        cfg = creg.get("arctic_480b")   # 56 heads: not divisible by 16
        mesh = jax.make_mesh((1,), ("model",))
        pol = make_policy(mesh, cfg, fsdp=False)
        rules = pol.activation_rules()
        assert rules["heads"] is None or cfg.n_heads % 1 == 0

    def test_param_specs_cover_tree(self):
        from repro.models.registry import build_model

        cfg = creg.reduced("qwen3_8b")
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        pol = make_policy(mesh, cfg, fsdp=True)
        api = build_model(cfg)
        pshape = jax.eval_shape(api.init, jax.random.key(0))
        specs = pol.param_specs(pshape)
        n_leaves = len(jax.tree.leaves(pshape))
        n_specs = len(jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "index") or x is None))
        assert n_specs >= 1
        # every spec has rank == leaf rank
        def chk(p, s):
            assert len(s) <= len(p.shape) or p.shape == ()

        jax.tree.map(chk, pshape, specs,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    def test_split_kv_rule_for_awkward_heads(self):
        cfg = creg.get("whisper_large_v3")   # 20 kv heads vs 16-way TP
        mesh = jax.make_mesh((2, 2), ("data", "model")) if False else None
        # synthesize a 16-way model mesh logically via policy math
        import numpy as _np

        # use single-device mesh but query the rule logic directly
        mesh = jax.make_mesh((1,), ("model",))
        pol = make_policy(mesh, cfg, fsdp=False)
        rules = pol.activation_rules(decode_batch=128)
        assert "cache_seq" in rules

    def test_mla_forces_cache_seq_sharding(self):
        cfg = creg.get("deepseek_v2_lite_16b")
        mesh = jax.make_mesh((1,), ("model",))
        pol = make_policy(mesh, cfg, fsdp=False)
        rules = pol.activation_rules(decode_batch=128)
        # kv_ok forced False for MLA -> cache_seq takes the tp axis (or None
        # on a degenerate 1-sized axis)
        assert rules["cache_seq"] in ("model", None)


class TestMultiDeviceEquivalence:
    @pytest.mark.slow
    def test_sharded_train_step_matches_single_device(self):
        """Run a reduced train step on a (2,4) host-device mesh in a
        subprocess and compare the loss with single-device execution."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import registry as creg
            from repro.launch import steps as steps_mod
            from repro.data.synthetic import batch_for
            from repro.train.trainer import init_state, TrainerConfig
            cfg = creg.reduced("qwen2_5_3b")
            tcfg = TrainerConfig(seq=32, global_batch=8)
            losses = {}
            for shape, axes in [((8, 1), ("data", "model")),
                                ((2, 4), ("data", "model")),
                                ((1, 1), ("data", "model"))]:
                mesh = jax.make_mesh(shape, axes)
                ts = steps_mod.make_train_step(cfg, mesh)
                state = init_state(cfg, tcfg, ts)
                state = jax.device_put(state, jax.tree.map(
                    lambda s: s.sharding, ts.state_struct))
                batch = batch_for(cfg, 32, 8, 0)
                state, metrics = ts.fn(state, batch)
                losses[str(shape)] = float(metrics["loss"])
            vals = list(losses.values())
            assert max(vals) - min(vals) < 5e-2, losses
            print("OK", losses)
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=600,
                           env={**__import__("os").environ,
                                "PYTHONPATH": str(REPO / "src")})
        assert r.returncode == 0, r.stderr[-3000:]
        assert "OK" in r.stdout


class TestCollectiveParser:
    def test_trip_count_multiplier(self):
        hlo = textwrap.dedent("""
            HloModule test
            %body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
              %all-reduce.7 = f32[8]{0} all-reduce(%gte), to_apply=%add
              ROOT %t = tuple(...)
            }
            %cond (p: (s32[], f32[8])) -> pred[] {
              %c = s32[] constant(12)
              ROOT %lt = pred[] compare(%i, %c), direction=LT
            }
            ENTRY %main (a: f32[8]) -> f32[8] {
              %all-gather.1 = f32[16]{0} all-gather(%a), dimensions={0}
              %while.2 = (s32[], f32[8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
              ROOT %r = f32[8] get-tuple-element(%while.2), index=1
            }
        """)
        c = collective_bytes(hlo)
        assert c["bytes"]["all-gather"] == 16 * 4
        assert c["bytes"]["all-reduce"] == 12 * 8 * 4
        assert c["count"]["all-reduce"] == 1

    def test_dryrun_artifacts_schema(self):
        runs = REPO / "runs" / "dryrun"
        files = list(runs.glob("*.json"))
        if not files:
            pytest.skip("dry-run not populated")
        ok = [json.loads(f.read_text()) for f in files]
        ok = [r for r in ok if r["status"] == "ok"]
        assert ok, "no successful cells recorded"
        for r in ok[:10]:
            assert {"compute_s", "memory_s", "collective_s",
                    "dominant"} <= set(r["roofline"])
            assert r["memory"]["total_bytes"] > 0

    def test_all_40_cells_recorded(self):
        runs = REPO / "runs" / "dryrun"
        files = list(runs.glob("*pod16x16.json"))
        if len(files) < 40:
            pytest.skip("full sweep not yet run")
        recs = [json.loads(f.read_text()) for f in files]
        assert len(recs) == 40
        assert sum(r["status"] == "ok" for r in recs) \
            + sum(r["status"] == "skip" for r in recs) == 40
        skips = [r for r in recs if r["status"] == "skip"]
        assert all(r["shape"] == "long_500k" for r in skips)
