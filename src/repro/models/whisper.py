"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment, the conv frontend is a STUB: `input_specs()` provides
precomputed frame embeddings (B, F, d_model) — everything downstream (32
encoder layers, 32 decoder layers with cross-attention, decode caches) is
real.  Norm = LayerNorm, plain GELU MLPs, sinusoidal positions (encoder) /
learned positions (decoder), MHA (kv == heads), as in arXiv:2212.04356.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import common, mlp
from repro.models.common import (NEG_INF, apply_norm, causal_mask, dense_init,
                                 embed_init, init_norm, sinusoidal_positions)
from repro.parallel.axes import logical

Array = jax.Array


# ---------------------------------------------------------------------------
# cross attention
# ---------------------------------------------------------------------------
def init_cross_attention(key: Array, cfg: ArchConfig) -> dict:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], (d, h * dh)),
            "wk": dense_init(ks[1], (d, h * dh)),
            "wv": dense_init(ks[2], (d, h * dh)),
            "wo": dense_init(ks[3], (h * dh, d))}


def cross_kv(p: dict, enc: Array, cfg: ArchConfig):
    b, f, _ = enc.shape
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    k = (enc @ p["wk"].astype(enc.dtype)).reshape(b, f, h, dh)
    v = (enc @ p["wv"].astype(enc.dtype)).reshape(b, f, h, dh)
    return k, v


def cross_attention_fwd(p: dict, x: Array, k: Array, v: Array,
                        cfg: ArchConfig) -> Array:
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    scores = jnp.einsum("bshd,bfhd->bhsf", q, k) / np.sqrt(dh)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhsf,bfhd->bshd", probs, v).reshape(b, s, h * dh)
    return out @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_enc_layer(key: Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {"ln1": init_norm(d, cfg.norm),
            "attn": attn.init_attention(ks[0], cfg),
            "ln2": init_norm(d, cfg.norm),
            "ffn": mlp.init_mlp(ks[1], d, cfg.d_ff, cfg)}


def _init_dec_layer(key: Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {"ln1": init_norm(d, cfg.norm),
            "attn": attn.init_attention(ks[0], cfg),
            "lnx": init_norm(d, cfg.norm),
            "xattn": init_cross_attention(ks[1], cfg),
            "ln2": init_norm(d, cfg.norm),
            "ffn": mlp.init_mlp(ks[2], d, cfg.d_ff, cfg)}


def init_whisper(key: Array, cfg: ArchConfig) -> dict:
    ne = cfg.encdec.n_enc_layers
    nd = cfg.n_layers
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    enc_keys = jax.random.split(k1, ne)
    dec_keys = jax.random.split(k2, nd)
    return {
        "enc_blocks": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": init_norm(cfg.d_model, cfg.norm),
        "emb": embed_init(k3, (cfg.vocab, cfg.d_model)),
        "pos_emb": embed_init(k4, (common.MAX_LEARNED_POS, cfg.d_model)),
        "dec_blocks": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "dec_norm": init_norm(cfg.d_model, cfg.norm),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def encode(params: dict, frames: Array, cfg: ArchConfig, *,
           remat: bool = False) -> Array:
    """frames: (B, F, D) stub embeddings -> encoder hidden (B, F, D)."""
    b, f, d = frames.shape
    x = frames.astype(jnp.bfloat16) + sinusoidal_positions(f, d).astype(jnp.bfloat16)[None]
    x = logical(x, "batch", "frames", "embed")
    full = jnp.ones((f, f), jnp.bool_)
    positions = jnp.arange(f)

    def step(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        x = x + attn.attention_fwd(lp["attn"], h, cfg, mask=full, positions=positions)
        h = apply_norm(lp["ln2"], x, cfg.norm)
        return x + mlp.mlp_fwd(lp["ffn"], h, cfg), None

    if remat:
        step = jax.checkpoint(step)
    x, _ = jax.lax.scan(step, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg.norm)


def decode_fwd(params: dict, tokens: Array, enc: Array, cfg: ArchConfig, *,
               remat: bool = False, attn_impl: str = "dense") -> Array:
    """Teacher-forced decoder forward.  Returns logits (B, S, V)."""
    b, s = tokens.shape
    x = params["emb"][tokens].astype(jnp.bfloat16)
    x = x + params["pos_emb"][:s].astype(x.dtype)[None]
    x = logical(x, "batch", "seq", "embed")
    mask = causal_mask(s) if attn_impl == "dense" else None
    positions = jnp.arange(s)

    def step(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        if attn_impl == "blockwise":
            a = attn.attention_fwd_blockwise(lp["attn"], h, cfg,
                                             positions=positions)
        else:
            a = attn.attention_fwd(lp["attn"], h, cfg, mask=mask,
                                   positions=positions)
        x = x + a
        h = apply_norm(lp["lnx"], x, cfg.norm)
        k, v = cross_kv(lp["xattn"], enc, cfg)
        x = x + cross_attention_fwd(lp["xattn"], h, k, v, cfg)
        h = apply_norm(lp["ln2"], x, cfg.norm)
        return x + mlp.mlp_fwd(lp["ffn"], h, cfg), None

    if remat:
        step = jax.checkpoint(step)
    x, _ = jax.lax.scan(step, x, params["dec_blocks"])
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    logits = x @ params["emb"].T.astype(x.dtype)   # whisper ties output to emb
    return logical(logits, "batch", "logits_seq", "vocab")


def whisper_loss(params: dict, batch: dict, cfg: ArchConfig, *,
                 remat: bool = False):
    enc = encode(params, batch["frames"], cfg, remat=remat)
    logits = decode_fwd(params, batch["inputs"], enc, cfg, remat=remat)
    loss, metrics = common.softmax_cross_entropy(logits, batch["targets"])
    metrics["aux_loss"] = jnp.float32(0.0)
    return loss, metrics


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------
def init_whisper_decode_state(cfg: ArchConfig, batch: int, max_seq: int):
    nd = cfg.n_layers
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    f = cfg.encdec.enc_frames
    self_cache = attn.init_kv_cache(cfg, batch, max_seq)
    return {
        "caches": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (nd,) + a.shape), self_cache),
        "cross_k": jnp.zeros((nd, batch, f, h, dh), jnp.bfloat16),
        "cross_v": jnp.zeros((nd, batch, f, h, dh), jnp.bfloat16),
        "pos": jnp.zeros((), jnp.int32),
    }


def precompute_cross(params: dict, frames: Array, cfg: ArchConfig):
    """Run the encoder once and cache per-layer cross K/V for decode."""
    enc = encode(params, frames, cfg)

    def per_layer(lp):
        k, v = cross_kv(lp["xattn"], enc, cfg)
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

    return jax.vmap(per_layer)(params["dec_blocks"])


def whisper_decode_step(params: dict, state: dict, tokens: Array,
                        cfg: ArchConfig):
    pos = state["pos"]
    x = params["emb"][tokens].astype(jnp.bfloat16)
    x = x + params["pos_emb"][pos].astype(x.dtype)[None]
    nl = cfg.n_layers

    def step(i, carry):
        x, caches = carry
        at = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)
        lp = jax.tree.map(at, params["dec_blocks"])
        cache = jax.tree.map(at, caches)
        ck, cv = at(state["cross_k"]), at(state["cross_v"])
        h = apply_norm(lp["ln1"], x[:, None], cfg.norm)[:, 0]
        a, c2 = attn.attention_decode(lp["attn"], h, cache, pos, cfg)
        x = x + a
        h = apply_norm(lp["lnx"], x[:, None], cfg.norm)
        x = x + cross_attention_fwd(lp["xattn"], h, ck.astype(h.dtype),
                                    cv.astype(h.dtype), cfg)[:, 0]
        h = apply_norm(lp["ln2"], x[:, None], cfg.norm)[:, 0]
        x = x + mlp.mlp_fwd(lp["ffn"], h, cfg)
        caches = jax.tree.map(
            lambda a, u: jax.lax.dynamic_update_index_in_dim(
                a, u.astype(a.dtype), i, 0), caches, c2)
        return x, caches

    x, new_caches = jax.lax.fori_loop(0, nl, step, (x, state["caches"]))
    x = apply_norm(params["dec_norm"], x[:, None], cfg.norm)[:, 0]
    logits = x @ params["emb"].T.astype(x.dtype)
    new_state = dict(state, caches=new_caches, pos=pos + 1)
    return logits.astype(jnp.float32), new_state
