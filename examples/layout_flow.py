"""Reproduce the paper's Fig. 8: three 16 kb ACIM layouts at different
design specifications, through the *batched* layout path — netlist stats,
placement, routing and DRC for all three specs in one dispatch chain
(`repro.api.DesignSession.layout`), the way a distilled Pareto set is
laid out.  Pass --full to also run the sequential `generate_layout` per
spec and export full GDS-like JSON (named cells + wire geometry), which
the batched path intentionally skips.

  PYTHONPATH=src python examples/layout_flow.py [--full]
"""
import pathlib
import sys
import time

from repro.api import DesignSession
from repro.core.acim_spec import MacroSpec
from repro.eda.flow import generate_layout

# (spec, paper TOPS, paper F^2/bit) — see benchmarks/fig8_layouts.py
PAPER = {
    "a": (MacroSpec(128, 128, 2, 3), 3.277, 4504.0),
    "b": (MacroSpec(512, 32, 8, 3), 0.813, 2610.0),
    "c": (MacroSpec(256, 64, 8, 3), 0.813, 2977.0),
}

OUT = pathlib.Path("runs/fig8")


def main() -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    specs = [spec for spec, _, _ in PAPER.values()]
    t0 = time.perf_counter()
    res = DesignSession().layout(specs)
    elapsed = time.perf_counter() - t0
    res.to_json(OUT / "fig8_batched.json")
    for (tag, (spec, _, paper_area)), m in zip(PAPER.items(),
                                               res.metrics_rows()):
        print(f"({tag}) H={spec.h} W={spec.w} L={spec.l} B={spec.b_adc}: "
              f"layout {m['layout_area_f2_per_bit']:.0f} F^2/bit "
              f"(paper {paper_area:.0f}), routed {m['routed_nets']} nets, "
              f"DRC clean={m['drc_clean']}")
    print(f"batched: {len(specs)} layouts in {elapsed:.1f}s "
          f"-> {OUT}/fig8_batched.json")
    if "--full" in sys.argv[1:]:
        for tag, (spec, _, _) in PAPER.items():
            lr = generate_layout(spec)
            lr.to_json(OUT / f"fig8_{tag}.json")
            print(f"({tag}) full layout JSON ({len(lr.placement.rects)} "
                  f"cells, {len(lr.routing.wires)} wires) in "
                  f"{lr.metrics()['elapsed_s']:.1f}s")


if __name__ == "__main__":
    main()
