"""Grid router (paper Sec. 2.3 / 3.3): Lee-style wavefront on a coarse
routing grid, hierarchical per the paper — template internals use
predefined tracks (constant-time), only inter-template nets are maze-routed.

Nets are routed sequentially, longest-first, on an occupancy grid with a
per-track capacity; power and SAR control nets go on reserved tracks
first (the paper's "pre-defined routing tracks for critical nets").

Since PR 2 the wavefront itself is the `repro.kernels.maze_route` op
(jnp reference off-TPU, grid-batched Pallas kernel on TPU) instead of a
host-Python BFS queue: one dispatch computes the full distance field
from the net's hub, and the host only backtraces the (short) paths.
The backtrace is deterministic — at distance d it steps to the first
neighbour at d-1 in `NEIGHBORS` order — and
`repro.eda.batched_flow.batched_route` uses the *same* field and the
same tie-break, which is what makes the batched layout path per-spec
identical to this sequential one.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.eda.placer import Placement
from repro.kernels.maze_route import INF, wavefront_distance

# Backtrace preference order (down, up, right, left) — shared with the
# batched router so sequential and batched paths pick identical cells.
NEIGHBORS = ((1, 0), (-1, 0), (0, 1), (0, -1))


@dataclasses.dataclass(frozen=True)
class Wire:
    net: str
    points: tuple[tuple[int, int], ...]     # grid path (coarse units)
    layer_pattern: str = "HV"


@dataclasses.dataclass
class RoutingResult:
    wires: list[Wire]
    grid_shape: tuple[int, int]
    coarse: int
    failed: list[str]
    total_wirelength: int

    @property
    def success_rate(self) -> float:
        n = len(self.wires) + len(self.failed)
        return len(self.wires) / n if n else 1.0


def grid_shape(width: int, height: int, coarse: int) -> tuple[int, int]:
    """Coarse routing-grid extent for a macro bounding box."""
    return (max(2, height // coarse + 3), max(2, width // coarse + 2))


def target_distance(dist: np.ndarray, dst: tuple[int, int]) -> int:
    """Path length (in steps) from the wavefront source to `dst`.

    A destination pin is always enterable even when its cell is at track
    capacity (the classic Lee-router exception), so a blocked dst costs
    one step more than its best free neighbour.  Returns `INF` when
    unreachable.
    """
    d = int(dist[dst])
    if d < INF:
        return d
    h, w = dist.shape
    best = INF
    for dy, dx in NEIGHBORS:
        ny, nx = dst[0] + dy, dst[1] + dx
        if 0 <= ny < h and 0 <= nx < w:
            best = min(best, int(dist[ny, nx]))
    return min(INF, best + 1) if best < INF else INF


def backtrace(dist: np.ndarray, dst: tuple[int, int]):
    """Walk the distance field from `dst` down to the source.

    Returns the path src -> dst (inclusive), or None when unreachable.
    Tie-break: first neighbour in `NEIGHBORS` order at distance d-1.
    """
    d = target_distance(dist, dst)
    if d >= INF:
        return None
    h, w = dist.shape
    path = [dst]
    cur = dst
    while d > 0:
        for dy, dx in NEIGHBORS:
            ny, nx = cur[0] + dy, cur[1] + dx
            if 0 <= ny < h and 0 <= nx < w and int(dist[ny, nx]) == d - 1:
                cur = (ny, nx)
                break
        else:  # pragma: no cover - the field always contains the chain
            return None
        path.append(cur)
        d -= 1
    return path[::-1]


def route(placement: Placement, nets: list[tuple[str, list[tuple[int, int]]]],
          *, coarse: int = 64, capacity: int = 4,
          use_kernel: bool | None = None,
          impl: str | None = None) -> RoutingResult:
    """Route multi-pin nets (star topology around the first pin) on a
    coarse grid.  nets: (name, [(x, y) pin coords in F units]).

    `impl` passes through to `wavefront_distance` — with both it and
    `use_kernel` unset, host calls get the frontier-bucketed engine
    (every impl produces the identical field, so the routing result
    does not depend on the choice)."""
    if use_kernel is not None:
        warnings.warn(
            "route(use_kernel=...) is deprecated; pass "
            "impl='kernel'/'ref' (see docs/kernels.md)",
            DeprecationWarning, stacklevel=2)
        if impl is None:
            impl = "kernel" if use_kernel else "ref"
    gh, gw = grid_shape(placement.width, placement.height, coarse)
    occ_count = np.zeros((gh, gw), np.int16)
    wires: list[Wire] = []
    failed: list[str] = []
    total = 0

    def cell(p):
        x, y = p
        return (min(gh - 1, max(0, int(y) // coarse)),
                min(gw - 1, max(0, int(x) // coarse)))

    # longest (bounding box) first
    def span(pins):
        xs = [p[0] for p in pins]
        ys = [p[1] for p in pins]
        return (max(xs) - min(xs)) + (max(ys) - min(ys))

    seed = np.zeros((gh, gw), bool)
    for name, pins in sorted(nets, key=lambda n: -span(n[1])):
        if len(pins) < 2:
            continue
        hub = cell(pins[0])
        occ = occ_count >= capacity
        seed[:] = False
        seed[hub] = True
        dist = np.asarray(wavefront_distance(occ, seed, impl=impl))
        pts: list[tuple[int, int]] = []
        ok = True
        for p in pins[1:]:
            path = backtrace(dist, cell(p))
            if path is None:
                ok = False
                break
            pts.extend(path)
        if ok:
            for y, x in pts:
                occ_count[y, x] += 1
            total += len(pts)
            wires.append(Wire(name, tuple(pts)))
        else:
            failed.append(name)
    return RoutingResult(wires, (gh, gw), coarse, failed, total)
