"""Tests for the `repro.analysis` static-analysis passes, the
suppression machinery, the runtime lock sanitizer, and the lock-fix
regressions the passes motivated.

Each pass is pinned to a pair of fixtures under
`tests/fixtures/analysis/`: a *bad* module that must produce the
pass's findings and a *good* twin that must be clean — so a pass that
silently stops firing fails here, not in a missed review.  The live
tree itself is then self-scanned: `run_all(REPO, strict=True)` must
keep zero findings, which is exactly the CI `lint` gate.
"""
import json
import pathlib
import threading

import pytest

from repro.analysis import run_all
from repro.analysis.core import (Finding, apply_suppressions, parse_file)
from repro.analysis import lock_discipline, schema_drift, trace_purity
from repro.runtime.lock_sanitizer import (InstrumentedLock,
                                          LockOrderRegistry, make_lock)

REPO = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "analysis"


def _mod(fname, name=None):
    return parse_file(FIXTURES / fname, root=FIXTURES, name=name)


def _rules(findings):
    return {f.rule for f in findings}


class TestTracePurity:
    def test_bad_fixture_flags_every_family(self):
        m = _mod("trace_bad.py")
        found = trace_purity.run({m.name: m})
        assert _rules(found) == {"host-call", "inplace-store",
                                 "set-iteration"}
        # time.time() and print() are separate findings, and the
        # inplace-store is in `fill`, reached transitively from `outer`.
        assert sum(f.rule == "host-call" for f in found) == 2
        assert any("fill" in f.message for f in found
                   if f.rule == "inplace-store")

    def test_good_fixture_is_clean(self):
        m = _mod("trace_good.py")
        assert trace_purity.run({m.name: m}) == []

    def test_ops_dispatch_contract(self):
        bad = _mod("ops_bad.py", name="repro.kernels.fake.ops")
        good = _mod("ops_good.py", name="repro.kernels.fake.ops")
        found = trace_purity.run({bad.name: bad})
        assert _rules(found) == {"host-guard"}
        assert "sweep_frontier" in found[0].message
        assert trace_purity.run({good.name: good}) == []

    def test_ops_rule_only_applies_to_kernel_ops_modules(self):
        # The same unguarded source under a non-ops name is out of scope.
        m = _mod("ops_bad.py", name="repro.eda.fake_router")
        assert trace_purity.run({m.name: m}) == []


class TestLockDiscipline:
    def test_bad_fixture_flags_every_family(self):
        m = _mod("locks_bad.py")
        found = lock_discipline.run({m.name: m})
        assert _rules(found) == {"unguarded-attr", "lock-order",
                                 "lock-reacquire"}
        unguarded = [f for f in found if f.rule == "unguarded-attr"]
        assert all("count" in f.message for f in unguarded)
        # both the thread-root write and the external read are named
        assert len(unguarded) == 2

    def test_good_fixture_is_clean(self):
        m = _mod("locks_good.py")
        assert lock_discipline.run({m.name: m}) == []


class TestSchemaDrift:
    def _run(self, tmp_path, fname, manifest_from="schema_base.py"):
        base = _mod(manifest_from, name="repro.telemetry.spans")
        (tmp_path / "src/repro/analysis").mkdir(parents=True)
        schema_drift.write_manifest(tmp_path, {base.name: base})
        live = _mod(fname, name="repro.telemetry.spans")
        return schema_drift.run({live.name: live}, root=tmp_path)

    def test_unchanged_schema_is_clean(self, tmp_path):
        assert self._run(tmp_path, "schema_base.py") == []

    def test_field_change_without_bump_is_drift(self, tmp_path):
        found = self._run(tmp_path, "schema_drifted.py")
        assert _rules(found) == {"schema-drift"}
        assert "TraceExport.to_dict:host" in found[0].message

    def test_bump_with_stale_manifest_is_stale(self, tmp_path):
        found = self._run(tmp_path, "schema_bumped.py")
        assert _rules(found) == {"manifest-stale"}

    def test_missing_manifest_is_stale(self, tmp_path):
        live = _mod("schema_base.py", name="repro.telemetry.spans")
        found = schema_drift.run({live.name: live}, root=tmp_path)
        assert _rules(found) == {"manifest-stale"}

    def test_committed_manifest_matches_live_tree(self):
        """`--update-manifest` was run after the last schema change."""
        from repro.analysis.core import load_tree

        committed = json.loads(
            (REPO / schema_drift.MANIFEST_PATH).read_text())
        assert schema_drift.extract(load_tree(REPO)) == committed


class TestSuppressions:
    def _module(self, tmp_path, text):
        p = tmp_path / "m.py"
        p.write_text(text)
        return parse_file(p, root=tmp_path)

    def test_line_suppression_with_reason(self, tmp_path):
        m = self._module(
            tmp_path, "x = 1  # lint: disable=host-call -- fixture\n")
        f = Finding("host-call", m.rel, 1, "probe")
        kept, suppressed = apply_suppressions([f], {m.name: m},
                                              strict=True)
        assert kept == [] and suppressed == [f]

    def test_strict_flags_reasonless_unknown_and_unused(self, tmp_path):
        m = self._module(tmp_path, "\n".join([
            "a = 1  # lint: disable=host-call",           # no reason
            "b = 2  # lint: disable=not-a-rule -- why",   # unknown rule
            "c = 3  # lint: disable=set-iteration -- why",  # unused
            ""]))
        kept, _ = apply_suppressions([], {m.name: m}, strict=True)
        assert [f.rule for f in kept] == ["bad-suppression"] * 3

    def test_docstring_mention_is_not_a_suppression(self, tmp_path):
        m = self._module(
            tmp_path,
            '"""Docs show: # lint: disable=host-call -- like so."""\n')
        kept, _ = apply_suppressions([], {m.name: m}, strict=True)
        assert kept == []


class TestLiveTree:
    def test_self_scan_is_clean(self):
        """The CI `lint` gate: zero kept findings over src/repro."""
        kept, _ = run_all(REPO, strict=True)
        assert kept == [], "\n".join(f.render() for f in kept)


class TestLockSanitizer:
    def test_reacquisition_raises_immediately(self):
        reg = LockOrderRegistry()
        a = InstrumentedLock("a", reg)
        with a:
            with pytest.raises(AssertionError, match="already held"):
                a.acquire()
        reg.assert_clean()

    def test_inversion_caught_at_teardown(self):
        reg = LockOrderRegistry()
        a, b = InstrumentedLock("a", reg), InstrumentedLock("b", reg)
        with a:
            with b:
                pass
        with b:
            with a:
                pass
        with pytest.raises(AssertionError, match="inversion"):
            reg.assert_clean()
        reg.reset()
        reg.assert_clean()

    def test_consistent_order_is_clean_across_threads(self):
        reg = LockOrderRegistry()
        a, b = InstrumentedLock("a", reg), InstrumentedLock("b", reg)

        def use():
            for _ in range(50):
                with a:
                    with b:
                        pass

        threads = [threading.Thread(target=use) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert reg.edges() == {("a", "b"): 200}
        reg.assert_clean()

    def test_make_lock_gated_by_env(self, monkeypatch):
        monkeypatch.delenv("REPRO_LOCK_SANITIZER", raising=False)
        assert isinstance(make_lock("x"), type(threading.Lock()))
        monkeypatch.setenv("REPRO_LOCK_SANITIZER", "1")
        lock = make_lock("x")
        assert isinstance(lock, InstrumentedLock)
        # Condition duck-types over the wrapper (wait/notify machinery
        # routes through acquire/release and is order-checked too).
        cond = threading.Condition(lock)
        with cond:
            cond.notify_all()


class TestLockFixRegressions:
    """Pin the code-level fixes the lock-discipline pass motivated."""

    def test_session_bump_is_atomic_under_contention(self):
        from repro.api.session import DesignSession

        s = DesignSession()

        def worker():
            for _ in range(500):
                s.bump("probe")
                s.bump("probe_n", 2)

        threads = [threading.Thread(target=worker) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        with s.stats_lock:
            assert s.stats["probe"] == 4000
            assert s.stats["probe_n"] == 8000

    def test_service_stats_snapshot_while_counters_move(self):
        """stats() copies under stats_lock: concurrent bump() inserts
        (dict resizes) must not corrupt or crash the snapshot."""
        from repro.serve.design_service import DesignService

        svc = DesignService()
        stop = threading.Event()

        def churn():
            i = 0
            while not stop.is_set():
                svc.session.bump(f"churn_{i % 97}")
                i += 1

        t = threading.Thread(target=churn)
        t.start()
        try:
            for _ in range(50):
                snap = svc.stats()
                assert snap["layout_workers"] == svc.layout_workers
        finally:
            stop.set()
            t.join()
        # the snapshot is a copy — mutating it cannot corrupt the service
        snap = svc.stats()
        snap["layout_workers"] = -1
        assert svc.stats()["layout_workers"] != -1

    def test_gauge_callbacks_sample_under_locks(self):
        """The metrics gauges read pipeline fields via lock-wrapped
        closures; a full snapshot must agree with stats() when idle."""
        from repro.serve.design_service import DesignService

        svc = DesignService()
        snap = svc.metrics()
        gauges = {}
        for name, entries in snap["metrics"].items():
            for m in entries:
                if m["type"] == "gauge":
                    key = (name, tuple(sorted(m["labels"].items())))
                    gauges[key] = m["value"]
        assert gauges[("design_layout_workers", ())] == \
            svc.stats()["layout_workers"]
        assert gauges[("design_coalesce_window_s", ())] == \
            pytest.approx(svc.coalesce_window_s)

    def test_service_lock_order_clean_under_sanitizer(self, monkeypatch):
        """End-to-end: a sanitizer-instrumented service records the
        canonical `_lock -> stats_lock` edge and no inversion."""
        monkeypatch.setenv("REPRO_LOCK_SANITIZER", "1")
        import repro.serve.design_service as ds

        reg = LockOrderRegistry()
        real = InstrumentedLock

        def patched(name, registry=None):
            return real(name, reg)

        monkeypatch.setattr("repro.runtime.lock_sanitizer."
                            "InstrumentedLock", patched)
        svc = ds.DesignService()
        svc.session.bump("probe")
        svc.stats()
        assert ("DesignService._lock",
                "DesignSession.stats_lock") in reg.edges()
        reg.assert_clean()
