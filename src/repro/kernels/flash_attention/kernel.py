"""Pallas TPU kernel: causal flash attention (online softmax), GQA-ready.

The jnp blockwise implementation (`models/attention.py::_blockwise_core`)
is the oracle; this kernel is the TPU-native form: one (q-block) VMEM tile
per grid step, KV streamed in `block_k` chunks with the running
(max, sum, acc) carried in registers.  MXU-aligned block shapes; heads are
folded into the grid's leading axis so GQA layouts reuse the same kernel
(ops.py broadcasts KV heads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float,
            causal: bool):
    bq, dh = q_ref.shape
    t = k_ref.shape[0]
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale

    def body(j, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T                    # (bq, bk)
        if causal:
            q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            s = jnp.where(k_idx <= q_idx, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + p @ v.astype(jnp.float32)
        return acc_new, m_new, l_new

    n_blocks = t // block_k
    if causal:
        # only KV blocks up to this q block contribute
        n_blocks = jnp.minimum(n_blocks, (qi + 1) * bq // block_k
                               + (1 if bq % block_k or True else 0))
        n_blocks = jnp.minimum(n_blocks, t // block_k)
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_blocks, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                             "interpret"))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           block_q: int = 128, block_k: int = 128,
                           causal: bool = True,
                           interpret: bool = False) -> jax.Array:
    """q: (BH, S, Dh); k/v: (BH, T, Dh); S % block_q == T % block_k == 0."""
    bh, s, dh = q.shape
    t = k.shape[1]
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    scale = 1.0 / (dh ** 0.5)
    grid = (bh, s // block_q)
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, scale=scale,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, t, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, t, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
