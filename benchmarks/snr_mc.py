"""Monte-Carlo validation of the analytical SNR model (paper Eqs. 2-6).

Simulates the QR macro (ADC quantization + Eq. 5 mismatch/thermal noise)
on random 1b data and compares measured SNR to `estimator.snr_total_db`
across (N, B_ADC) operating points.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acim_numerics as an
from repro.core import estimator
from repro.core.acim_spec import MacroSpec

POINTS = [(128, 2, 3), (128, 2, 5), (512, 8, 4), (256, 2, 6), (1024, 32, 5)]


def mc_snr_db(spec: MacroSpec, *, rows: int = 256, cols: int = 64,
              noisy: bool = True, seed: int = 0) -> float:
    k = spec.n_caps
    x = jnp.where(jax.random.bernoulli(jax.random.key(seed), 0.5,
                                       (rows, k)), 1.0, -1.0)
    w = jnp.where(jax.random.bernoulli(jax.random.key(seed + 1), 0.5,
                                       (k, cols)), 1.0, -1.0)
    noise = an.NoiseParams.from_cal() if noisy else None
    y = an.acim_matmul_ref(x, w, spec, noise=noise,
                           instance_key=jax.random.key(seed + 2),
                           conversion_key=jax.random.key(seed + 3))
    ref = x @ w
    return 10.0 * float(np.log10(float(jnp.var(ref))
                                 / max(float(jnp.var(y - ref)), 1e-12)))


def main() -> None:
    print("h,l,b_adc,analytic_db,mc_db,delta_db")
    for h, l, b in POINTS:
        spec = MacroSpec(h, 64, l, b)
        ana = float(estimator.snr_total_db(h, l, b))
        mc = mc_snr_db(spec)
        print(f"{h},{l},{b},{ana:.2f},{mc:.2f},{mc - ana:+.2f}")


if __name__ == "__main__":
    main()
