"""Fault-tolerant design fleet: per-bucket retry + isolation in the
layout pool, supervised stage workers, preemption journal + replay,
straggler shedding, and the `TicketJournal` / `PreemptionGuard` /
`run_supervised` primitives they are built on.

Every fault here is injected deterministically (`FailureInjector`
schedules, monkeypatched stage functions, injectable `sleep`) — no real
signals, no flaky timing assumptions beyond generous deadlines."""
import threading
import time

import pytest

from repro.api import DesignRequest, DesignSession, Requirements, TicketJournal
from repro.runtime.fault_tolerance import (FailureInjector, PreemptionGuard,
                                           SimulatedNodeFailure,
                                           StragglerMonitor, capped_backoff,
                                           run_supervised)
from repro.serve.design_service import DesignService, PendingTicket

# threaded pipeline tests deadlock rather than fail when broken
pytestmark = pytest.mark.timeout(900)

POP, GENS = 16, 4
REQS = Requirements(min_tops=0.5, min_snr_db=10.0)


def _request(array_size=4096, seed=0, **kw):
    kw.setdefault("pop_size", POP)
    kw.setdefault("generations", GENS)
    return DesignRequest(array_size=array_size, seed=seed, **kw)


def _fast_svc(**kw):
    """A service with sub-millisecond retry backoff (tests should not
    wait out real backoff) and a short coalescing window."""
    kw.setdefault("coalesce_window_s", 0.02)
    kw.setdefault("retry_backoff_s", 0.001)
    kw.setdefault("retry_backoff_cap_s", 0.002)
    return DesignService(**kw)


# -- primitives: backoff, guard, supervisor, injector ----------------------

class TestCappedBackoff:
    def test_exponential_then_capped(self):
        delays = [capped_backoff(n, base_s=0.1, cap_s=0.5)
                  for n in (1, 2, 3, 4, 5)]
        assert delays == [0.1, 0.2, 0.4, 0.5, 0.5]

    def test_jitter_bounded_and_attempt_validated(self):
        import random
        rng = random.Random(7)
        for _ in range(50):
            d = capped_backoff(3, base_s=0.1, cap_s=10.0,
                               jitter_frac=0.25, rng=rng)
            assert 0.4 <= d <= 0.4 * 1.25
        with pytest.raises(ValueError, match="1-based"):
            capped_backoff(0, base_s=0.1, cap_s=1.0)


class TestPreemptionGuard:
    def test_double_install_raises_and_uninstall_restores_once(self):
        import signal
        before = signal.getsignal(signal.SIGTERM)
        guard = PreemptionGuard()
        assert not guard.installed
        guard.install()
        assert guard.installed
        with pytest.raises(RuntimeError, match="install\\(\\) called twice"):
            guard.install()
        guard.uninstall()
        assert not guard.installed
        assert signal.getsignal(signal.SIGTERM) is before
        # idempotent: a second uninstall must not re-restore stale
        # handlers over someone else's
        other = PreemptionGuard().install()
        guard.uninstall()   # no-op, NOT a restore of `before`
        assert signal.getsignal(signal.SIGTERM) == other._handler
        other.uninstall()
        assert signal.getsignal(signal.SIGTERM) is before

    def test_context_manager_and_request_without_install(self):
        import signal
        before = signal.getsignal(signal.SIGTERM)
        with PreemptionGuard() as guard:
            assert guard.installed
            assert not guard.preempted
            guard.request()
            assert guard.preempted
        assert not guard.installed
        assert signal.getsignal(signal.SIGTERM) is before
        # request() never needs install() (the test path)
        g = PreemptionGuard()
        g.request()
        assert g.preempted and not g.installed


class TestRunSupervised:
    def test_backoff_spacing_between_restarts(self):
        slept, calls = [], []

        def crashy():
            calls.append(1)
            if len(calls) < 4:
                raise SimulatedNodeFailure("boom")
            return 0

        code = run_supervised(crashy, max_restarts=5, backoff_s=0.1,
                              backoff_cap_s=0.25, sleep=slept.append)
        assert code == 0 and len(calls) == 4
        assert slept == [0.1, 0.2, 0.25]   # capped exponential

    def test_budget_exhausted_raises(self):
        slept = []

        def always():
            raise SimulatedNodeFailure("boom")

        with pytest.raises(RuntimeError, match="restart budget exhausted"):
            run_supervised(always, max_restarts=2, backoff_s=0.05,
                           sleep=slept.append)
        assert len(slept) == 2   # no sleep after the final give-up

    def test_restart_on_filters_exception_types(self):
        def raises_value_error():
            raise ValueError("not restartable by default")

        with pytest.raises(ValueError):
            run_supervised(raises_value_error, backoff_s=0.0)
        calls = []

        def once():
            calls.append(1)
            if len(calls) == 1:
                raise ValueError("restartable here")
            return 0

        assert run_supervised(once, restart_on=(Exception,),
                              backoff_s=0.0) == 0

    def test_on_restart_callback_counts(self):
        seen, calls = [], []

        def twice():
            calls.append(1)
            if len(calls) < 3:
                raise SimulatedNodeFailure("boom")
            return 0

        run_supervised(twice, backoff_s=0.0, on_restart=seen.append)
        assert seen == [1, 2]


class TestFailureInjector:
    def test_stage_schedule_fires_once_per_unit(self):
        inj = FailureInjector(fail_at={"layout": [2]})
        inj.fire("layout", 0)
        inj.fire("layout", 1)
        with pytest.raises(SimulatedNodeFailure, match="layout .* unit 2"):
            inj.fire("layout", 2)
        inj.fire("layout", 3)    # a retried unit gets a new index: no fire
        inj.fire("explore", 2)   # other stages unaffected
        assert inj.fired == [("layout", 2, "node")]

    def test_per_entry_kind_override_and_preempt(self):
        guard = PreemptionGuard()
        inj = FailureInjector(fail_at={"admit": [(1, "preempt")],
                                       "layout": [0]}, guard=guard)
        inj.fire("admit", 0)
        assert not guard.preempted
        inj.fire("admit", 1)
        assert guard.preempted
        with pytest.raises(SimulatedNodeFailure):
            inj.fire("layout", 0)

    def test_preempt_without_guard_and_unknown_kind(self):
        with pytest.raises(ValueError, match="PreemptionGuard"):
            FailureInjector(fail_at={"layout": [(0, "preempt")]}) \
                .fire("layout", 0)
        with pytest.raises(ValueError, match="unknown failure kind"):
            FailureInjector(fail_at={"layout": [(0, "meteor")]}) \
                .fire("layout", 0)

    def test_slow_kind_sleeps(self, monkeypatch):
        import repro.runtime.fault_tolerance as ft
        slept = []
        monkeypatch.setattr(ft.time, "sleep", slept.append)
        FailureInjector(kind="slow", slow_seconds=3.0,
                        fail_at={"layout": [0]}).fire("layout", 0)
        assert slept == [3.0]

    def test_legacy_train_step_shape_still_works(self):
        inj = FailureInjector(fail_at_steps=(5,))
        inj.maybe_fail(4)
        with pytest.raises(SimulatedNodeFailure):
            inj.maybe_fail(5)


# -- ticket journal (the preemption WAL) -----------------------------------

class TestTicketJournal:
    def test_write_replay_roundtrip_preserves_order(self, tmp_path):
        j = TicketJournal(tmp_path / "wal" / "journal.jsonl")
        reqs = [_request(seed=sd, layout=False) for sd in (3, 1, 2)]
        assert j.write(reqs) == 3
        assert len(j) == 3
        assert j.replay() == reqs        # admission order, not seed order
        assert j.replay() == reqs        # replay does NOT clear
        j.clear()
        assert j.replay() == [] and len(j) == 0

    def test_write_is_full_rewrite_and_empty_clears(self, tmp_path):
        j = TicketJournal(tmp_path / "journal.jsonl")
        j.write([_request(seed=1, layout=False)])
        j.write([_request(seed=2, layout=False)])
        assert [r.seed for r in j.replay()] == [2]   # replaced, not appended
        j.write([])
        assert not j.path.exists()

    def test_corrupt_line_skipped_and_counted(self, tmp_path):
        j = TicketJournal(tmp_path / "journal.jsonl")
        good = _request(seed=9, layout=False)
        j.write([good])
        j.path.write_text("this is not json\n" + good.to_json() + "\n")
        assert j.replay() == [good]
        assert j.stats["rejects"] == 1

    def test_beside_cache_colocation(self, tmp_path):
        from repro.api import ArtifactCache
        from repro.api.artifact_cache import JOURNAL_NAME
        cache = ArtifactCache(tmp_path / "cache")
        j = TicketJournal.beside(cache)
        assert j.path == cache.root / JOURNAL_NAME


# -- per-bucket retry + isolation in the layout pool -----------------------

class TestBucketIsolation:
    def test_killed_bucket_retries_then_succeeds(self):
        # the first layout dispatch (unit 0) dies; the retry is a NEW
        # unit index, so the injection fires exactly once and the
        # bucket completes on attempt 2
        inj = FailureInjector(fail_at={"layout": [0]})
        svc = _fast_svc(injector=inj, max_retries=2)
        ref = DesignSession().run_many(
            [_request(requirements=REQS, layout=True)], strict=False)
        with svc.serve():
            t = svc.submit(_request(requirements=REQS, layout=True))
            art = svc.collect(t, timeout=600)
        assert art.ok and art.error is None
        (ref_art,) = ref.values()
        assert art.summary() == ref_art.summary()
        assert art.provenance.retried_buckets == 1
        assert art.provenance.attempts >= 2
        stats = svc.stats()
        assert stats["bucket_retries"] == 1
        assert stats["bucket_failures"] == 0
        assert inj.fired == [("layout", 0, "node")]

    def test_exhausted_bucket_isolates_only_touching_tickets(self):
        # two coalesced tenants with DISJOINT bucket sets (different
        # array sizes quantize to different grid shapes); every dispatch
        # of tenant A's first bucket dies and the budget is zero — A
        # completes with artifact.error, B finalizes untouched
        inj = FailureInjector(fail_at={"layout": [0]})
        svc = _fast_svc(max_coalesce=2, coalesce_window_s=0.3,
                        injector=inj, max_retries=0)
        ra = _request(array_size=4096, seed=0, requirements=REQS, layout=True)
        rb = _request(array_size=16384, seed=1, requirements=REQS,
                      layout=True)
        ref = DesignSession().run_many([ra, rb], strict=False)
        with svc.serve():
            ta = svc.submit(ra)
            tb = svc.submit(rb)
            aa = svc.collect(ta, timeout=600)
            ab = svc.collect(tb, timeout=600)
        assert not aa.ok
        assert "layout bucket" in aa.error and "failed" in aa.error
        assert aa.pareto.specs        # the distilled front still rides along
        assert aa.layout_rows is None
        assert ab.ok and ab.error is None
        assert ab.summary() == ref[rb].summary()
        stats = svc.stats()
        assert stats["bucket_failures"] == 1
        assert stats["bucket_retries"] == 0
        assert stats["service_batches"] == 1   # one batch, two fates

    def test_batch_stage_failure_yields_error_artifacts(self, monkeypatch):
        # a whole-batch stage (explore) that fails through its retry
        # budget turns into per-ticket error artifacts — the pipeline
        # survives and serves the next batch
        svc = _fast_svc(max_retries=1)
        calls = []

        def boom(*a, **kw):
            calls.append(1)
            raise RuntimeError("injected explore failure")

        real = svc.session.explore_stage
        monkeypatch.setattr(svc.session, "explore_stage", boom)
        with svc.serve():
            t = svc.submit(_request(layout=False))
            art = svc.collect(t, timeout=600)
            assert not art.ok
            assert "explore stage failed after 2 attempt(s)" in art.error
            assert art.provenance.served_from == "error"
            assert len(calls) == 2            # initial + one retry
            # the pipeline is still alive: the next batch serves fine
            monkeypatch.setattr(svc.session, "explore_stage", real)
            t2 = svc.submit(_request(seed=1, layout=False))
            assert svc.collect(t2, timeout=600).ok
        stats = svc.stats()
        assert stats["explore_stage_retries"] == 1
        assert stats["explore_stage_failures"] == 1


# -- supervised stage workers ----------------------------------------------

class TestSupervisedWorkers:
    def test_worker_crash_restarts_in_process_and_unit_survives(self):
        svc = _fast_svc()
        real = svc._process_explore
        crashes = []

        def flaky(batch):
            if not crashes:
                crashes.append(1)
                raise RuntimeError("worker loop crash")
            real(batch)

        svc._process_explore = flaky
        with svc.serve():
            t = svc.submit(_request(layout=False))
            art = svc.collect(t, timeout=600)
        assert art.ok    # the in-hand batch was re-queued, not lost
        assert svc.stats()["stage_worker_restarts"] == 1

    def test_restart_budget_exhaustion_is_terminal_and_restores(self):
        svc = _fast_svc(worker_restarts=1)

        def always(batch):
            raise RuntimeError("hopeless worker")

        svc._process_explore = always
        svc.serve()
        ticket = svc.submit(_request(layout=False))
        with pytest.raises(RuntimeError, match="pump failed"):
            svc.collect(ticket, timeout=600)
        with pytest.raises(RuntimeError, match="restored"):
            svc.close()
        assert svc.stats()["stage_worker_restarts"] == 1
        # the ticket is back in the queue — the synchronous drain path
        # (run_many, untouched by the patch) still serves it
        assert svc.poll(ticket) is None
        assert svc.run()[ticket].ok


# -- preemption: drain, journal, replay ------------------------------------

class TestPreemptionReplay:
    def _drain_pump(self, svc, timeout=600.0):
        deadline = time.monotonic() + timeout
        while svc._pump is not None and svc._pump.is_alive():
            assert time.monotonic() < deadline, "preempted pump never exited"
            time.sleep(0.02)

    def test_preempt_journals_then_fresh_service_replays(self, tmp_path):
        reqs = [_request(seed=sd, layout=False) for sd in range(4)]
        ref = DesignSession().run_many(reqs, strict=False)
        guard = PreemptionGuard()
        svc = _fast_svc(session=DesignSession(artifact_cache=tmp_path),
                        max_coalesce=1, pipeline_depth=1, guard=guard)
        assert svc.journal is not None
        assert svc.journal.path.parent == svc.session.artifact_cache.root
        svc.serve()
        tickets = [svc.submit(r) for r in reqs]
        guard.request()              # simulated SIGTERM
        self._drain_pump(svc)
        svc.close()

        drained, journaled = {}, []
        for t, r in zip(tickets, reqs):
            try:
                art = svc.poll(t)
            except PendingTicket:
                journaled.append((t, r))
                continue
            assert art is not None, "drain finished with an unset ticket"
            drained[r] = art
        stats = svc.stats()
        assert stats["preemptions"] == 1
        assert stats["preempted"]
        assert stats["journaled_tickets"] == len(journaled) > 0
        assert [r.seed for r in svc.journal.replay()] \
            == [r.seed for _, r in journaled]   # admission order preserved
        with pytest.raises(RuntimeError, match="preempted"):
            svc.submit(_request(seed=99, layout=False))

        # a fresh service over the same cache root replays the journal
        svc2 = _fast_svc(session=DesignSession(artifact_cache=tmp_path),
                         max_coalesce=1)
        svc2.serve()
        replayed = svc2.stats()["replayed_tickets"]
        assert replayed == len(journaled)
        assert len(svc2.journal) == 0    # cleared once resubmitted
        arts2 = [svc2.collect(t, timeout=600)
                 for t in range(replayed)]
        svc2.close()
        for (orig_t, r), art in zip(journaled, arts2):
            assert art.provenance.served_from == "journal_replay"
            assert art.summary() == ref[r].summary()
        # drained tickets match the uninterrupted reference too
        for r, art in drained.items():
            assert art.summary() == ref[r].summary()

    def test_injector_preempt_kind_drives_the_same_path(self, tmp_path):
        # kind="preempt" on the admit schedule: the SECOND admitted
        # batch requests preemption mid-run — no real signal involved
        guard = PreemptionGuard()
        inj = FailureInjector(fail_at={"admit": [(1, "preempt")]},
                              guard=guard)
        svc = _fast_svc(max_coalesce=1, guard=guard, injector=inj,
                        journal=tmp_path / "journal.jsonl")
        svc.serve()
        tickets = [svc.submit(_request(seed=sd, layout=False))
                   for sd in range(3)]
        self._drain_pump(svc)
        svc.close()
        assert guard.preempted
        assert ("admit", 1, "preempt") in inj.fired
        resolved, unresolved = [], []
        for t in tickets:
            try:
                (resolved if svc.poll(t) is not None
                 else unresolved).append(t)
            except PendingTicket:
                unresolved.append(t)
        # the WAL covers everything unfinished at drain time — every
        # unresolved ticket for sure, plus in-flight tickets that then
        # drained locally (if the drain had died, replay still recovers
        # them; the artifact cache de-duplicates on replay)
        journaled_shas = {r.sha() for r in svc.journal.replay()}
        by_ticket = dict(zip(tickets, range(3)))
        for t in unresolved:
            assert _request(seed=by_ticket[t],
                            layout=False).sha() in journaled_shas
        assert len(unresolved) >= 1
        assert resolved   # the first admitted batch drained to an artifact

    def test_serve_refused_with_already_preempted_guard(self):
        guard = PreemptionGuard()
        guard.request()
        svc = _fast_svc(guard=guard)
        with pytest.raises(RuntimeError, match="fresh guard"):
            svc.serve()

    def test_explicit_replay_journal_for_sync_drains(self, tmp_path):
        j = TicketJournal(tmp_path / "journal.jsonl")
        reqs = [_request(seed=sd, layout=False) for sd in (5, 6)]
        j.write(reqs)
        svc = _fast_svc(journal=j)
        tickets = svc.replay_journal()
        assert len(tickets) == 2 and len(j) == 0
        done = svc.run()
        for t, r in zip(tickets, reqs):
            assert done[t].request == r
            assert done[t].provenance.served_from == "journal_replay"


# -- straggler shedding in the layout pool ---------------------------------

class TestStragglerShed:
    def test_stuck_bucket_shed_to_peer_first_completion_wins(self):
        # the first layout dispatch is held by a slow fault far past
        # threshold x EMA; the watchdog re-queues it, the peer worker
        # completes it, and the stuck incarnation is cancelled-on-observe
        mon = StragglerMonitor(threshold=2.0, ema=3.0)   # stuck past 6s
        inj = FailureInjector(slow_seconds=20.0,
                              fail_at={"layout": [(0, "slow")]})
        svc = _fast_svc(layout_workers=2, straggler=mon, injector=inj)
        with svc.serve():
            t = svc.submit(_request(requirements=REQS, layout=True))
            art = svc.collect(t, timeout=600)
            stats_live = svc.stats()
        assert art.ok and art.error is None
        assert art.provenance.shed_buckets >= 1
        assert stats_live["shed_buckets"] >= 1
        assert any(ev[0] == "shed" for ev in mon.events)
        # the ticket completed long before the 20s fault released: the
        # shed actually rescued it rather than waiting the fault out
        stats = svc.stats()   # post-close: the loser was observed
        assert stats["shed_losses"] + stats["bucket_cancellations"] >= 1

    def test_single_worker_pool_never_sheds(self):
        # shedding requires a peer; K=1 must not re-queue to itself
        mon = StragglerMonitor(threshold=2.0, ema=0.001)
        svc = _fast_svc(layout_workers=1, straggler=mon)
        with svc.serve():
            t = svc.submit(_request(requirements=REQS, layout=True))
            art = svc.collect(t, timeout=600)
        assert art.ok
        assert svc.stats()["shed_buckets"] == 0
        assert not any(ev[0] == "shed" for ev in mon.events)


# -- layout pool: equality + knobs -----------------------------------------

class TestLayoutPool:
    def test_pool_artifacts_equal_sequential(self):
        reqs = [_request(array_size=4096, seed=0, requirements=REQS,
                         layout=True),
                _request(array_size=16384, seed=1, requirements=REQS,
                         layout=True)]
        ref = DesignSession().run_many(reqs, strict=False)
        svc = _fast_svc(max_coalesce=2, coalesce_window_s=0.3,
                        layout_workers=4)
        with svc.serve():
            tickets = [svc.submit(r) for r in reqs]
            arts = [svc.collect(t, timeout=600) for t in tickets]
        for r, a in zip(reqs, arts):
            assert a.summary() == ref[r].summary()
            assert a.ok
            assert a.provenance.worker_id.startswith("layout-")
        assert svc.stats()["layout_workers"] == 4

    def test_knob_validation(self):
        with pytest.raises(ValueError, match="layout_workers"):
            DesignService(layout_workers=0)
        with pytest.raises(ValueError, match="max_retries"):
            DesignService(max_retries=-1)
