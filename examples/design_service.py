"""Multi-tenant design service demo: concurrent users, one dispatch.

Several tenants submit different `DesignRequest`s — different array
sizes, seeds, and application requirements — against a *running*
`DesignService` staged pipeline (`serve()`): submissions landing
inside the coalescing window are folded into one compiled MOGA sweep
dispatch, the union of surviving specs is laid out in streamed
routing-grid-shape buckets (each bucket dispatches as soon as the
distill stage forms it, overlapped with any following batch's
exploration), and each tenant blocks in `collect(timeout=...)` until
its ticketed artifact lands.  The closing stats line shows the
per-stage busy clocks and the explore∥layout overlap gauge.

A persistent artifact cache backs the session, so re-running this
script (same `--cache-dir`) serves every tenant from disk with zero
explorer dispatches — the provenance line flips to `artifact_cache`.

With `--telemetry-dir DIR` the service runs instrumented
(`docs/observability.md`): it dumps the per-batch stage Gantt as
Chrome-trace JSON plus a metrics snapshot, both inspectable with
`tools/repro_ctl.py` (`gantt DIR/service_trace.json --ascii`,
`metrics DIR/service_metrics.json`).

  PYTHONPATH=src python examples/design_service.py [--cache-dir DIR]
                                                   [--telemetry-dir DIR]
"""
import argparse
import pathlib

from repro.api import DesignRequest, DesignSession, Requirements
from repro.serve.design_service import DesignService
from repro.telemetry import Telemetry, write_metrics_json

TENANTS = {
    "edge-snr": DesignRequest(
        array_size=4096, pop_size=96, generations=30,
        requirements=Requirements(min_snr_db=20.0)),
    "edge-tops": DesignRequest(
        array_size=4096, pop_size=96, generations=30, seed=1,
        requirements=Requirements(min_tops=0.5, min_snr_db=15.0)),
    # screening query: Pareto front only, no layouts
    "cloud-eff": DesignRequest(
        array_size=16384, pop_size=96, generations=30,
        requirements=Requirements(min_tops_per_w=100.0), layout=False),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-dir", default=None,
                    help="persistent artifact-cache directory; re-run with "
                         "the same dir to be served from disk")
    ap.add_argument("--telemetry-dir", default=None,
                    help="dump the stage-span trace and metrics snapshot "
                         "here (see docs/observability.md)")
    args = ap.parse_args()

    session = DesignSession(artifact_cache=args.cache_dir)
    telemetry = Telemetry() if args.telemetry_dir else None
    with DesignService(session, coalesce_window_s=0.25,
                       telemetry=telemetry).serve() as svc:
        tickets = {name: svc.submit(req) for name, req in TENANTS.items()}
        arts = {name: svc.collect(t, timeout=600)
                for name, t in tickets.items()}

    for name, art in arts.items():
        p = art.provenance
        if not art.ok or not len(art.pareto):
            why = art.error or "requirements removed every point"
            print(f"{name:10s} ticket={tickets[name]} | no surviving "
                  f"solution ({why})")
            continue
        best = art.pareto.best("tops_per_w")
        laid = ("front only" if art.layout_rows is None
                else f"{p.layout_dispatches} layout bucket(s)")
        print(f"{name:10s} ticket={tickets[name]} | {len(art.pareto)} "
              f"survivors, best H={best.h} W={best.w} L={best.l} "
              f"B={best.b_adc} | served from {p.served_from}, coalesced "
              f"with {p.coalesced - 1} other request(s), {laid}")
    s = svc.stats()   # point-in-time snapshot: counters + pipeline gauges
    factor = (s["service_batch_requests"] / s["service_batches"]
              if s["service_batches"] else 0.0)
    print(f"\nservice: {s['requests_served']} requests -> "
          f"{s['service_batches']} batch(es) (coalescing factor "
          f"{factor:.1f}), {s['explorer_dispatches']} explorer "
          f"dispatch(es), {s['run_cell_traces']} sweep-program trace(s), "
          f"{s['layout_dispatches']} layout bucket dispatch(es), "
          f"{s['artifact_cache_hits']} artifact-cache hit(s)")
    busy = s["stage_busy_s"]
    print(f"pipeline: explore {busy['explore']:.3f}s / distill "
          f"{busy['distill']:.3f}s / layout {busy['layout']:.3f}s / "
          f"finalize {busy['finalize']:.3f}s busy, explore∥layout overlap "
          f"{s['pipeline_overlap_s']:.3f}s "
          f"(fraction {s['pipeline_overlap_fraction']:.2f})")

    if args.telemetry_dir:
        out = pathlib.Path(args.telemetry_dir)
        out.mkdir(parents=True, exist_ok=True)
        svc.trace().to_json(out / "service_trace.json")
        write_metrics_json(svc.metrics(), out / "service_metrics.json")
        print(f"telemetry: stage Gantt + metrics snapshot -> {out} "
              f"(inspect with tools/repro_ctl.py)")


if __name__ == "__main__":
    main()
