"""Render and persist telemetry snapshots: prometheus text + atomic JSON.

The scrape surface of the telemetry subsystem is file/string-shaped on
purpose: the service stays a library (no HTTP dependency baked in),
and anything that can serve a string — a debug handler, a cron job
writing a node-exporter textfile, `tools/repro_ctl.py` — becomes a
metrics endpoint.  Two formats from one `MetricsRegistry.snapshot()`:

  * `render_prometheus(snapshot)` — text exposition format
    (`# HELP`/`# TYPE` headers, `_bucket{le=...}` cumulative histogram
    series with the canonical `+Inf` bound, `_sum`/`_count`);
  * `atomic_write_json(payload, path)` — temp-file + `os.replace`, the
    same durability contract as `DesignArtifact.to_json` (readers only
    ever see a complete file), shared by metrics snapshots and
    `TraceExport` dumps.

`load_snapshot(path)` is the read side for the CLI: it validates the
`schema` stamp against `METRICS_SCHEMA` so an operator inspecting a
stale dump gets a clear error instead of nonsense columns.
"""
from __future__ import annotations

import json
import os
import re
import tempfile

from repro.telemetry.metrics import METRICS_SCHEMA

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_VAL_ESCAPES = {"\\": r"\\", "\n": r"\n", '"': r'\"'}


def _name(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    return name if not name[:1].isdigit() else "_" + name


def _labels(labels: dict, extra: dict | None = None) -> str:
    merged = {**labels, **(extra or {})}
    if not merged:
        return ""
    parts = []
    for k, v in sorted(merged.items()):
        v = "".join(_LABEL_VAL_ESCAPES.get(ch, ch) for ch in str(v))
        parts.append(f'{_name(str(k))}="{v}"')
    return "{" + ",".join(parts) + "}"


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def render_prometheus(snapshot: dict) -> str:
    """Prometheus text exposition of a `MetricsRegistry.snapshot()`."""
    schema = snapshot.get("schema")
    if schema != METRICS_SCHEMA:
        raise ValueError(f"metrics schema {schema} != supported "
                         f"{METRICS_SCHEMA}; re-snapshot the registry")
    lines = []
    for name, series in snapshot["metrics"].items():
        pname = _name(name)
        kind = series[0]["type"]
        help_ = next((s["help"] for s in series if s.get("help")), "")
        if help_:
            lines.append(f"# HELP {pname} {help_}")
        lines.append(f"# TYPE {pname} {kind}")
        for s in series:
            labels = s.get("labels", {})
            if kind in ("counter", "gauge"):
                lines.append(f"{pname}{_labels(labels)} {_fmt(s['value'])}")
                continue
            acc = 0
            for le, count in s["buckets"]:
                acc += count
                lines.append(f"{pname}_bucket"
                             f"{_labels(labels, {'le': _fmt(le)})} {acc}")
            acc += s.get("inf_count", 0)
            lines.append(f"{pname}_bucket"
                         f"{_labels(labels, {'le': '+Inf'})} {acc}")
            lines.append(f"{pname}_sum{_labels(labels)} {_fmt(s['sum'])}")
            lines.append(f"{pname}_count{_labels(labels)} {s['count']}")
    return "\n".join(lines) + "\n"


def atomic_write_json(payload: dict, path) -> None:
    """Temp-file + `os.replace` JSON write in the target's directory, so
    a crash mid-dump can never leave a truncated snapshot behind."""
    path = os.fspath(path)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def write_metrics_json(snapshot: dict, path) -> None:
    """Persist a metrics snapshot (schema-checked on the way out, so a
    bad dump fails at write time, not at the operator's read)."""
    if snapshot.get("schema") != METRICS_SCHEMA:
        raise ValueError("refusing to write a snapshot without the "
                         "current METRICS_SCHEMA stamp")
    atomic_write_json(snapshot, path)


def load_snapshot(path) -> dict:
    """Read + schema-validate a metrics snapshot dumped by
    `write_metrics_json` (the CLI's inspect path)."""
    with open(path) as f:
        d = json.load(f)
    schema = d.get("schema") if isinstance(d, dict) else None
    if schema != METRICS_SCHEMA:
        raise ValueError(f"metrics snapshot at {path} has schema "
                         f"{schema}, supported {METRICS_SCHEMA}")
    return d
