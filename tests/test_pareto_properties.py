"""Hypothesis property tests for the Pareto utilities.

Collected only where hypothesis is installed (`pytest.importorskip`);
deterministic Pareto/NSGA-II coverage lives in `test_pareto_nsga2.py`."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pareto  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def objective_sets(draw):
    p = draw(st.integers(3, 24))
    m = draw(st.integers(2, 4))
    rows = draw(st.lists(
        st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                 min_size=m, max_size=m), min_size=p, max_size=p))
    return np.array(rows, np.float32)


class TestDominanceProperties:
    @given(objective_sets())
    def test_irreflexive(self, f):
        d = np.asarray(pareto.dominance_matrix(jnp.asarray(f)))
        assert not d.diagonal().any()

    @given(objective_sets())
    def test_antisymmetric(self, f):
        d = np.asarray(pareto.dominance_matrix(jnp.asarray(f)))
        assert not (d & d.T).any()

    @given(objective_sets())
    def test_transitive(self, f):
        d = np.asarray(pareto.dominance_matrix(jnp.asarray(f)))
        viol = (d.astype(int) @ d.astype(int) > 0) & ~d
        # i dom j, j dom k => i dom k  (true for Pareto dominance)
        assert not viol.any()

    @given(objective_sets())
    def test_rank_zero_iff_nondominated(self, f):
        fj = jnp.asarray(f)
        ranks = np.asarray(pareto.non_dominated_rank(fj))
        nd = np.asarray(pareto.non_dominated_mask(fj))
        assert ((ranks == 0) == nd).all()

    @given(objective_sets())
    def test_rank_matches_bruteforce_peeling(self, f):
        fj = jnp.asarray(f)
        ranks = np.asarray(pareto.non_dominated_rank(fj))
        # brute force peeling
        remaining = list(range(len(f)))
        expect = np.zeros(len(f), int)
        level = 0
        while remaining:
            sub = f[remaining]
            d = np.asarray(pareto.dominance_matrix(jnp.asarray(sub)))
            front = [remaining[i] for i in range(len(remaining))
                     if not d[:, i].any()]
            for i in front:
                expect[i] = level
                remaining.remove(i)
            level += 1
        assert (ranks == expect).all()
