"""Shared infrastructure for the house-rules static-analysis passes.

The `repro.analysis` package enforces, by machine, the three invariant
families the codebase previously held by convention (see
`docs/static_analysis.md` for the rule catalog):

  * **trace purity** (`repro.analysis.trace_purity`) — no host-side
    effects reachable from `jax.jit` / `vmap` / `lax.scan` /
    `pallas_call` regions;
  * **lock discipline** (`repro.analysis.lock_discipline`) — instance
    attributes written from more than one thread root must be accessed
    under a lock, and lock acquisition orders must not invert;
  * **schema drift** (`repro.analysis.schema_drift`) — serialized field
    sets must match the committed per-version manifest, so provenance
    changes cannot ship without a schema bump.

This module holds what every pass shares: the `Finding` record, module
loading (path -> parsed AST with stable dotted names), and the
suppression-comment machinery.

Suppression syntax (one line, trailing or the line directly above the
flagged statement)::

    x[i] = v   # lint: disable=inplace-store -- trace-time probe, host dict

    # lint: disable-file=unguarded-attr -- single-threaded test helper

A suppression MUST carry a ``-- reason`` tail: `--strict` turns both a
reasonless disable and an *unused* disable into findings of their own,
so the suppression inventory stays justified and live.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

# Every rule id a pass can emit.  The CLI validates suppressions against
# this set so a typo'd disable comment fails loudly instead of silently
# suppressing nothing.
RULES = {
    "host-call": "host-side call reachable from traced code",
    "inplace-store": "in-place subscript store reachable from traced code",
    "set-iteration": "iteration over an unordered set in traced code",
    "host-guard": "kernels/*/ops.py host impl dispatched without a "
                  "trace-check guard",
    "unguarded-attr": "attribute written from >1 thread root accessed "
                      "outside its lock",
    "lock-order": "lock-order inversion (cycle in the acquisition graph)",
    "lock-reacquire": "non-reentrant lock (or an alias) re-acquired while "
                      "already held",
    "schema-drift": "serialized fields changed without a schema bump",
    "manifest-stale": "schema version bumped but the committed manifest "
                      "was not regenerated",
    "bad-suppression": "malformed, reasonless, or unused lint suppression",
}

_DISABLE_RE = re.compile(
    r"#\s*lint:\s*(disable|disable-file)\s*=\s*"
    r"(?P<rules>[\w,\s-]+?)\s*(?:--\s*(?P<reason>.*\S))?\s*$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, printable as ``path:line: [rule] message``."""

    rule: str
    path: str          # repo-relative, '/'-separated
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    path: str
    line: int          # 0 for file-level
    rule: str
    reason: str | None
    file_level: bool


@dataclasses.dataclass
class Module:
    """One parsed source file: dotted name, AST, and raw lines."""

    name: str          # dotted ("repro.serve.design_service")
    path: pathlib.Path
    rel: str           # repo-relative, '/'-separated
    tree: ast.Module
    lines: list[str]

    @property
    def suppressions(self) -> list[Suppression]:
        out = []
        for i, text in self._comments():
            m = _DISABLE_RE.search(text)
            if m is None:
                continue
            file_level = m.group(1) == "disable-file"
            for rule in re.split(r"[,\s]+", m.group("rules")):
                if rule:
                    out.append(Suppression(
                        path=self.rel, line=0 if file_level else i,
                        rule=rule, reason=m.group("reason"),
                        file_level=file_level))
        return out

    def _comments(self) -> list[tuple[int, str]]:
        """(line, text) of real COMMENT tokens — a docstring that merely
        *shows* the disable syntax is not a suppression."""
        import io
        import tokenize

        out: list[tuple[int, str]] = []
        try:
            for tok in tokenize.generate_tokens(
                    io.StringIO("\n".join(self.lines) + "\n").readline):
                if tok.type == tokenize.COMMENT:
                    out.append((tok.start[0], tok.string))
        except (tokenize.TokenizeError, IndentationError, SyntaxError):
            return [(i, t) for i, t in enumerate(self.lines, 1)
                    if "#" in t]
        return out


def parse_file(path: pathlib.Path, *, root: pathlib.Path,
               name: str | None = None) -> Module:
    text = path.read_text()
    rel = path.relative_to(root).as_posix()
    if name is None:
        parts = list(path.relative_to(root).with_suffix("").parts)
        if parts and parts[0] == "src":
            parts = parts[1:]
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        name = ".".join(parts)
    return Module(name=name, path=path, rel=rel,
                  tree=ast.parse(text, filename=str(path)),
                  lines=text.splitlines())


def load_tree(root: pathlib.Path,
              subdirs: tuple[str, ...] = ("src/repro",),
              ) -> dict[str, Module]:
    """Parse every ``*.py`` under ``root/<subdir>`` into a name-keyed
    module map (the unit all passes operate on)."""
    modules: dict[str, Module] = {}
    for sub in subdirs:
        base = root / sub
        for path in sorted(base.rglob("*.py")):
            if "__pycache__" in path.parts:
                continue
            mod = parse_file(path, root=root)
            modules[mod.name] = mod
    return modules


def apply_suppressions(findings: list[Finding],
                       modules: dict[str, Module], *,
                       strict: bool = False
                       ) -> tuple[list[Finding], list[Finding]]:
    """Split findings into (kept, suppressed).

    A finding is suppressed by a file-level disable of its rule, or a
    line-level disable on the finding's line or the line directly above
    it.  Under ``strict``, a suppression with no ``-- reason`` tail, an
    unknown rule id, or one that suppressed nothing becomes a
    `bad-suppression` finding in the kept list.
    """
    by_path: dict[str, list[Suppression]] = {}
    for mod in modules.values():
        by_path.setdefault(mod.rel, []).extend(mod.suppressions)

    kept: list[Finding] = []
    suppressed: list[Finding] = []
    used: set[tuple[str, int, str]] = set()
    for f in findings:
        hit = None
        for s in by_path.get(f.path, ()):
            if s.rule != f.rule:
                continue
            if s.file_level or s.line in (f.line, f.line - 1):
                hit = s
                break
        if hit is None:
            kept.append(f)
        else:
            used.add((hit.path, hit.line, hit.rule))
            suppressed.append(f)

    if strict:
        for sups in by_path.values():
            for s in sups:
                if s.rule not in RULES:
                    kept.append(Finding(
                        "bad-suppression", s.path, s.line or 1,
                        f"unknown rule {s.rule!r} in disable comment"))
                elif not s.reason:
                    kept.append(Finding(
                        "bad-suppression", s.path, s.line or 1,
                        f"suppression of {s.rule!r} has no '-- reason' "
                        f"tail; justify it inline"))
                elif (s.path, s.line, s.rule) not in used:
                    kept.append(Finding(
                        "bad-suppression", s.path, s.line or 1,
                        f"suppression of {s.rule!r} matched no finding; "
                        f"remove it"))
    return kept, suppressed


# -- small AST helpers shared by the passes -----------------------------
def dotted(node: ast.expr) -> str | None:
    """``a.b.c`` attribute/name chain as a string, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_map(tree: ast.Module) -> dict[str, str]:
    """Top-level import bindings: local alias -> dotted origin.

    ``import a.b as c``      -> {"c": "a.b"}
    ``import a.b``           -> {"a": "a"}   (binding is the root name)
    ``from a.b import c``    -> {"c": "a.b.c"}
    ``from .x import y``     -> {"y": ".x.y"}  (leading dots preserved)
    """
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    out[a.asname] = a.name
                else:
                    out[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom):
            base = "." * node.level + (node.module or "")
            for a in node.names:
                out[a.asname or a.name] = (f"{base}.{a.name}"
                                           if base else a.name)
    return out
