"""Multi-tenant design service: deadline-coalescing, thread-pumped front door.

The design-flow counterpart of `repro.serve.engine.ServeEngine`'s slot
model: concurrent users `submit()` `DesignRequest`s and collect
ticketed `DesignArtifact`s, while the service amortizes the heavy work
across tenants.  Two driving modes share one queue:

  * **synchronous drain** — `step()` takes one coalesced batch (up to
    `max_coalesce` requests), `run()` drains everything.  This is the
    PR-3 shape and stays the right tool for scripted batch jobs
    (`explore_sizes`, the benchmarks' cold/warm sweeps).
  * **async serve loop** — `serve()` starts a pump thread with
    latency-bounded coalescing windows, in the style of `ServeEngine`'s
    slot refill: a batch dispatches when either `max_coalesce` requests
    have queued or `coalesce_window_s` has elapsed since the *oldest*
    queued request (admit-until-deadline).  `submit()`/`poll()`/
    `collect(timeout=...)` are thread-safe; `close()` (or leaving the
    `with` block) drains the queue gracefully and joins the pump.

Each dispatched batch goes to `DesignSession.run_many`, which

  * coalesces every request in the same explore group (equal MOGA
    budget / calibration / backend knobs) into ONE `explore_cells`
    dispatch — concurrent tenants share the compiled sweep program and
    a single padded population stack instead of dispatching per user;
  * buckets the union of surviving specs by routing-grid shape before
    `generate_layouts`, so a mixed tenant population does not pay
    padded-batch waste for the biggest member;
  * consults / fills the session's persistent artifact cache when one
    is configured (`repro.api.artifact_cache.ArtifactCache`), so a
    fleet of service processes shares exploration results;
  * demuxes per-request artifacts whose content is equal to what the
    sequential legacy path produces for each request alone — asserted
    in `tests/test_design_api.py` and `tests/test_design_service_async.py`.

Failure semantics: a request whose requirements remove every Pareto
point completes with `artifact.error` set (non-strict mode) and cannot
poison its batch.  An *unexpected* exception inside a dispatch restores
the whole batch to the FRONT of the queue — no ticket is lost or
reordered — and, on the pump path, is re-raised from `close()` (and
surfaced to blocked `collect()` callers).

Dispatch accounting lives in `service.stats` (a view of the session's
counter): `explorer_dispatches`, `layout_dispatches`,
`run_cell_traces`, cache hit/miss counts, plus the service-level
`service_batches` / `service_batch_requests` pair whose ratio is the
realized coalescing factor.
"""
from __future__ import annotations

import collections
import threading
import time

from repro.api.request import DesignRequest
from repro.api.session import DesignArtifact, DesignSession


class UnknownTicket(KeyError):
    """Raised for a ticket this service never issued, or whose artifact
    was already collected (and popped — pass `keep_done=True` to keep)."""

    def __str__(self) -> str:  # KeyError repr-quotes its message otherwise
        return self.args[0] if self.args else ""


class PendingTicket(RuntimeError):
    """Raised when a ticket's artifact is not ready: the request is still
    queued or in flight.  Distinct from `UnknownTicket` so callers can
    tell "wait longer / drain the queue" from "you never submitted this"."""


class DesignService:
    """Queue-backed multi-tenant layer over a `DesignSession`."""

    def __init__(self, session: DesignSession | None = None, *,
                 max_coalesce: int = 16, coalesce_window_s: float = 0.05):
        if max_coalesce <= 0:
            raise ValueError("max_coalesce must be positive")
        if coalesce_window_s < 0:
            raise ValueError("coalesce_window_s must be >= 0")
        self.session = session or DesignSession()
        self.max_coalesce = max_coalesce
        self.coalesce_window_s = coalesce_window_s
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)   # queue grew / closing
        self._done_cv = threading.Condition(self._lock)  # artifacts landed
        # serializes session.run_many: the session's caches/stats are not
        # thread-safe, and the run()/step()-vs-pump guards are advisory
        # (unlocked liveness reads) — this lock is the hard guarantee that
        # only one dispatch drives the session at a time
        self._dispatch = threading.Lock()
        self._queue: list[tuple[int, DesignRequest, float]] = []
        self._pending: set[int] = set()   # issued, not yet in `done`
        self._next_ticket = 0
        self.done: dict[int, DesignArtifact] = {}
        self._pump: threading.Thread | None = None
        self._closing = False
        self._pump_error: BaseException | None = None

    @property
    def stats(self) -> collections.Counter:
        return self.session.stats

    def __len__(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- submission ------------------------------------------------------
    def submit(self, request: DesignRequest) -> int:
        """Enqueue a request; returns the ticket to collect its artifact.

        Thread-safe; wakes the `serve()` pump (if running) so the
        coalescing window starts counting from the oldest queued request."""
        with self._lock:
            if self._closing:
                raise RuntimeError("DesignService is closing; "
                                   "no new submissions accepted")
            if self._pump_error is not None:
                # nothing will serve this ticket: the pump died.  Refuse
                # admission until close() surfaces (and clears) the error.
                raise RuntimeError(
                    "DesignService serve() pump failed; call close() to "
                    "surface the error (its batch was restored to the "
                    "queue), then serve() or run() again"
                ) from self._pump_error
            ticket = self._next_ticket
            self._next_ticket += 1
            self._queue.append((ticket, request, time.monotonic()))
            self._pending.add(ticket)
            self._work.notify_all()
        return ticket

    # -- synchronous drain -----------------------------------------------
    def step(self) -> dict[int, DesignArtifact]:
        """Dispatch one coalesced batch (up to `max_coalesce` requests) and
        return its per-ticket artifacts.

        A request whose requirements remove every Pareto point cannot
        poison the batch: it completes with `artifact.error` set (the
        session's non-strict mode) while the other tenants are served.
        On an unexpected exception the batch is restored — in order, at
        the front of the queue — so no tenant's submission is lost.

        Not valid while a `serve()` pump is running: the underlying
        session is not thread-safe, so only one dispatcher may drive it."""
        if self._pump_alive():
            raise RuntimeError("step() while the serve() pump is active; "
                               "the pump is the only dispatcher — use "
                               "collect()/poll() instead")
        return self._dispatch_once()

    def _dispatch_once(self) -> dict[int, DesignArtifact]:
        with self._lock:
            batch = self._queue[:self.max_coalesce]
            del self._queue[:self.max_coalesce]
        if not batch:
            return {}
        try:
            with self._dispatch:
                artifacts = self.session.run_many([r for _, r, _ in batch],
                                                  bucket_layouts=True,
                                                  strict=False)
        except Exception:
            with self._lock:
                self._queue[:0] = batch
                self._work.notify_all()
            raise
        out = {ticket: artifacts[r] for ticket, r, _ in batch}
        with self._lock:
            self.done.update(out)
            self._pending.difference_update(out)
            self.stats["service_batches"] += 1
            self.stats["service_batch_requests"] += len(out)
            self._done_cv.notify_all()
        return out

    def run(self) -> dict[int, DesignArtifact]:
        """Drain the whole queue synchronously; returns a snapshot of every
        completed (uncollected) ticket.  Not valid while a `serve()` pump
        is running — use `collect()`/`poll()` there."""
        if self._pump_alive():
            raise RuntimeError("run() while the serve() pump is active; "
                               "use collect()/poll() instead")
        while self._dispatch_once():
            pass
        with self._lock:
            return dict(self.done)

    # -- ticket lifecycle ------------------------------------------------
    def _check_known(self, ticket: int) -> None:
        # lock held
        if not 0 <= ticket < self._next_ticket:
            raise UnknownTicket(f"ticket {ticket} was never issued by this "
                                f"service (tickets 0..{self._next_ticket - 1})")
        if ticket not in self._pending and ticket not in self.done:
            raise UnknownTicket(f"ticket {ticket} was already collected "
                                f"(use collect(..., keep_done=True) to keep "
                                f"artifacts around)")

    def poll(self, ticket: int) -> DesignArtifact | None:
        """Non-blocking, non-destructive readiness probe: the artifact if
        ready, `None` while the ticket is still queued / in flight.
        Raises `UnknownTicket` for a ticket this service never issued, and
        (like `collect`) surfaces a dead pump as `RuntimeError` — a
        poll-only consumer must not spin forever on a ticket that nothing
        is going to serve."""
        with self._lock:
            self._check_known(ticket)
            art = self.done.get(ticket)
            if art is None and self._pump_error is not None:
                raise RuntimeError(
                    f"ticket {ticket} cannot complete: the serve() pump "
                    f"failed (its batch was restored to the queue; drain "
                    f"with run()/step() or serve() again)"
                ) from self._pump_error
            return art

    def collect(self, ticket: int, *, timeout: float | None = None,
                keep_done: bool = False) -> DesignArtifact:
        """Return (and pop) the ticket's artifact.

        With a `serve()` pump running — or a `timeout` given — blocks
        until the artifact lands, the timeout expires (`PendingTicket`),
        or the pump fails (`RuntimeError` chaining the pump's exception;
        the batch was restored to the queue).  Without a pump and without
        a timeout, a still-pending ticket raises `PendingTicket`
        immediately instead of deadlocking — drain with `run()`/`step()`.

        Popping on collect keeps `done` bounded in a long-lived service;
        pass `keep_done=True` to leave the artifact collectable again."""
        deadline = (None if timeout is None
                    else time.monotonic() + max(timeout, 0.0))
        with self._lock:
            while True:
                self._check_known(ticket)
                art = self.done.get(ticket)
                if art is not None:
                    if not keep_done:
                        del self.done[ticket]
                    return art
                if self._pump_error is not None:
                    raise RuntimeError(
                        f"ticket {ticket} cannot complete: the serve() pump "
                        f"failed (its batch was restored to the queue; drain "
                        f"with run()/step() or serve() again)"
                    ) from self._pump_error
                if deadline is None and not self._pump_alive():
                    raise PendingTicket(
                        f"ticket {ticket} is still pending and no serve() "
                        f"pump is running; drain the queue with run()/step() "
                        f"or pass collect(..., timeout=...) under serve()")
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise PendingTicket(f"ticket {ticket} still pending "
                                        f"after {timeout:g}s")
                # bounded wait so a pump that dies without notifying
                # (or a run()-mode caller) cannot strand us
                self._done_cv.wait(timeout=0.1 if remaining is None
                                   else min(remaining, 0.1))

    # -- async serve loop ------------------------------------------------
    def _pump_alive(self) -> bool:
        pump = self._pump
        return pump is not None and pump.is_alive()

    def serve(self) -> "DesignService":
        """Start the coalescing pump thread (idempotent); returns `self`
        so `with DesignService(...).serve() as svc:` reads naturally."""
        with self._lock:
            if self._pump_alive():
                return self
            if self._closing:
                # a concurrent close() is joining the old pump; starting a
                # second one here would orphan that drain (and race two
                # dispatchers on the non-thread-safe session)
                raise RuntimeError("serve() while close() is in progress; "
                                   "wait for close() to return")
            self._pump_error = None
            self._pump = threading.Thread(target=self._pump_loop,
                                          name="design-service-pump",
                                          daemon=True)
            self._pump.start()
        return self

    def _pump_loop(self) -> None:
        try:
            while True:
                with self._lock:
                    while True:
                        if self._closing:
                            if not self._queue:
                                return          # graceful: queue drained
                            break               # final drain dispatches
                        n = len(self._queue)
                        if n >= self.max_coalesce:
                            break               # batch is full
                        if n:
                            oldest = self._queue[0][2]
                            wait = (self.coalesce_window_s
                                    - (time.monotonic() - oldest))
                            if wait <= 0:
                                break           # deadline of oldest request
                            self._work.wait(timeout=wait)
                        else:
                            self._work.wait()
                self._dispatch_once()
        except Exception as e:   # step() already restored the batch
            with self._lock:
                self._pump_error = e
                self._done_cv.notify_all()

    def close(self) -> None:
        """Graceful shutdown: stop admitting, let the pump drain the queue,
        join it.  Idempotent; a no-op if `serve()` was never called.  If
        the pump failed, the failing batch was restored to the queue
        (tickets intact, in order) and the pump's exception is re-raised
        here."""
        with self._lock:
            pump = self._pump
            if pump is not None:
                self._closing = True
            self._work.notify_all()
        if pump is not None:
            # keep self._pump set while joining: a concurrent collect()
            # must still see a live pump (no spurious PendingTicket during
            # the final drain), and a concurrent serve() must not start a
            # second dispatcher (it sees _closing and refuses)
            pump.join()
        with self._lock:
            if self._pump is pump:
                self._pump = None
            self._closing = False
            err, self._pump_error = self._pump_error, None
        if err is not None:
            raise RuntimeError(
                "serve() pump failed; queued tickets were restored — "
                "drain with run()/step() or serve() again") from err

    def __enter__(self) -> "DesignService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
