"""xLSTM mixers: mLSTM (matrix memory, exponential gating) and sLSTM
(scalar memory, recurrent gate mixing), per arXiv:2405.04517.

Training forward for the mLSTM uses the exact stabilized recurrence under
`lax.scan` over time (baseline); `mlstm_fwd_chunked` is the chunkwise
parallel form used as a perf iteration for the long-context cells — both are
cross-checked by tests.  The sLSTM is inherently sequential (nonlinear
recurrent mixing) and always scans; its per-step work is tiny.

Blocks follow the paper's pre-LN residual structure with up/down projection
(proj_factor) and a causal conv on the mLSTM q/k path.  d_ff = 0 in the
assigned config: there is no separate FFN — the projections inside the
blocks play that role.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, XLSTMConfig
from repro.models.common import dense_init

Array = jax.Array


def _dims(cfg: ArchConfig):
    x: XLSTMConfig = cfg.xlstm
    inner = int(x.proj_factor * cfg.d_model)
    nh = cfg.n_heads
    assert inner % nh == 0
    return inner, nh, inner // nh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key: Array, cfg: ArchConfig) -> dict:
    x: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    inner, nh, dh = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], (d, inner)),
        "gate": dense_init(ks[1], (d, inner)),
        "conv_w": (0.1 * jax.random.normal(ks[2], (x.conv_width, inner))).astype(jnp.float32),
        "conv_b": jnp.zeros((inner,), jnp.float32),
        "wq": dense_init(ks[3], (inner, inner)),
        "wk": dense_init(ks[4], (inner, inner)),
        "wv": dense_init(ks[5], (inner, inner)),
        "w_if": dense_init(ks[6], (inner, 2 * nh)),
        "b_if": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]).astype(jnp.float32),
        "down": dense_init(ks[7], (inner, d)),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(k)) + b


def _mlstm_qkvif(p: dict, x: Array, cfg: ArchConfig):
    b, s, _ = x.shape
    inner, nh, dh = _dims(cfg)
    up = x @ p["up"].astype(x.dtype)
    gate = jax.nn.silu(x @ p["gate"].astype(x.dtype))
    conv = jax.nn.silu(_causal_conv(up, p["conv_w"].astype(x.dtype),
                                    p["conv_b"].astype(x.dtype)))
    q = (conv @ p["wq"].astype(x.dtype)).reshape(b, s, nh, dh)
    k = (conv @ p["wk"].astype(x.dtype)).reshape(b, s, nh, dh) / np.sqrt(dh)
    v = (up @ p["wv"].astype(x.dtype)).reshape(b, s, nh, dh)
    if_ = conv @ p["w_if"].astype(x.dtype) + p["b_if"].astype(x.dtype)
    log_i = if_[..., :nh].astype(jnp.float32)                  # log input gate
    log_f = jax.nn.log_sigmoid(if_[..., nh:].astype(jnp.float32))
    return q, k, v, log_i, log_f, gate


def mlstm_fwd(p: dict, x: Array, cfg: ArchConfig) -> Array:
    """Exact stabilized recurrence over time (scan baseline)."""
    b, s, _ = x.shape
    inner, nh, dh = _dims(cfg)
    q, k, v, log_i, log_f, gate = _mlstm_qkvif(p, x, cfg)

    def step(carry, inp):
        c, n, m = carry                          # (B,H,dh,dh),(B,H,dh),(B,H)
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)[..., None]
        ip = jnp.exp(li - m_new)[..., None]
        c = c * fp[..., None] + ip[..., None] * (vt[..., :, None] * kt[..., None, :])
        n = n * fp + ip * kt
        h_num = jnp.einsum("bhvk,bhk->bhv", c, qt)
        h_den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)
        h = h_num / h_den[..., None]
        return (c, n, m_new), h

    f32 = jnp.float32
    seq_inputs = (q.swapaxes(0, 1).astype(f32), k.swapaxes(0, 1).astype(f32),
                  v.swapaxes(0, 1).astype(f32), log_i.swapaxes(0, 1),
                  log_f.swapaxes(0, 1))
    carry0 = (jnp.zeros((b, nh, dh, dh), f32), jnp.zeros((b, nh, dh), f32),
              jnp.full((b, nh), -jnp.inf, f32))
    _, hs = jax.lax.scan(step, carry0, seq_inputs)
    h = hs.swapaxes(0, 1).reshape(b, s, inner).astype(x.dtype)
    return (h * gate) @ p["down"].astype(x.dtype)


def mlstm_fwd_chunked(p: dict, x: Array, cfg: ArchConfig) -> Array:
    """Chunkwise-parallel mLSTM (linear-attention form within chunks).

    Math (per head; chunk-relative log weights): with cum_f[t] = sum_{u<=t}
    log f_u and input weight at insertion li[u],
        num[t] = sum_{u<=t} (q_t.k_u) e^{cum_f[t]-cum_f[u]+li[u]} v_u
                 + q_t . C_in e^{cum_f[t]}
        den[t] = same with v -> 1 (via n)
        h[t]   = num[t] / max(|den[t]|, e^{m_abs[t]})
    where (C_in, n_in) are the unscaled carry states at the chunk start and
    m_abs[t] = max(max_{u<=t} logweight, m_in + cum_f[t]) is the running max
    log-weight — giving *exact* equivalence with the stabilized scan form
    `mlstm_fwd` (tests check this).  Chunk-local work is MXU matmuls; the
    scan runs over S/chunk boundaries only.
    """
    b, s, _ = x.shape
    inner, nh, dh = _dims(cfg)
    xcfg: XLSTMConfig = cfg.xlstm
    ch = min(xcfg.chunk, s)
    assert s % ch == 0
    nch = s // ch
    q, k, v, log_i, log_f, gate = _mlstm_qkvif(p, x, cfg)

    f32 = jnp.float32
    qc = q.reshape(b, nch, ch, nh, dh).astype(f32)
    kc = k.reshape(b, nch, ch, nh, dh).astype(f32)
    vc = v.reshape(b, nch, ch, nh, dh).astype(f32)
    li = log_i.reshape(b, nch, ch, nh)
    lf = log_f.reshape(b, nch, ch, nh)

    cum_f = jnp.cumsum(lf, axis=2)                        # (B,N,t,H)
    seg = cum_f[:, :, -1, :]                              # (B,N,H)
    wu = li - cum_f                                       # insertion weight rel. chunk start
    dmat = cum_f[:, :, :, None, :] + wu[:, :, None, :, :]  # (B,N,t,u,H)
    mask = jnp.tril(jnp.ones((ch, ch), bool))[None, None, :, :, None]
    dexp = jnp.where(mask, jnp.exp(dmat), 0.0)

    scores = jnp.einsum("bntha,bnuha->bntuh", qc, kc) * dexp
    num_intra = jnp.einsum("bntuh,bnuhv->bnthv", scores, vc)
    den_intra = jnp.sum(scores, axis=3)                   # (B,N,t,H)
    local_max = jnp.max(jnp.where(mask, dmat, -jnp.inf), axis=3)  # (B,N,t,H)

    # carry states into each chunk: C' = e^seg C + sum_u e^{seg+wu[u]} k v^T
    w_in = jnp.exp(wu + seg[:, :, None, :])               # (B,N,u,H)
    c_in = jnp.einsum("bnuha,bnuh,bnuhv->bnhav", kc, w_in, vc)  # (B,N,H,dhk,dhv)
    n_in = jnp.einsum("bnuha,bnuh->bnha", kc, w_in)
    in_max = jnp.max(wu + seg[:, :, None, :], axis=2)     # (B,N,H)

    def chunk_step(carry, inp):
        c, n, m = carry
        c_i, n_i, sg, im = inp
        c2 = c * jnp.exp(sg)[..., None, None] + c_i
        n2 = n * jnp.exp(sg)[..., None] + n_i
        m2 = jnp.maximum(m + sg, im)
        return (c2, n2, m2), (c, n, m)

    mv = lambda a: jnp.moveaxis(a, 1, 0)
    carry0 = (jnp.zeros((b, nh, dh, dh), f32), jnp.zeros((b, nh, dh), f32),
              jnp.full((b, nh), -jnp.inf, f32))
    _, (c_prev, n_prev, m_prev) = jax.lax.scan(
        chunk_step, carry0, (mv(c_in), mv(n_in), mv(seg), mv(in_max)))
    c_prev = jnp.moveaxis(c_prev, 0, 1)                   # (B,N,H,dhk,dhv)
    n_prev = jnp.moveaxis(n_prev, 0, 1)
    m_prev = jnp.moveaxis(m_prev, 0, 1)                   # (B,N,H)

    w_out = jnp.exp(cum_f)                                # (B,N,t,H)
    num_inter = jnp.einsum("bntha,bnhav,bnth->bnthv", qc, c_prev, w_out)
    den_inter = jnp.einsum("bntha,bnha,bnth->bnth", qc, n_prev, w_out)

    num = num_intra + num_inter
    den = den_intra + den_inter
    m_abs = jnp.maximum(local_max, m_prev[:, :, None, :] + cum_f)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(m_abs))[..., None]
    h = h.reshape(b, s, inner).astype(x.dtype)            # (B,N,t,H,dhv) -> (B,S,inner)
    return (h * gate) @ p["down"].astype(x.dtype)


def init_mlstm_state(cfg: ArchConfig, batch: int):
    x: XLSTMConfig = cfg.xlstm
    inner, nh, dh = _dims(cfg)
    return {
        "c": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, x.conv_width - 1, inner), jnp.float32),
    }


def mlstm_decode(p: dict, x_t: Array, state: dict, cfg: ArchConfig):
    b, _ = x_t.shape
    inner, nh, dh = _dims(cfg)
    up = x_t @ p["up"].astype(x_t.dtype)
    gate = jax.nn.silu(x_t @ p["gate"].astype(x_t.dtype))
    hist = jnp.concatenate([state["conv"], up[:, None, :].astype(state["conv"].dtype)], 1)
    w = p["conv_w"].astype(x_t.dtype)
    conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist.astype(x_t.dtype), w)
                       + p["conv_b"].astype(x_t.dtype))
    q = (conv @ p["wq"].astype(x_t.dtype)).reshape(b, nh, dh).astype(jnp.float32)
    k = ((conv @ p["wk"].astype(x_t.dtype)).reshape(b, nh, dh)
         / np.sqrt(dh)).astype(jnp.float32)
    v = (up @ p["wv"].astype(x_t.dtype)).reshape(b, nh, dh).astype(jnp.float32)
    if_ = conv @ p["w_if"].astype(x_t.dtype) + p["b_if"].astype(x_t.dtype)
    li = if_[..., :nh].astype(jnp.float32)
    lf = jax.nn.log_sigmoid(if_[..., nh:].astype(jnp.float32))
    m_new = jnp.maximum(lf + state["m"], li)
    fp = jnp.exp(lf + state["m"] - m_new)[..., None]
    ip = jnp.exp(li - m_new)[..., None]
    c = state["c"] * fp[..., None] + ip[..., None] * (v[..., :, None] * k[..., None, :])
    n = state["n"] * fp + ip * k
    h_num = jnp.einsum("bhvk,bhk->bhv", c, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h = (h_num / h_den[..., None]).reshape(b, inner).astype(x_t.dtype)
    y = (h * gate) @ p["down"].astype(x_t.dtype)
    return y, {"c": c, "n": n, "m": m_new, "conv": hist[:, 1:, :]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key: Array, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 3)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d)),        # i, f, z, o
        "r_gates": (0.2 * jax.random.normal(ks[1], (nh, dh, 4 * dh))
                    ).astype(jnp.float32),               # recurrent, per head
        "b_gates": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                                    jnp.zeros((2 * d,))]).astype(jnp.float32),
        "down": dense_init(ks[2], (d, d)),
    }


def _slstm_scan(p: dict, gx: Array, cfg: ArchConfig, carry0):
    """gx: (B, S, 4D) input-side gate preactivations."""
    b, s, _ = gx.shape
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    r = p["r_gates"]

    def step(carry, g_in):
        c, n, m, h = carry                                # all (B, H, dh) / m:(B,H,dh)
        rec = jnp.einsum("bhd,hdg->bhg", h, r)            # (B,H,4dh)
        g = g_in.reshape(b, nh, 4 * dh) + rec
        li, lf, z, o = jnp.split(g, 4, axis=-1)
        lf = jax.nn.log_sigmoid(lf)
        m_new = jnp.maximum(lf + m, li)
        ip = jnp.exp(li - m_new)
        fp = jnp.exp(lf + m - m_new)
        c2 = fp * c + ip * jnp.tanh(z)
        n2 = fp * n + ip
        h2 = jax.nn.sigmoid(o) * c2 / jnp.maximum(n2, 1.0)
        return (c2, n2, m_new, h2), h2

    seq = gx.swapaxes(0, 1).astype(jnp.float32)
    (c, n, m, h), hs = jax.lax.scan(step, carry0, seq)
    return (c, n, m, h), hs.swapaxes(0, 1)


def slstm_fwd(p: dict, x: Array, cfg: ArchConfig) -> Array:
    b, s, _ = x.shape
    d = cfg.d_model
    nh, dh = cfg.n_heads, d // cfg.n_heads
    gx = x @ p["w_gates"].astype(x.dtype) + p["b_gates"].astype(x.dtype)
    carry0 = tuple(jnp.zeros((b, nh, dh), jnp.float32) for _ in range(2)) + (
        jnp.full((b, nh, dh), -1e30, jnp.float32), jnp.zeros((b, nh, dh), jnp.float32))
    _, hs = _slstm_scan(p, gx, cfg, carry0)
    return hs.reshape(b, s, d).astype(x.dtype) @ p["down"].astype(x.dtype)


def init_slstm_state(cfg: ArchConfig, batch: int):
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, nh, dh), -1e30, jnp.float32), "h": z}


def slstm_decode(p: dict, x_t: Array, state: dict, cfg: ArchConfig):
    b, _ = x_t.shape
    d = cfg.d_model
    gx = (x_t @ p["w_gates"].astype(x_t.dtype) + p["b_gates"].astype(x_t.dtype))
    carry0 = (state["c"], state["n"], state["m"], state["h"])
    (c, n, m, h), hs = _slstm_scan(p, gx[:, None, :], cfg, carry0)
    y = hs[:, 0].reshape(b, d).astype(x_t.dtype) @ p["down"].astype(x_t.dtype)
    return y, {"c": c, "n": n, "m": m, "h": h}
