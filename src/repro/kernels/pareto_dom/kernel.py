"""Pallas TPU kernel: tiled pairwise Pareto-dominance matrix.

The O(P^2 * M) dominance matrix is the hot spot of NSGA-II's fast
non-dominated sort (population P up to several thousand in the distributed
explorer; M = 4 objectives).  Objectives are passed transposed, (M, P), so
population indexes the 128-wide lane dimension; each (bi, bj) output tile
loads two thin (M, b) strips into VMEM and reduces over M on the VPU.

    D[i, j] = all_m(F[m,i] <= F[m,j]) & any_m(F[m,i] < F[m,j])
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(fi_ref, fj_ref, o_ref):
    fi = fi_ref[...]   # (M, bi)
    fj = fj_ref[...]   # (M, bj)
    le = jnp.all(fi[:, :, None] <= fj[:, None, :], axis=0)
    lt = jnp.any(fi[:, :, None] < fj[:, None, :], axis=0)
    o_ref[...] = (le & lt).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dominance_matrix_kernel(f_t: jax.Array, *, block: int = 256,
                            interpret: bool = False) -> jax.Array:
    """f_t: (M, P) objectives, P % block == 0.  Returns (P, P) int8 where
    D[i, j] = 1 iff point i dominates point j (minimization, Eq. 1)."""
    m, p = f_t.shape
    assert p % block == 0, (p, block)
    grid = (p // block, p // block)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block), lambda i, j: (0, i)),
            pl.BlockSpec((m, block), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, p), jnp.int8),
        interpret=interpret,
    )(f_t.astype(jnp.float32), f_t.astype(jnp.float32))
