"""Public maze_route entry point: shape handling, padding, impl selection.

`wavefront_distance` accepts a single (H, W) grid or a batched (B, H, W)
stack and returns int32 BFS distances (`INF` = unreachable).  Four
implementations sit behind it, all bit-identical on every accepted
input (the shared property suite `tests/test_maze_route_properties.py`
pins them to each other and to the Python oracle):

  impl="ref"       jitted jnp fast-sweeping oracle (`ref.py`)
  impl="kernel"    grid-batched Pallas Jacobi kernel (`kernel.py`)
  impl="frontier"  host numpy frontier-bucketed engine (`frontier.py`)
  impl="bfs"       pure-Python deque BFS oracle (`oracle.py`)

Selection (`impl=None`): under a jit trace the inputs are tracers, so
the choice is between the traceable implementations — the Pallas kernel
on TPU, the jitted ref elsewhere (Pallas interpret mode re-enters
Python per while-loop step: fine for tests, not for a hot path).  On
concrete host arrays off-TPU the frontier engine wins — per-level work
is proportional to the active frontier, not H×W — and is the default;
it returns numpy (callers on this path, e.g. `repro.eda.router`, read
the field on host anyway).  Host-only impls raise under tracing rather
than silently falling back.  ``use_kernel=True/False`` remains as the
legacy spelling of impl="kernel"/"ref" (tests force the kernel in
interpret mode off-TPU and assert it matches the ref).

Padding: the kernel needs TPU tile multiples (sublane 8, lane 128).
`pad_blocked` pads the occupancy with *blocked* cells and the seed with
zeros — the pad region is masked out of the sweep explicitly, so no
wavefront can enter it and tunnel around the real grid's edge, and
distances inside the real grid are untouched (regression-tested along
the pad boundary in the property suite).  Different-sized grids in one
batch are handled the same way by the caller (`repro.eda.batched_flow`
blocks every cell beyond a spec's own grid bounds).
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.maze_route.frontier import wavefront_distance_frontier
from repro.kernels.maze_route.kernel import wavefront_kernel
from repro.kernels.maze_route.oracle import wavefront_distance_bfs
from repro.kernels.maze_route.ref import INF, wavefront_distance_ref

_ref_jit = jax.jit(wavefront_distance_ref)

IMPLS = ("ref", "kernel", "frontier", "bfs")
HOST_IMPLS = ("frontier", "bfs")     # numpy in / numpy out, never traced


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _traced(*arrays) -> bool:
    return any(isinstance(a, jax.core.Tracer) for a in arrays)


def pad_blocked(occ: jax.Array, seed: jax.Array):
    """Pad (B, H, W) grids to the TPU tile multiples with an explicitly
    *blocked* pad region (occ=1, seed=0).

    Blocked padding is the correctness argument, not a convenience: a
    free pad region would participate in the relaxation and let
    wavefronts leave the real grid at its edge and re-enter elsewhere,
    shortening distances along the boundary.  Returns
    (occ_padded, seed_padded, (h, w)) with the original extent for
    de-padding.
    """
    _, h, w = occ.shape
    ph, pw = (-h) % 8, (-w) % 128
    pad = [(0, 0), (0, ph), (0, pw)]
    occ_p = jnp.pad(occ.astype(jnp.int8), pad, constant_values=1)
    seed_p = jnp.pad(seed.astype(jnp.int8), pad, constant_values=0)
    return occ_p, seed_p, (h, w)


def wavefront_distance(occ: jax.Array, seed: jax.Array, *,
                       use_kernel: bool | None = None,
                       interpret: bool | None = None,
                       impl: str | None = None) -> jax.Array:
    """BFS distance field(s) for the Lee maze router.

    occ, seed: (H, W) or (B, H, W) bool.  Returns int32 distances of the
    same shape; seeds are 0 (even if occupied), blocked cells `INF`.
    Host impls ("frontier", "bfs") return numpy arrays; traced/"ref"/
    "kernel" return jax arrays.
    """
    if use_kernel is not None:
        warnings.warn(
            "wavefront_distance(use_kernel=...) is deprecated; pass "
            "impl='kernel'/'ref' (see docs/kernels.md)",
            DeprecationWarning, stacklevel=2)
    if impl is None:
        if use_kernel is True:
            impl = "kernel"
        elif use_kernel is False:
            impl = "ref"
        elif _traced(occ, seed) or jax.default_backend() == "tpu":
            impl = "kernel" if jax.default_backend() == "tpu" else "ref"
        else:
            impl = "frontier"
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if impl in HOST_IMPLS:
        if _traced(occ, seed):
            raise TypeError(
                f"impl={impl!r} is a host engine and cannot run under a "
                "jit trace; use impl='ref'/'kernel' inside traced code")
        occ_np = np.asarray(occ, bool)
        seed_np = np.asarray(seed, bool)
        if impl == "frontier":
            return wavefront_distance_frontier(occ_np, seed_np)
        return wavefront_distance_bfs(occ_np, seed_np)

    occ = jnp.asarray(occ)
    seed = jnp.asarray(seed)
    squeeze = occ.ndim == 2
    if squeeze:
        occ, seed = occ[None], seed[None]
    if impl == "ref":
        out = _ref_jit(occ, seed)
        return out[0] if squeeze else out
    if interpret is None:
        interpret = _should_interpret()
    occ_p, seed_p, (h, w) = pad_blocked(occ, seed)
    out = wavefront_kernel(occ_p, seed_p, interpret=interpret)[:, :h, :w]
    return out[0] if squeeze else out
