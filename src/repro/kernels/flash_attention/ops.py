"""Public wrapper: GQA head broadcasting + padding + backend selection."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, S, H, Dh); k/v: (B, T, KV, Dh) with H % KV == 0.

    Returns (B, S, H, Dh).  KV heads are broadcast to H (GQA) and the
    (B, H) axes fold into the kernel grid.
    """
    if interpret is None:
        interpret = _should_interpret()
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    kx = jnp.repeat(k, g, axis=2)
    vx = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kf = kx.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    vf = vx.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    bq = min(block_q, s)
    while s % bq:
        bq //= 2
    bk = min(block_k, t)
    while t % bk:
        bk //= 2
    o = flash_attention_kernel(qf, kf, vf, block_q=bq, block_k=bk,
                               causal=causal, interpret=interpret)
    return o.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
