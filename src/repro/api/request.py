"""Declarative design queries: the one request type for the whole flow.

The paper's pitch is *end-to-end*: spec in, Pareto set and layouts out.
`DesignRequest` captures the entire query as a frozen, hashable,
JSON-(de)serializable value — array size and seed, the MOGA budget,
calibration constants, backend knobs, the user's application
requirements (the agile-filter thresholds of `ParetoResult.filter`),
and the layout options.  Everything downstream (`repro.api.session
.DesignSession`, `repro.serve.design_service.DesignService`) consumes
requests; nothing threads loose kwargs.

Two derived keys organize the caching / coalescing machinery:

  * `shape_signature()` — the *static* (shape-determining) part of the
    request: population size, generation count, and kernel selection.
    Requests sharing a signature share one compiled sweep program
    (array size, seed, and calibration are traced operands — see
    `repro.core.nsga2`), so a session can serve a signature-compatible
    variant request with zero new traces.
  * `explore_key()` — the full exploration identity (signature + cell +
    calibration).  Two requests with equal explore keys have bit-equal
    Pareto fronts, so the session caches fronts under it.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math

from repro.core.constants import CAL28, CalibConstants
from repro.core.nsga2 import DEFAULT_CROSSOVER_PROB, DEFAULT_MUTATION_PROB


@dataclasses.dataclass(frozen=True)
class Requirements:
    """Application requirements: the agile-distillation thresholds
    (paper Fig. 4, arrow 'remove undesired solutions')."""

    min_snr_db: float = float("-inf")
    min_tops: float = 0.0
    max_energy_fj: float = float("inf")
    max_area: float = float("inf")
    min_tops_per_w: float = 0.0

    @property
    def is_noop(self) -> bool:
        return self == Requirements()

    def as_filter_kwargs(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class DesignRequest:
    """One end-to-end design query (explore -> distill -> layout)."""

    array_size: int
    seed: int = 0
    # MOGA budget (all static: they shape / specialize the sweep program)
    pop_size: int = 256
    generations: int = 80
    crossover_prob: float = DEFAULT_CROSSOVER_PROB
    mutation_prob: float = DEFAULT_MUTATION_PROB
    # technology calibration (a traced operand of the sweep program)
    cal: CalibConstants = CAL28
    # backend knobs
    use_pallas_dominance: bool = False
    use_pallas_rank: bool = False
    # island-model mesh exploration (repro.parallel.distributed_explorer):
    # islands > 1 evolves that many ring-migrating NSGA-II islands per
    # cell and serves the merged union front; migrate_every is the
    # generation cadence between elite migrations.  Both shape the
    # compiled program, so they are part of `shape_signature()`.
    islands: int = 1
    migrate_every: int = 20
    # application requirements (agile distillation)
    requirements: Requirements = Requirements()
    # layout options
    layout: bool = True
    coarse: int = 64
    capacity: int = 4

    def __post_init__(self) -> None:
        s = self.array_size
        if s <= 0 or (s & (s - 1)) != 0:
            raise ValueError(f"array_size must be a positive power of two, "
                             f"got {s}")
        if self.pop_size <= 0 or self.generations <= 0:
            raise ValueError("pop_size and generations must be positive")
        if self.coarse <= 0 or self.capacity <= 0:
            raise ValueError("coarse and capacity must be positive")
        if self.islands <= 0 or self.migrate_every <= 0:
            raise ValueError("islands and migrate_every must be positive")

    # -- derived keys ---------------------------------------------------
    def shape_signature(self) -> tuple:
        """Static (shape-determining) part: requests sharing it share one
        compiled sweep program."""
        return (self.pop_size, self.generations, self.crossover_prob,
                self.mutation_prob, self.use_pallas_dominance,
                self.use_pallas_rank, self.islands, self.migrate_every)

    def explore_group(self) -> tuple:
        """Requests sharing this can be coalesced into one dispatch."""
        return self.shape_signature() + (self.cal,)

    def explore_key(self) -> tuple:
        """Full exploration identity: equal keys -> bit-equal fronts."""
        return self.explore_group() + (self.array_size, self.seed)

    @property
    def cell(self) -> tuple[int, int]:
        return (self.array_size, self.seed)

    # -- (de)serialization ----------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["requirements"] = _finite_dict(d["requirements"])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "DesignRequest":
        d = dict(d)
        # a clear diagnosis beats dataclass __init__'s TypeError when an
        # artifact-cache entry was written by a newer request schema
        unknown = sorted(set(d) - {f.name for f in dataclasses.fields(cls)})
        if unknown:
            raise ValueError(f"unknown DesignRequest field(s) {unknown} — "
                             f"written by a newer schema?")
        d["cal"] = CalibConstants(**d["cal"])
        d["requirements"] = Requirements(**_definite_dict(d["requirements"]))
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "DesignRequest":
        return cls.from_dict(json.loads(text))

    def sha(self) -> str:
        """Stable content hash (provenance / cache keys across processes)."""
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]


def _finite_dict(d: dict) -> dict:
    """+/-inf thresholds -> "inf"/"-inf" strings, for strict-JSON
    interchange.  Signed string markers (not null) so a request that
    *excludes* everything (`min_tops=inf`) stays distinct from the
    all-pass defaults after a round trip."""
    return {k: (("-inf" if v < 0 else "inf")
                if isinstance(v, float) and math.isinf(v) else v)
            for k, v in d.items()}


def _definite_dict(d: dict) -> dict:
    """Invert `_finite_dict`."""
    return {k: (float(v) if v in ("inf", "-inf") else v)
            for k, v in d.items()}
