"""Hypothesis property sweeps for the Pallas kernels (interpret mode).

Collected only where hypothesis is installed (`pytest.importorskip`);
deterministic kernel coverage lives in `test_kernels.py`."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pareto  # noqa: E402
from repro.core.acim_spec import MacroSpec  # noqa: E402
from repro.kernels.acim_matmul import acim_matmul, acim_matmul_ref  # noqa: E402
from repro.kernels.pareto_dom import (dominance_matrix,  # noqa: E402
                                      dominance_matrix_ref,
                                      non_dominated_rank)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _pm1(key, shape):
    return jnp.where(jax.random.bernoulli(jax.random.key(key), 0.5, shape),
                     1.0, -1.0)


class TestAcimMatmulProperties:
    @given(st.integers(1, 33), st.integers(1, 200), st.integers(1, 17),
           st.sampled_from([64, 128, 256]), st.integers(1, 6))
    def test_kernel_matches_ref_hypothesis(self, m, k, c, n, b):
        x = _pm1(m + k, (m, k))
        w = _pm1(k + c, (k, c))
        spec = MacroSpec(h=2 * n, w=c, l=2, b_adc=b)
        np.testing.assert_array_equal(
            np.asarray(acim_matmul(x, w, spec)),
            np.asarray(acim_matmul_ref(x, w, n=n, b_adc=b)))


class TestParetoDomProperties:
    @given(st.integers(2, 40), st.integers(2, 5))
    def test_matches_ref_hypothesis(self, p, m):
        f = jax.random.normal(jax.random.key(p * 31 + m), (p, m))
        np.testing.assert_array_equal(np.asarray(dominance_matrix(f)),
                                      np.asarray(dominance_matrix_ref(f)))

    @given(st.integers(2, 40), st.integers(2, 5))
    def test_fused_rank_matches_ref_hypothesis(self, p, m):
        f = jax.random.normal(jax.random.key(p * 13 + m), (p, m))
        np.testing.assert_array_equal(
            np.asarray(non_dominated_rank(f)),
            np.asarray(pareto.non_dominated_rank(f)))
