"""Fixture: `schema_base.py` with a new serialized field (``host``) but
the *same* version constant — the schema-drift pass must flag it.
"""
TRACE_SCHEMA = 1


class TraceExport:
    def __init__(self, name, spans):
        self.name = name
        self.spans = spans

    def to_dict(self):
        return {"schema": TRACE_SCHEMA, "name": self.name,
                "spans": list(self.spans), "host": "localhost"}

    def to_events(self):
        return [{"ph": "X", "name": self.name}]
