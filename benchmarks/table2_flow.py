"""Table 2 reproduction: design-flow comparison (wall-clock, automation).

Paper: traditional flow 1-2 months manual; AutoDCIM automatic layout from
user-fixed parameters; EasyACIM explores the Pareto frontier automatically
and generates layouts in "several hours" (exploration < 30 min, layout
minutes/solution).  Here both stages are measured on this machine — the
vectorized NSGA-II does the exploration in seconds (beyond-paper speedup,
single fused XLA evaluation per generation).
"""
from __future__ import annotations

import time

from repro.api import DesignRequest, DesignSession
from repro.eda.flow import generate_layout


def run() -> dict:
    session = DesignSession()
    req = DesignRequest(array_size=16384, pop_size=192, generations=60,
                        layout=False)
    t0 = time.time()
    res = session.run(req).pareto
    t_explore = time.time() - t0

    sel = res.filter(min_tops=0.5).specs[:2] or res.specs[:2]
    t0 = time.time()
    for spec in sel:
        generate_layout(spec)
    t_layout = (time.time() - t0) / max(len(sel), 1)

    return {
        "explore_seconds": round(t_explore, 2),
        "paper_explore_seconds": 1800.0,
        "explore_speedup_vs_paper": round(1800.0 / max(t_explore, 1e-9), 1),
        "layout_seconds_per_solution": round(t_layout, 2),
        "paper_layout_seconds": 180.0,
        "pareto_points": len(res),
        "parameters_determined_automatically": True,
        "layout_automatic": True,
    }


def main() -> None:
    for k, v in run().items():
        print(f"{k}={v}")


if __name__ == "__main__":
    main()
