"""Codesign showcase: recommend an ACIM macro for every assigned
architecture (the paper's Fig. 1 'versatile scenarios', made quantitative).

  PYTHONPATH=src python examples/codesign_sweep.py
"""
from repro.configs import registry as creg
from repro.core.codesign import recommend_macro


def main() -> None:
    print(f"{'arch':24s} {'macro (H,W,L,B)':>20s} {'SNR':>6s} {'util':>5s} "
          f"{'TOPS/W':>7s} {'#macros@1tok/us':>15s}")
    for name in creg.ARCH_IDS:
        cfg = creg.get(name)
        rec = recommend_macro(cfg, array_size=65536, min_snr_db=3.0,
                              pop_size=96, generations=25, seed=7)
        s = rec.spec
        print(f"{cfg.name:24s} {str((s.h, s.w, s.l, s.b_adc)):>20s} "
              f"{rec.snr_db:6.1f} {rec.utilization:5.2f} "
              f"{rec.eff_tops_per_w:7.0f} {rec.macro_count_for_rate:15d}")


if __name__ == "__main__":
    main()
