"""Pipeline parallelism: GPipe schedule over a mesh axis via shard_map +
collective_permute.

At 1000+-node scale the "pod" axis becomes a pipeline axis (stage-sharded
layers, microbatched activations over DCN) rather than pure DP.  This
module implements the schedule generically: `pipeline_apply` runs S stages
over M microbatches in M + S - 1 ticks, activations hopping stage->stage+1
by `jax.lax.ppermute` each tick; bubble fraction (S-1)/(M+S-1), matching
the GPipe analysis.

The per-device program is the user's `stage_fn(stage_params, x)`; outputs
are collected on the last stage and psum-broadcast so every device returns
the full (M, ...) result.  Differentiable end to end (ppermute and psum
have transposes), so the same schedule serves training — exercised by the
tests including a gradient check against the unpipelined reference.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.parallel.axes import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def pipeline_apply(mesh: Mesh, stage_axis: str,
                   stage_fn: Callable[[object, jax.Array], jax.Array],
                   stage_params, microbatches: jax.Array) -> jax.Array:
    """Run `stage_fn` as an S-stage pipeline.

    stage_params: pytree with leading stage axis S (sharded over
    `stage_axis`); microbatches: (M, B, ...) activations (replicated).
    Returns (M, B, ...) outputs (replicated).
    """
    n_stages = mesh.shape[stage_axis]
    m = microbatches.shape[0]
    ticks = m + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    pspec = jax.tree.map(lambda _: P(stage_axis), stage_params)

    @functools.partial(
        shard_map, mesh=mesh, check_vma=False,
        in_specs=(pspec, P()), out_specs=P())
    def run(params, mb):
        my_params = jax.tree.map(lambda a: a[0], params)
        stage_id = jax.lax.axis_index(stage_axis)
        zero = jnp.zeros_like(mb[0])
        out_buf = jnp.zeros_like(mb)

        def tick(t, state):
            prev_out, out_buf = state
            recv = jax.lax.ppermute(prev_out, stage_axis, perm)
            feed = jax.lax.dynamic_index_in_dim(
                mb, jnp.clip(t, 0, m - 1), 0, keepdims=False)
            x = jnp.where(stage_id == 0, feed, recv)
            y = stage_fn(my_params, x)
            # microbatch id being finished at the last stage this tick
            mb_id = t - (n_stages - 1)
            is_out = (stage_id == n_stages - 1) & (mb_id >= 0)
            upd = jnp.where(is_out, y, 0.0)
            out_buf = jax.lax.dynamic_update_index_in_dim(
                out_buf,
                jax.lax.dynamic_index_in_dim(out_buf, jnp.clip(mb_id, 0, m - 1),
                                             0, keepdims=False) + upd,
                jnp.clip(mb_id, 0, m - 1), 0)
            return y, out_buf

        _, out_buf = jax.lax.fori_loop(0, ticks, tick, (zero, out_buf))
        # only the last stage holds results: broadcast via psum
        return jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, out_buf, 0.0), stage_axis)

    return run(stage_params, microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
