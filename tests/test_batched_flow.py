"""Batched layout flow vs the sequential per-spec path.

The contract (asserted per spec): identical placed rectangles, identical
DRC verdict, identical routed/failed counts and wirelength — the batched
path is the sequential path, vectorized, not an approximation of it.
"""
import numpy as np
import pytest

from repro.core.acim_spec import MacroSpec
from repro.eda import netlist as nl
from repro.eda.batched_flow import generate_layouts, stack_layout_operands
from repro.eda.flow import generate_layout
from repro.eda.placer import BatchDims, geometry

# Mixed extents on purpose: every BatchDims axis gets real padding.
SPECS = (MacroSpec(64, 16, 2, 3), MacroSpec(128, 32, 4, 3),
         MacroSpec(256, 16, 8, 3), MacroSpec(128, 8, 4, 2),
         MacroSpec(64, 8, 2, 5))


@pytest.fixture(scope="module")
def results():
    return generate_layouts(SPECS), [generate_layout(s) for s in SPECS]


class TestEquivalence:
    def test_same_rects_per_spec(self, results):
        bat, seq = results
        for i, lr in enumerate(seq):
            rb = {(r.name, r.cell, r.x, r.y, r.w, r.h)
                  for r in bat.placements()[i].rects}
            rs = {(r.name, r.cell, r.x, r.y, r.w, r.h)
                  for r in lr.placement.rects}
            assert rb == rs, SPECS[i]

    def test_same_drc_verdict_per_spec(self, results):
        bat, seq = results
        for i, lr in enumerate(seq):
            assert int(bat.drc_overlaps[i]) == lr.drc.overlaps
            assert int(bat.drc_oob[i]) == lr.drc.out_of_bounds
            assert bool(bat.drc_clean[i]) == lr.drc.clean
            assert bat.drc_reports()[i] == lr.drc

    def test_same_routing_per_spec(self, results):
        bat, seq = results
        for i, lr in enumerate(seq):
            assert int(bat.routing.routed[i]) == len(lr.routing.wires)
            assert int(bat.routing.failed[i]) == len(lr.routing.failed)
            assert (int(bat.routing.wirelength[i])
                    == lr.routing.total_wirelength)
            assert (float(bat.routing.success_rate[i])
                    == lr.routing.success_rate)

    def test_metrics_rows_match(self, results):
        bat, seq = results
        for row, lr in zip(bat.metrics_rows(), seq):
            m = lr.metrics()
            # batched rows are pure content: no wall-clock key
            assert set(row) == set(m) - {"elapsed_s"}
            for k in ("h", "w", "l", "b_adc", "routed_nets", "failed_nets",
                      "route_success", "wirelength", "drc_clean"):
                assert row[k] == m[k], k
            for k in ("layout_area_f2_per_bit", "estimator_area_f2_per_bit",
                      "area_model_error"):
                assert row[k] == pytest.approx(m[k]), k

    def test_netlist_stats_closed_form(self, results):
        bat, seq = results
        for i, lr in enumerate(seq):
            assert bat.netlist_stats[i] == lr.netlist_stats
            assert nl.stats_for_spec(SPECS[i]) == lr.netlist_stats


class TestBatchedPlacement:
    def test_operand_stack_shape(self):
        ops = stack_layout_operands(SPECS, geometry())
        for leaf in ops:
            assert leaf.shape == (len(SPECS),)

    def test_batch_dims_are_maxima(self):
        d = BatchDims.for_specs(SPECS)
        assert d.w == max(s.w for s in SPECS)
        assert d.n_la == max(s.n_caps for s in SPECS)
        assert d.l == max(s.l for s in SPECS)
        assert d.b == max(s.b_adc for s in SPECS)

    def test_single_spec_batch_matches_sequential(self):
        spec = MacroSpec(64, 16, 2, 3)
        bat = generate_layouts([spec])
        lr = generate_layout(spec)
        assert len(bat) == 1
        row = bat.metrics_rows()[0]
        m = lr.metrics()
        assert row["wirelength"] == m["wirelength"]
        assert row["drc_clean"] and m["drc_clean"]

    def test_congestion_map_totals_wirelength(self, results):
        bat, _ = results
        # every routed path point increments exactly one occupancy cell
        per_spec = bat.routing.occ_count.sum(axis=(1, 2))
        np.testing.assert_array_equal(per_spec, bat.routing.wirelength)

    def test_empty_batch_rejected(self):
        with pytest.raises(ValueError):
            generate_layouts([])


class TestDistillAndLayout:
    def test_explore_to_batched_layouts(self):
        from repro.core.explorer import distill_and_layout

        # agile distillation thresholds keep the laid-out batch small
        distilled, layouts = distill_and_layout(
            4096, pop_size=48, generations=10, seed=0,
            min_tops=0.5, min_snr_db=10.0)
        assert len(distilled) == len(layouts) >= 2
        rows = layouts.metrics_rows()
        assert all(r["drc_clean"] for r in rows)
        assert [(r["h"], r["w"], r["l"], r["b_adc"]) for r in rows] \
            == [s.as_tuple() for s in distilled.specs]

    def test_overfiltered_raises(self):
        from repro.core.explorer import distill_and_layout

        with pytest.raises(ValueError):
            distill_and_layout(4096, pop_size=32, generations=5,
                               min_tops=1e9)
