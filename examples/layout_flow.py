"""Reproduce the paper's Fig. 8: three 16 kb ACIM layouts at different
design specifications, end-to-end (netlist -> place -> route -> DRC ->
GDS-like JSON).

  PYTHONPATH=src python examples/layout_flow.py
"""
import pathlib

from repro.core.acim_spec import MacroSpec
from repro.eda.flow import generate_layout

# (spec, paper TOPS, paper F^2/bit) — see benchmarks/fig8_layouts.py
PAPER = {
    "a": (MacroSpec(128, 128, 2, 3), 3.277, 4504.0),
    "b": (MacroSpec(512, 32, 8, 3), 0.813, 2610.0),
    "c": (MacroSpec(256, 64, 8, 3), 0.813, 2977.0),
}

OUT = pathlib.Path("runs/fig8")


def main() -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    for tag, (spec, paper_tops, paper_area) in PAPER.items():
        lr = generate_layout(spec)
        m = lr.metrics()
        lr.to_json(OUT / f"fig8_{tag}.json")
        print(f"({tag}) H={spec.h} W={spec.w} L={spec.l} B={spec.b_adc}: "
              f"layout {m['layout_area_f2_per_bit']:.0f} F^2/bit "
              f"(paper {paper_area:.0f}), routed {m['routed_nets']} nets, "
              f"DRC clean={m['drc_clean']}, {m['elapsed_s']:.1f}s")
    print(f"layout JSONs in {OUT}/")


if __name__ == "__main__":
    main()
