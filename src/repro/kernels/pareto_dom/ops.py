"""Public wrappers: pad the population to the tile size and strip it back.

Pad rows are +inf in every objective: they dominate nothing and real points
dominating them is irrelevant after slicing, so correctness is unaffected.
For the fused rank path the +inf rows are dominated by every real point and
therefore peel strictly after them — the real prefix of the rank vector is
exactly the unpadded sort.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pareto
from repro.kernels.pareto_dom.kernel import (dominance_matrix_kernel,
                                             nds_rank_kernel)


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_inf(f: jax.Array, multiple: int) -> jax.Array:
    p, m = f.shape
    pad = (-p) % multiple
    if pad:
        f = jnp.concatenate([f, jnp.full((pad, m), jnp.inf, f.dtype)], 0)
    return f


def dominance_matrix(f: jax.Array, *, block: int = 256,
                     interpret: bool | None = None) -> jax.Array:
    """f: (P, M) objectives (minimization).  Returns (P, P) bool."""
    if interpret is None:
        interpret = _should_interpret()
    p, m = f.shape
    block = min(block, max(8, p))
    f = _pad_inf(f, block)
    d = dominance_matrix_kernel(f.T, block=block, interpret=interpret)
    return d[:p, :p].astype(jnp.bool_)


def non_dominated_rank(f: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Fused Pallas fast non-dominated sort: (P, M) -> (P,) int32 ranks.

    Dominance tiles are built and bit-packed in VMEM and fronts are peeled
    on-device — the (P, P) matrix never exists in f32 nor reaches HBM.
    Oracle: `repro.core.pareto.non_dominated_rank`.
    """
    if interpret is None:
        interpret = _should_interpret()
    p, _ = f.shape
    ranks = nds_rank_kernel(_pad_inf(f, 256), interpret=interpret)
    return ranks[:p]


def rank_and_crowd(f: jax.Array, *, interpret: bool | None = None):
    """Fused rank-and-crowd path: Pallas peel + vectorized crowding.

    Drop-in replacement for the separate
    `pareto.non_dominated_rank` / `pareto.crowding_distance` pair in the
    NSGA-II generation step (`repro.core.nsga2.rank_and_crowd` selects it
    via `use_pallas_rank`).
    """
    ranks = non_dominated_rank(f, interpret=interpret)
    crowd = pareto.crowding_distance(f, ranks)
    return ranks, crowd
