"""Fig. 9 reproduction: the explored design space, categorized by array
size / H / L / B_ADC, with the paper's qualitative trends asserted
quantitatively:
  (a)(b) larger arrays -> higher attainable SNR & throughput; smaller ->
         better energy & area;
  (c)(d) smaller H -> higher throughput, lower SNR, more area;
  (e)(f) smaller L -> higher throughput, higher SNR bound, more area;
  (g)(h) smaller B_ADC -> better energy efficiency, lower SNR.
"""
from __future__ import annotations

import numpy as np

from repro.api import DesignRequest, DesignSession
from repro.core import estimator


def run(sizes=(4096, 16384, 65536), pop=192, gens=60) -> dict:
    session = DesignSession()
    fronts = session.fronts_for([
        DesignRequest(array_size=s, seed=s, pop_size=pop, generations=gens,
                      layout=False) for s in sizes])
    out = {}
    for req, res in fronts.items():
        s = req.array_size
        m = res.metrics
        out[s] = {
            "n_pareto": len(res),
            "snr_max": float(np.max(m["snr_db"])),
            "tops_max": float(np.max(m["tops"])),
            "tops_per_w_max": float(np.max(m["tops_per_w"])),
            "area_min": float(np.min(m["area_f2_per_bit"])),
            "area_max": float(np.max(m["area_f2_per_bit"])),
        }
    return out


def trend_checks() -> dict:
    """Single-variable sweeps at 16 kb (paper Fig. 9 c-h).

    Note on (c)(d): at fixed (L, B_ADC), Eq. 7 is H-independent (H*W = S
    cancels: T = S/(L*t)).  The paper's "smaller H -> higher throughput /
    limited SNR" trend is mediated by the constraint B_ADC <= log2(H/L):
    small H caps the ADC precision, shortening the cycle (more T) and
    capping SNR.  We therefore sweep H with B at its constraint maximum —
    the Pareto-edge coupling Fig. 9 actually shows.
    """
    s = 16384
    h = np.array([64, 128, 256, 512, 1024], np.float32)
    w = s / h
    b_max = np.log2(h / 8.0)                    # L = 8 in this sweep
    t_h = np.asarray(estimator.throughput_ops(h, w, 8, b_max))
    snr_h = np.asarray(estimator.snr_total_db(h, 8, b_max))
    a_h = np.asarray(estimator.area_f2_per_bit(h, 8, 3))

    l = np.array([2, 4, 8, 16, 32], np.float32)
    t_l = np.asarray(estimator.throughput_ops(512, 32, l, 3))
    # SNR *upper bound* vs L (paper e/f): B at its constraint max
    snr_l = np.asarray(estimator.snr_total_db(512, l, np.minimum(
        np.log2(512.0 / l), 8.0)))
    a_l = np.asarray(estimator.area_f2_per_bit(512, l, 3))

    b = np.array([1, 2, 3, 4, 5], np.float32)
    e_b = np.asarray(estimator.energy_efficiency_tops_w(512, 8, b))
    snr_b = np.asarray(estimator.snr_total_db(512, 8, b))

    def mono(x, increasing):
        d = np.diff(x)
        return bool(np.all(d > 0) if increasing else np.all(d < 0))

    return {
        "smaller_H_higher_T": mono(t_h, False),       # T falls as H grows
        "smaller_H_lower_SNR": mono(snr_h, True),     # SNR cap rises with H
        "smaller_H_more_area": mono(a_h, False),
        "smaller_L_higher_T": mono(t_l, False),
        "smaller_L_higher_SNR": mono(snr_l, False),
        "smaller_L_more_area": mono(a_l, False),
        "smaller_B_better_EE": mono(e_b, False),
        "smaller_B_lower_SNR": mono(snr_b, True),
    }


def main() -> None:
    for s, row in run().items():
        print(f"size={s}," + ",".join(f"{k}={v:.4g}" if isinstance(v, float)
                                      else f"{k}={v}" for k, v in row.items()))
    for k, v in trend_checks().items():
        print(f"trend,{k},{v}")


if __name__ == "__main__":
    main()
