"""Fixture: a `kernels/*/ops.py` dispatcher that violates the host-guard
contract — parsed under the name ``repro.kernels.fake.ops`` so the
trace-purity pass applies the ops dispatch rule (docs/kernels.md).
"""
from repro.kernels.fake.frontier import sweep_frontier
from repro.kernels.fake.ref import sweep_ref


def dispatch(occ, impl=None):
    if impl == "frontier":
        return sweep_frontier(occ)   # host engine, no raising trace check
    return sweep_ref(occ)
