"""Fixture: every trace-purity violation family in one jitted region.

Never imported — parsed by `tests/test_analysis.py` and fed to the
`repro.analysis.trace_purity` pass, which must flag each marked line.
"""
import time

import jax


@jax.jit
def step(x):
    t = time.time()             # host-call: wall clock under a trace
    print("step", t)            # host-call: console effect
    for k in {1, 2, 3}:         # set-iteration: unordered trace structure
        x = x + k
    return x


def fill(buf, x):
    buf[0] = x                  # inplace-store, reachable from `outer`
    return buf


@jax.jit
def outer(x):
    return fill([0], x)[0]
