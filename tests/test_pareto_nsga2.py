"""Pareto utilities + NSGA-II: deterministic checks and ground-truth front
recovery against exhaustive enumeration.

Hypothesis property tests live in `test_pareto_properties.py` (skipped
cleanly when hypothesis is not installed)."""
import jax.numpy as jnp
import numpy as np

from repro.core import explorer, nsga2, pareto


class TestDominance:
    def test_crowding_boundaries_infinite(self):
        f = jnp.asarray(np.array([[0., 5.], [1., 4.], [2., 3.], [3., 2.]],
                                 np.float32))
        ranks = pareto.non_dominated_rank(f)
        crowd = np.asarray(pareto.crowding_distance(f, ranks))
        assert crowd[0] > 1e20 and crowd[-1] > 1e20
        assert np.all(crowd[1:-1] < 1e20)

    def test_constrained_dominance_feasible_beats_infeasible(self):
        f = jnp.asarray(np.array([[5., 5.], [0., 0.]], np.float32))
        cv = jnp.asarray(np.array([0.0, 2.0], np.float32))
        d = np.asarray(pareto.constrained_dominance_matrix(f, cv))
        assert d[0, 1] and not d[1, 0]


class TestNSGA2:
    def test_recovers_true_front_16kb(self):
        genes, objs_all = explorer.full_design_space(16384)
        true_front_mask = np.asarray(pareto.non_dominated_mask(objs_all))
        true_front = {tuple(g) for g, m in
                      zip(np.asarray(genes), true_front_mask) if m}
        res = explorer.explore(16384, pop_size=192, generations=60, seed=3)
        found = {(int(np.log2(s.h)), int(np.log2(s.l)), s.b_adc)
                 for s in res.specs}
        # every found point is truly non-dominated...
        assert found <= true_front
        # ...and covers most of the true front
        assert len(found) >= 0.6 * len(true_front)

    def test_population_always_feasible(self):
        cfg = nsga2.NSGA2Config(array_size=16384, pop_size=64, generations=10)
        pop = nsga2.run(cfg)
        cv = np.asarray(nsga2.constraint_violation(pop.genes, cfg))
        assert (cv == 0).all()
        g = np.asarray(pop.genes)
        h_lo, h_hi = cfg.h_exp_bounds
        assert (g[:, 0] >= h_lo).all() and (g[:, 0] <= h_hi).all()
        assert (g[:, 2] >= 1).all() and (g[:, 2] <= (g[:, 0] - g[:, 1])).all()

    def test_repair_projects_into_feasible_set(self):
        cfg = nsga2.NSGA2Config(array_size=16384)
        bad = jnp.asarray(np.array([[20, 9, 9], [4, 7, 8], [6, 1, 0]], np.int32))
        fixed = np.asarray(nsga2.repair(bad, cfg))
        cv = np.asarray(nsga2.constraint_violation(jnp.asarray(fixed), cfg))
        assert (cv == 0).all()
        assert (fixed[:, 2] >= 1).all()

    def test_agile_filter(self):
        res = explorer.explore(16384, pop_size=96, generations=25, seed=5)
        filt = res.filter(min_tops=0.5)
        assert all(m >= 0.5 for m in filt.metrics["tops"])
        assert len(filt) <= len(res)

    def test_legacy_generation_step_shapes(self):
        cfg = nsga2.NSGA2Config(array_size=16384, pop_size=32)
        import jax

        key = jax.random.key(0)
        genes = nsga2.init_population(key, cfg)
        objs = nsga2.evaluate(genes, cfg)
        g2, o2 = nsga2.generation_step(key, genes, objs, cfg)
        assert g2.shape == genes.shape and o2.shape == objs.shape
        cv = np.asarray(nsga2.constraint_violation(g2, cfg))
        assert (cv == 0).all()
