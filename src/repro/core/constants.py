"""Calibrated physical / technology constants for the EasyACIM estimation model.

The paper (DAC'24, TSMC28) publishes the *form* of the estimation model
(Eqs. 2-11) but not the fitted constants (cell areas, ADC energy coefficients
k1/k2, timing constants, C0, kappa, k3/k4).  We therefore calibrate them
against the paper's own reported numbers, exactly as a user of the flow would
calibrate against post-layout simulation (the paper itself obtains k1/k2
"from post-layout simulation").

Anchors used (all from the paper text):
  [T1] Fig. 8(a): 16 kb, H=128, W=128, L=2, B_ADC=3  ->  3.277 TOPS.
       With t_cycle = t_com + 0.69*tau*B + t_conv_bit*B this pins
       t_cycle(B=3) = 2*(H/L)*W / 3.2768e12 = 5.000 ns exactly:
           t_com = 0.40 ns, 0.69*tau*3 = 1.00 ns (tau = 0.4831 ns),
           t_conv_bit = 1.20 ns  (3.6 ns for 3 bits).
       Cross-check Fig. 8(b): H=512, W=32, L=8, B=3 -> 2*2048/5ns =
       0.8192 TOPS vs paper "0.813" (+0.8%), and Fig. 8(c) H=256, W=64,
       L=8 gives the *same* throughput at +3 dB SNR, matching the text.
  [A1] Fig. 8(a) area 4504 F^2/bit at (H=128, L=2, B=3),
  [A2] design-space floor  ~1500 F^2/bit (paper Fig. 9/10), anchored at
       (L=32, H=2048, B=1),
  [A3] design-space ceiling ~7500 F^2/bit, anchored at (L=2, H=64, B=5).
       Solving Eq. 10 through [A1][A2][A3] exactly (with A_DFF chosen at
       4759 F^2, a dynamic DFF + per-bit RBL switch) gives
           A_SRAM = 1304.7 F^2 (~1.0 um^2 8T compute cell @28nm - sane)
           A_LC   =  704.0 F^2 (local cap + switch cell)
           A_COMP = 350175 F^2 (~275 um^2: comparator + column SAR
                                periphery lumped, per paper's A_COMP term)
       Prediction check: Fig. 8(b) -> 2125 (paper 2610, -19%: its exact
       (H,L) is not published), Fig. 8(c) -> 2837 (paper 2977, -4.7%).
  [E1] energy-efficiency span 50-750 TOPS/W (paper Fig. 10):
       EE = 2000 / E_fJ per 1b-MAC.  Low end pinned at (B=8, H/L=256):
       E_ADC(8) ~ 9.6 pJ -> E = 2.5 + 37.5 fJ -> 50 TOPS/W.  High end at
       (B=1, H/L=2048): E = 2.5 + 0.115 fJ -> ~765 TOPS/W.
           E_compute + E_control = 2.5 fJ, k1 = 276 fJ, k2 = 0.14 fJ.
  [S1] SNR model constants: C0 = 2 fF compute cap, kappa = 0.45 %*sqrt(fF)
       (Tripathi & Murmann metal-fringe mismatch [28]), kT @ 300 K.
       Eq. 11's (k3, k4) are *derived* from the full model (Eqs. 2-6) by
       least squares in `fit_eq11_constants` and verified by a unit test.

Everything downstream reads from the frozen `CAL28` instance; an alternative
technology can be modelled by constructing another `CalibConstants`.
"""
from __future__ import annotations

import dataclasses
import math

BOLTZMANN = 1.380649e-23  # J/K


@dataclasses.dataclass(frozen=True)
class CalibConstants:
    """Technology calibration for the estimation model (defaults: TSMC28)."""

    # --- timing (Eq. 7) ------------------------------------------------
    t_com: float = 0.40e-9        # MAC (charge-share) phase [s]
    tau: float = 0.4831e-9        # RBL settling time constant [s]
    t_conv_bit: float = 1.20e-9   # SAR conversion time per bit [s]

    # --- energy (Eqs. 8-9), femtojoules per 1b MAC ---------------------
    e_compute_fj: float = 1.5
    e_control_fj: float = 1.0
    k1_fj: float = 276.0          # Murmann ADC model, linear term
    k2_fj: float = 0.14           # Murmann ADC model, 4^B term
    v_dd: float = 0.9             # [V]

    # --- area (Eq. 10), F^2 ---------------------------------------------
    a_sram: float = 1304.7        # 8T compute bit-cell
    a_lc: float = 704.0           # local-array shared cap + control cell
    a_comp: float = 350175.0      # column comparator + SAR periphery
    a_dff: float = 4759.0         # per-ADC-bit DFF + RBL switch

    # --- SNR (Eqs. 2-6) -------------------------------------------------
    c0_ff: float = 2.0            # compute capacitor [fF]
    kappa: float = 0.0045         # mismatch coeff, sigma(dC/C)=kappa/sqrt(C_fF)
    temperature_k: float = 300.0
    b_w: int = 1                  # weight bits (paper: 1b x 1b computation)
    b_x: int = 1                  # activation bits
    # normalized signal statistics.  1-bit (Rademacher) signals:
    # E[x^2] = x_m^2 = 1, sigma_w = w_m = 1, zeta = x_m/sigma = 1 (0 dB).
    x_m: float = 1.0
    w_m: float = 1.0
    sigma_x: float = 1.0
    sigma_w: float = 1.0
    e_x2: float = 1.0             # E[x^2]
    sigma_inj2: float = 0.0       # charge-injection noise: killed by
    #                               bottom-plate sampling (paper Sec. 3.2.1)

    # --- search-space bounds (paper Sec. 4) ------------------------------
    l_min: int = 2
    l_max: int = 32
    b_min: int = 1
    b_max: int = 8
    h_min: int = 64     # paper Fig. 9(c)(d) explores H >= 64
    h_max: int = 4096
    w_min: int = 8

    @property
    def kt(self) -> float:
        return BOLTZMANN * self.temperature_k

    @property
    def e_cc_fj(self) -> float:
        """E_compute + E_control (Eq. 8, design-point independent)."""
        return self.e_compute_fj + self.e_control_fj

    @property
    def zeta_x_db(self) -> float:
        return 20.0 * math.log10(self.x_m / self.sigma_x)

    @property
    def zeta_w_db(self) -> float:
        return 20.0 * math.log10(self.w_m / self.sigma_w)


CAL28 = CalibConstants()

# TPU v5e roofline constants (per chip), from the brief.
TPU_PEAK_BF16_FLOPS = 197e12   # FLOP/s
TPU_HBM_BW = 819e9             # B/s
TPU_ICI_BW = 50e9              # B/s per link
