"""Operator CLI for the design service's telemetry & control plane.

Four command families over the file-shaped telemetry surface
(`docs/observability.md`):

  * `metrics PATH` — inspect a metrics snapshot dumped by
    `repro.telemetry.export.write_metrics_json` (or by `drain` below):
    non-zero counters, live gauges, histogram summaries; `--prometheus`
    renders the same snapshot as text exposition format instead.
  * `gantt PATH` — inspect a span trace dumped by `TraceExport.to_json`
    (Chrome-trace JSON, loadable as-is in Perfetto): per-batch stage
    rows, `--ascii` draws the stage Gantt as terminal bars,
    `--stage-totals` prints the per-stage span sums the acceptance
    check compares against the busy clocks.
  * `cache DIR stats|prune|clear|warm` — artifact-cache maintenance:
    entry count / size / hit counters, an explicit eviction pass with
    operator-supplied bounds (`--ttl-s`, `--max-entries`), a full
    clear, and a warm pass that runs a service over a requests file so
    a fresh fleet boots hot.  With `--remote URI` the same actions run
    over the two-tier fleet cache (`TieredArtifactCache`): `stats`
    reports per-tier entry counts/sizes plus the tier counters
    (hits/misses/promotions), and `--tier l1|l2|all` filters what
    `prune`/`clear` touch — the shared L2 has no owning worker, so its
    eviction is exactly this explicit operator pass.
  * `drain REQUESTS_FILE` — run a telemetry-instrumented service over
    a JSON file of `DesignRequest.to_dict()` entries until every
    ticket lands, then dump the span trace, the per-batch Gantt, and
    the metrics snapshot (`--out-dir`) and print the summary counters.

  PYTHONPATH=src python tools/repro_ctl.py metrics service_metrics.json
  PYTHONPATH=src python tools/repro_ctl.py gantt service_trace.json --ascii
  PYTHONPATH=src python tools/repro_ctl.py cache /var/acim-cache stats
  PYTHONPATH=src python tools/repro_ctl.py drain requests.json --out-dir tel/
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.telemetry import (TraceExport, atomic_write_json,  # noqa: E402
                             load_snapshot, render_prometheus,
                             write_metrics_json)


# -- metrics ---------------------------------------------------------------

def cmd_metrics(args) -> int:
    snap = load_snapshot(args.path)
    if args.prometheus:
        print(render_prometheus(snap), end="")
        return 0
    print(f"# metrics snapshot schema={snap['schema']} "
          f"time_unix_s={snap['time_unix_s']:.3f}")
    for name in sorted(snap["metrics"]):
        for s in snap["metrics"][name]:
            labels = ",".join(f"{k}={v}" for k, v in
                              sorted(s.get("labels", {}).items()))
            tag = f"{name}{{{labels}}}" if labels else name
            if s["type"] in ("counter", "gauge"):
                if s["value"] or args.all:
                    print(f"{s['type']:9s} {tag} = {s['value']:g}")
            else:
                m = s["summary"]
                if not m["count"] and not args.all:
                    continue
                q = (f" p50={m['p50']:.4g}s p95={m['p95']:.4g}s "
                     f"p99={m['p99']:.4g}s min={m['min']:.4g}s "
                     f"max={m['max']:.4g}s" if m["count"] else "")
                print(f"histogram {tag}: count={m['count']} "
                      f"sum={m['sum']:.4g}s{q}")
    return 0


# -- gantt -----------------------------------------------------------------

def _bar(t0, t1, span, width) -> str:
    if span <= 0:
        return " " * width
    a = int(round(t0 / span * (width - 1)))
    b = max(a + 1, int(round(t1 / span * (width - 1))))
    return " " * a + "#" * (b - a) + " " * (width - b)


def cmd_gantt(args) -> int:
    trace = TraceExport.from_json(args.path)
    if args.stage_totals:
        for stage, total in sorted(trace.stage_totals().items()):
            print(f"{stage:10s} {total:.6f}s")
        return 0
    g = trace.gantt()
    batches = {int(k): v for k, v in g["batches"].items()} \
        if all(isinstance(k, str) for k in g["batches"]) else g["batches"]
    keys = sorted(batches)
    if args.batch is not None:
        keys = [k for k in keys if k == args.batch]
    ends = [r["t1_s"] for rows in batches.values() for r in rows
            if r["t1_s"] is not None]
    span = max(ends) if ends else 0.0
    for k in keys:
        label = "unbatched" if k < 0 else f"batch {k}"
        print(f"-- {label} --")
        for r in batches[k]:
            if r["cat"] == "control" and not args.control:
                continue
            t0 = r["t0_s"]
            t1 = t0 if r["t1_s"] is None else r["t1_s"]
            who = r["worker"] or r["cat"]
            extra = f" bucket={r['bucket']}" if r["bucket"] else ""
            if args.ascii:
                print(f"{r['name']:>14s} |{_bar(t0, t1, span, args.width)}| "
                      f"{t1 - t0:8.4f}s {who}{extra}")
            else:
                print(f"{r['name']:>14s} [{t0:10.4f}, {t1:10.4f}] "
                      f"{t1 - t0:8.4f}s {who}{extra}")
    return 0


# -- cache -----------------------------------------------------------------

def _dir_stats(root: pathlib.Path) -> tuple[int, int]:
    entries = sorted(root.glob("*.json"))
    return len(entries), sum(p.stat().st_size for p in entries)


def cmd_cache(args) -> int:
    from repro.api import ArtifactCache, TieredArtifactCache
    root = pathlib.Path(args.root)
    tiered = args.remote is not None
    if args.action == "stats":
        n1, b1 = _dir_stats(root)
        if not tiered:
            print(f"{root}: {n1} entries, {b1 / 1e6:.2f} MB")
            return 0
        cache = TieredArtifactCache(root, args.remote)
        n2 = len(cache.remote.list())
        b2 = cache.remote.size_bytes()
        print(f"l1 {root}: {n1} entries, {b1 / 1e6:.2f} MB")
        print(f"l2 {cache.remote.uri}: {n2} entries, {b2 / 1e6:.2f} MB")
        # lifetime counters live in session metrics exports; a fresh CLI
        # cache object only sees this invocation's traffic
        for k in ("l1_hits", "l1_misses", "l2_hits", "l2_misses",
                  "promotions", "l2_writes", "l2_rejects", "l2_evictions"):
            print(f"  {k} = {cache.stats[k]}")
        return 0
    if args.action == "prune":
        if tiered:
            cache = TieredArtifactCache(root, args.remote,
                                        max_entries=args.max_entries,
                                        ttl_s=args.ttl_s)
            removed = 0
            for tier in (("l1", "l2") if args.tier == "all"
                         else (args.tier,)):
                removed += cache.prune(tier=tier,
                                       max_entries=args.max_entries,
                                       ttl_s=args.ttl_s)
            sizes = cache.lengths()
            print(f"pruned {removed} entries (tier={args.tier}); now "
                  f"l1={sizes['l1']} l2={sizes['l2']} "
                  f"(l2 evictions {cache.stats['l2_evictions']})")
            return 0
        cache = ArtifactCache(root, max_entries=args.max_entries,
                              ttl_s=args.ttl_s)
        before = len(cache)
        cache._prune()
        print(f"pruned {before - len(cache)} of {before} entries "
              f"(ttl evictions {cache.stats['ttl_evictions']}, "
              f"lru evictions {cache.stats['lru_evictions']})")
        return 0
    if args.action == "clear":
        if tiered:
            cache = TieredArtifactCache(root, args.remote)
            n = cache.clear(tier=args.tier)
            print(f"cleared {n} entries (tier={args.tier})")
            return 0
        n = 0
        for p in root.glob("*.json"):
            p.unlink()
            n += 1
        print(f"cleared {n} entries from {root}")
        return 0
    # warm: run a service over the cache so a fresh fleet boots hot
    from repro.api import DesignSession
    from repro.serve.design_service import DesignService
    reqs = _load_requests(args.requests)
    store = (TieredArtifactCache(root, args.remote) if tiered else root)
    svc = DesignService(DesignSession(artifact_cache=store),
                        max_coalesce=len(reqs))
    tickets = [svc.submit(r) for r in reqs]
    done = svc.run()
    ok = sum(1 for t in tickets if done[t].ok)
    s = svc.stats()
    print(f"warmed {root}: {ok}/{len(reqs)} ok "
          f"({s['artifact_cache_hits']} already cached, "
          f"{s['artifact_cache_writes']} written)")
    return 0 if ok == len(reqs) else 1


def _load_requests(path):
    from repro.api import DesignRequest
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, dict):
        payload = payload["requests"]
    return [DesignRequest.from_dict(d) for d in payload]


# -- drain -----------------------------------------------------------------

def cmd_drain(args) -> int:
    from repro.api import DesignSession
    from repro.serve.design_service import DesignService
    from repro.telemetry import ControllerConfig, Telemetry
    reqs = _load_requests(args.requests)
    controller = None
    if args.adaptive:
        controller = ControllerConfig(max_workers=max(args.layout_workers,
                                                      1))
    svc = DesignService(DesignSession(artifact_cache=args.cache_dir),
                        max_coalesce=args.max_coalesce,
                        layout_workers=args.layout_workers,
                        telemetry=Telemetry(), controller=controller)
    with svc.serve():
        tickets = [svc.submit(r) for r in reqs]
        arts = [svc.collect(t, timeout=args.timeout_s) for t in tickets]
    ok = sum(1 for a in arts if a.ok)
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    trace = svc.trace()
    trace.to_json(out / "service_trace.json")
    atomic_write_json(trace.gantt(), out / "service_gantt.json")
    write_metrics_json(svc.metrics(), out / "service_metrics.json")
    s = svc.stats()
    print(f"drained {ok}/{len(reqs)} ok -> {out} | "
          f"{s['service_batches']} batch(es), "
          f"{s['explorer_dispatches']} explorer dispatch(es), "
          f"{s['layout_dispatches']} layout bucket(s), "
          f"window now {svc.coalesce_window_s:.3f}s, "
          f"pool now {svc.layout_workers}")
    return 0 if ok == len(reqs) else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro_ctl",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    m = sub.add_parser("metrics", help="inspect a metrics snapshot")
    m.add_argument("path")
    m.add_argument("--prometheus", action="store_true",
                   help="render text exposition format instead")
    m.add_argument("--all", action="store_true",
                   help="include zero-valued series")
    m.set_defaults(fn=cmd_metrics)

    g = sub.add_parser("gantt", help="inspect a span trace")
    g.add_argument("path")
    g.add_argument("--batch", type=int, default=None,
                   help="only this batch sequence number")
    g.add_argument("--ascii", action="store_true",
                   help="draw terminal Gantt bars")
    g.add_argument("--width", type=int, default=60)
    g.add_argument("--stage-totals", action="store_true",
                   help="print per-stage span sums instead of rows")
    g.add_argument("--control", action="store_true",
                   help="include controller decision instants")
    g.set_defaults(fn=cmd_gantt)

    c = sub.add_parser("cache", help="artifact-cache maintenance")
    c.add_argument("root", help="L1 cache directory")
    c.add_argument("action", choices=("stats", "prune", "clear", "warm"))
    c.add_argument("--remote", default=None,
                   help="shared L2 URI (file://... or path): operate on "
                        "the two-tier fleet cache")
    c.add_argument("--tier", choices=("l1", "l2", "all"), default="all",
                   help="which tier prune/clear touch (with --remote)")
    c.add_argument("--ttl-s", type=float, default=None)
    c.add_argument("--max-entries", type=int, default=None)
    c.add_argument("--requests", default=None,
                   help="requests JSON file (for `warm`)")
    c.set_defaults(fn=cmd_cache)

    d = sub.add_parser("drain", help="serve a requests file, dump telemetry")
    d.add_argument("requests", help="JSON file of DesignRequest dicts")
    d.add_argument("--out-dir", default="telemetry")
    d.add_argument("--cache-dir", default=None)
    d.add_argument("--max-coalesce", type=int, default=16)
    d.add_argument("--layout-workers", type=int, default=1)
    d.add_argument("--adaptive", action="store_true",
                   help="attach the feedback controller")
    d.add_argument("--timeout-s", type=float, default=600.0)
    d.set_defaults(fn=cmd_drain)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:
        sys.exit(0)
