"""Fault-tolerant training loop.

Wires together: step builders (launch/steps.py), stateless data pipeline
(data/synthetic.py), atomic checkpoints (checkpoint/ckpt.py), preemption /
failure / straggler runtime (runtime/fault_tolerance.py).

Restart-exactness: state lives entirely in (checkpoint, step index); the
data pipeline is a pure function of step — `tests/test_fault_tolerance.py`
asserts bitwise-identical losses for interrupted-and-resumed vs
uninterrupted runs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ArchConfig
from repro.data.synthetic import batch_for
from repro.launch import steps as steps_mod
from repro.optim import adamw
from repro.runtime.fault_tolerance import (FailureInjector, PreemptionGuard,
                                           StragglerMonitor, RESTART_EXIT_CODE)


@dataclasses.dataclass
class TrainerConfig:
    seq: int = 256
    global_batch: int = 8
    total_steps: int = 50
    ckpt_every: int = 10
    ckpt_dir: str = "runs/ckpt"
    microbatches: int = 1
    remat: bool = False
    seed: int = 0
    log_every: int = 10
    opt: adamw.AdamWConfig | None = None


@dataclasses.dataclass
class TrainResult:
    exit_code: int
    losses: list
    steps_run: int
    straggler_events: list


def init_state(cfg: ArchConfig, tcfg: TrainerConfig, train_step) -> dict:
    from repro.models.registry import build_model

    api = build_model(cfg)
    params = api.init(jax.random.key(tcfg.seed))
    opt_cfg = tcfg.opt or steps_mod.default_opt_cfg(cfg)
    opt = adamw.init(params, opt_cfg)
    return {"params": params, "opt": opt,
            "step": jax.numpy.zeros((), jax.numpy.int32)}


def train(cfg: ArchConfig, mesh, tcfg: TrainerConfig, *,
          guard: PreemptionGuard | None = None,
          injector: FailureInjector | None = None,
          on_step: Callable[[int, dict], None] | None = None) -> TrainResult:
    """Run (or resume) training; returns exit code 0 (done) or
    RESTART_EXIT_CODE (preempted after checkpointing)."""
    import jax.numpy as jnp

    opt_cfg = tcfg.opt or steps_mod.default_opt_cfg(cfg)
    ts = steps_mod.make_train_step(cfg, mesh, opt_cfg=opt_cfg,
                                   microbatches=tcfg.microbatches,
                                   remat=tcfg.remat)
    monitor = StragglerMonitor()
    losses: list[float] = []

    start = ckpt.latest_step(tcfg.ckpt_dir)
    if start is not None:
        state_struct = jax.eval_shape(lambda: init_state(cfg, tcfg, ts))
        shardings = jax.tree.map(lambda s: s.sharding, ts.state_struct)
        state = ckpt.restore(tcfg.ckpt_dir, start, ts.state_struct, shardings)
    else:
        start = 0
        state = init_state(cfg, tcfg, ts)
        state = jax.device_put(state, jax.tree.map(lambda s: s.sharding,
                                                   ts.state_struct))

    step = start
    while step < tcfg.total_steps:
        if injector is not None:
            injector.maybe_fail(step)
        batch = batch_for(cfg, tcfg.seq, tcfg.global_batch, step, tcfg.seed)
        t0 = time.time()
        state, metrics = ts.fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        monitor.observe(step, dt)
        losses.append(loss)
        if on_step is not None:
            on_step(step, metrics)
        if tcfg.log_every and step % tcfg.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms",
                  flush=True)
        step += 1
        stop_now = guard is not None and guard.preempted
        if step % tcfg.ckpt_every == 0 or step == tcfg.total_steps or stop_now:
            ckpt.save(tcfg.ckpt_dir, step, state,
                      extra={"arch": cfg.name, "loss": loss})
        if stop_now:
            return TrainResult(RESTART_EXIT_CODE, losses, step - start,
                               monitor.events)
    return TrainResult(0, losses, step - start, monitor.events)
