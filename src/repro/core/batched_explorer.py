"""Batched one-compile MOGA explorer: a vmapped multi-cell NSGA-II sweep.

The paper's headline claim is *agile* design-space exploration; the
sequential `explore_sizes` loop undercut it by re-dispatching (and, in the
seed implementation, re-compiling) the whole NSGA-II program per array
size.  Here the full (array_size x seed) sweep is ONE compilation and ONE
device program: every per-cell quantity (array size, gene bounds,
calibration constants) is a traced operand (`nsga2.SpaceOperands`), so
`nsga2.run_cell` is `jax.vmap`-ed over a stacked operand tree and the
generation loop scans over the whole population stack at once.

`explore()` / `explore_sizes()` in `repro.core.explorer` are thin wrappers
over `explore_batch`; `nsga2.run` remains the non-vmapped sequential
reference, and the batched sweep returns bit-identical per-cell fronts
(same RNG stream, same generation program, mapped).

Trace accounting: compiling the sweep bumps `nsga2.TRACE_COUNTS
["run_cell"]` exactly once per program signature — asserted by
`tests/test_batched_explorer.py` and recorded by
`benchmarks/explorer_bench.py`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nsga2
from repro.core.constants import CAL28, CalibConstants


@functools.partial(jax.jit, static_argnames=("statics", "n_gens"))
def sweep_program(keys, spaces, *, statics: nsga2.EvolveStatics, n_gens: int):
    """The one compiled sweep: vmap of the full per-cell NSGA-II run."""
    cell = functools.partial(nsga2.run_cell, statics=statics, n_gens=n_gens)
    return jax.vmap(cell)(keys, spaces)


def stack_spaces(spaces) -> nsga2.SpaceOperands:
    """Stack per-cell `SpaceOperands` trees into one batched operand tree."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *spaces)


def explore_cells(cells, *, pop_size: int = 256, generations: int = 80,
                  crossover_prob: float = nsga2.DEFAULT_CROSSOVER_PROB,
                  mutation_prob: float = nsga2.DEFAULT_MUTATION_PROB,
                  cal: CalibConstants = CAL28,
                  use_pallas_dominance: bool = False,
                  use_pallas_rank: bool = False,
                  program=None) -> dict:
    """Sweep an explicit (array_size, seed) cell list in one device program.

    The engine entry point under `repro.api.DesignSession` (which coalesces
    concurrent requests into one cell list) and `explore_batch` (which
    crosses sizes x seeds).  Returns {(array_size, seed): ParetoResult} —
    per-cell deduplicated Pareto fronts, identical to what the sequential
    per-size path (`nsga2.run` + the legacy `explorer.explore`) produces
    for the same cell.

    `program` optionally injects a pre-built sweep callable
    (keys, spaces) -> (genes, objs) — the session's program cache — and
    defaults to the module-level `sweep_program`.
    """
    from repro.core import explorer  # deferred: explorer wraps this module

    cells = list(dict.fromkeys((int(s), int(sd)) for s, sd in cells))
    if not cells:
        raise ValueError("explore_cells needs at least one (size, seed) cell")
    if program is None:
        statics = nsga2.EvolveStatics(
            pop_size=pop_size, crossover_prob=crossover_prob,
            mutation_prob=mutation_prob,
            use_pallas_dominance=use_pallas_dominance,
            use_pallas_rank=use_pallas_rank)
        program = functools.partial(sweep_program, statics=statics,
                                    n_gens=generations)
    spaces = stack_spaces([
        nsga2.space_operands(nsga2.NSGA2Config(array_size=s, cal=cal))
        for s, _ in cells])
    keys = jnp.stack([jax.random.key(sd) for _, sd in cells])
    genes_b, objs_b = program(keys, spaces)
    genes_b = np.asarray(genes_b)
    objs_b = np.asarray(objs_b)
    return {
        (s, sd): explorer.pareto_result_from_population(
            s, genes_b[i], objs_b[i], cal=cal)
        for i, (s, sd) in enumerate(cells)
    }


def explore_batch(sizes=(4096, 16384, 65536), seeds=(0,), *,
                  pop_size: int = 256, generations: int = 80,
                  crossover_prob: float = nsga2.DEFAULT_CROSSOVER_PROB,
                  mutation_prob: float = nsga2.DEFAULT_MUTATION_PROB,
                  cal: CalibConstants = CAL28,
                  use_pallas_dominance: bool = False,
                  use_pallas_rank: bool = False) -> dict:
    """Sweep every (array_size, seed) cell in one compiled device program.

    Thin cross-product wrapper over `explore_cells`.
    """
    sizes = tuple(int(s) for s in sizes)
    seeds = tuple(int(s) for s in seeds)
    if not sizes or not seeds:
        raise ValueError(
            f"explore_batch needs at least one (size, seed) cell; got "
            f"sizes={sizes!r}, seeds={seeds!r}")
    return explore_cells([(s, sd) for s in sizes for sd in seeds],
                         pop_size=pop_size, generations=generations,
                         crossover_prob=crossover_prob,
                         mutation_prob=mutation_prob, cal=cal,
                         use_pallas_dominance=use_pallas_dominance,
                         use_pallas_rank=use_pallas_rank)
