"""GPipe pipeline over a host-device mesh (subprocess, 4 stages)."""
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.parallel.pipeline import bubble_fraction

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert bubble_fraction(1, 8) == 0.0


@pytest.mark.slow
def test_pipeline_matches_sequential_and_grads():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_apply

        mesh = jax.make_mesh((4,), ("stage",))
        S, M, B, D = 4, 8, 2, 16
        key = jax.random.key(0)
        w = 0.3 * jax.random.normal(key, (S, D, D))
        xs = jax.random.normal(jax.random.key(1), (M, B, D))

        def stage_fn(wi, x):
            return jnp.tanh(x @ wi)

        out = pipeline_apply(mesh, "stage", stage_fn, w, xs)

        # sequential reference
        ref = xs
        for i in range(S):
            ref = jnp.tanh(ref @ w[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

        # gradient equivalence
        def loss_pipe(w):
            return jnp.sum(pipeline_apply(mesh, "stage", stage_fn, w, xs) ** 2)

        def loss_ref(w):
            y = xs
            for i in range(S):
                y = jnp.tanh(y @ w[i])
            return jnp.sum(y ** 2)

        g1 = jax.grad(loss_pipe)(w)
        g2 = jax.grad(loss_ref)(w)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=1e-4, rtol=1e-4)
        print("OK pipeline fwd+bwd equivalent")
    """)
    import os

    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
