"""The unified `repro.api` front-end: request round-trips, session
caching (zero-retrace contract), service coalescing, artifact equality
with the legacy path, and the deprecation shims."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.api.session import ARTIFACT_SCHEMA
from repro.api import (DesignArtifact, DesignRequest, DesignSession,
                       Requirements, default_session)
from repro.core import explorer, nsga2
from repro.core.batched_explorer import explore_batch
from repro.eda.batched_flow import generate_layouts
from repro.serve.design_service import DesignService

# Small budget shared by most tests: fast, and known (from the batched
# flow tests) to leave >= 2 DRC-clean survivors at 4 kb under REQS.
POP, GENS = 48, 10
REQS = Requirements(min_tops=0.5, min_snr_db=10.0)


def _request(array_size=4096, seed=0, **kw):
    kw.setdefault("pop_size", POP)
    kw.setdefault("generations", GENS)
    return DesignRequest(array_size=array_size, seed=seed, **kw)


def _legacy(req: DesignRequest):
    """The pre-API call sequence: explore -> filter -> generate_layouts."""
    front = explore_batch((req.array_size,), (req.seed,),
                          pop_size=req.pop_size,
                          generations=req.generations,
                          cal=req.cal)[req.cell]
    distilled = (front if req.requirements.is_noop
                 else front.filter(**req.requirements.as_filter_kwargs()))
    rows = None
    if req.layout:
        rows = generate_layouts(distilled.specs, coarse=req.coarse,
                                capacity=req.capacity).metrics_rows()
    return distilled, rows


class TestDesignRequest:
    def test_frozen_hashable_json_roundtrip(self):
        req = _request(requirements=REQS, layout=True)
        again = DesignRequest.from_json(req.to_json())
        assert again == req
        assert hash(again) == hash(req)
        assert again.sha() == req.sha()
        with pytest.raises(dataclasses.FrozenInstanceError):
            req.array_size = 8192

    def test_infinite_thresholds_survive_strict_json(self):
        req = _request(requirements=Requirements(min_tops=1.0))
        d = json.loads(req.to_json())  # default thresholds are +/-inf
        assert d["requirements"]["min_snr_db"] == "-inf"
        assert DesignRequest.from_json(json.dumps(d)) == req
        # an exclude-everything threshold must NOT collapse to a default
        hard = _request(requirements=Requirements(min_tops=float("inf")))
        back = DesignRequest.from_json(hard.to_json())
        assert back == hard and back.sha() != req.sha()

    def test_validation(self):
        with pytest.raises(ValueError):
            DesignRequest(array_size=3000)       # not a power of two
        with pytest.raises(ValueError):
            _request(pop_size=0)

    def test_shape_signature_ignores_operands(self):
        a, b = _request(4096, seed=0), _request(16384, seed=3)
        assert a.shape_signature() == b.shape_signature()
        assert a.explore_key() != b.explore_key()
        assert _request(pop_size=POP + 8).shape_signature() \
            != a.shape_signature()


class TestParetoResultJson:
    def test_from_json_roundtrip(self, tmp_path):
        res = explore_batch((4096,), (0,), pop_size=POP,
                            generations=GENS)[(4096, 0)]
        path = tmp_path / "pareto.json"
        res.to_json(path)
        back = explorer.ParetoResult.from_json(path)
        assert back.array_size == res.array_size
        assert back.specs == res.specs
        assert set(back.metrics) == set(res.metrics)
        for k in res.metrics:
            np.testing.assert_array_equal(back.metrics[k], res.metrics[k])

    def test_empty_frontier_raises_clearly(self):
        res = explore_batch((4096,), (0,), pop_size=POP,
                            generations=GENS)[(4096, 0)]
        empty = res.filter(min_tops=1e9)
        with pytest.raises(ValueError, match="empty Pareto frontier"):
            empty.best("tops")
        with pytest.raises(ValueError, match="empty Pareto frontier"):
            empty.filter(min_tops=1.0)


class TestDesignSession:
    def test_artifact_equals_legacy_path(self):
        req = _request(requirements=REQS, layout=True)
        art = DesignSession().run(req)
        distilled, rows = _legacy(req)
        assert [s.as_tuple() for s in art.pareto.specs] \
            == [s.as_tuple() for s in distilled.specs]
        assert art.pareto.to_rows() == distilled.to_rows()
        assert list(art.layout_rows) == rows
        assert art.layouts is not None and len(art.layouts) == len(distilled)

    def test_zero_retrace_for_repeat_and_shape_compatible_requests(self):
        jax.clear_caches()   # order-independent: force a fresh compile
        ses = DesignSession()
        before = nsga2.TRACE_COUNTS["run_cell"]
        req = _request(4096, layout=False)
        a1 = ses.run(req)
        assert nsga2.TRACE_COUNTS["run_cell"] - before == 1
        assert a1.provenance.new_traces == 1
        # repeat request: front cache, no dispatch, no trace
        a2 = ses.run(req)
        assert nsga2.TRACE_COUNTS["run_cell"] - before == 1
        assert a2.provenance.front_cache_hit
        assert a2.provenance.explorer_dispatches == 0
        # shape-signature-compatible variants (size and seed are traced
        # operands): new dispatches, ZERO new traces
        a3 = ses.run(dataclasses.replace(req, array_size=16384))
        a4 = ses.run(dataclasses.replace(req, seed=7))
        assert nsga2.TRACE_COUNTS["run_cell"] - before == 1
        assert a3.provenance.new_traces == 0
        assert a4.provenance.explorer_dispatches == 1
        assert ses.stats["program_cache_hits"] >= 2
        assert a1.pareto.to_rows() == a2.pareto.to_rows()

    def test_requirements_removing_everything(self):
        ses = DesignSession()
        req = _request(requirements=Requirements(min_tops=1e9), layout=True)
        with pytest.raises(ValueError, match="removed every Pareto point"):
            ses.run(req)
        # without layout the empty distilled front is a valid answer
        art = ses.run(dataclasses.replace(req, layout=False))
        assert len(art.pareto) == 0 and art.layout_rows is None

    def test_artifact_json_roundtrip(self, tmp_path):
        req = _request(requirements=REQS, layout=True)
        art = DesignSession().run(req)
        path = tmp_path / "artifact.json"
        art.to_json(path)
        back = DesignArtifact.from_json(path)
        assert back.request == req
        assert back.summary() == art.summary()
        assert back.provenance == art.provenance
        assert back.layouts is None   # tensors are not serialized

    def test_route_provenance_columns(self):
        req = _request(requirements=REQS, layout=True)
        art = DesignSession().run(req)
        p = art.provenance
        # the auto engine choice is recorded: conflict-aware concurrent
        # scheduler off-TPU, scanned per-slot wavefronts on TPU
        expected = "scan" if jax.default_backend() == "tpu" else "concurrent"
        assert p.route_engine == expected
        assert p.route_rounds > 0 and p.route_collisions >= 0
        d = art.to_dict()
        assert d["schema"] == ARTIFACT_SCHEMA >= 4
        for k in ("route_engine", "route_rounds", "route_collisions"):
            assert k in d["provenance"]

    def test_mesh_provenance_columns(self):
        req = _request(requirements=REQS, layout=True, islands=2,
                       migrate_every=5)
        session = DesignSession()
        art = session.run(req)
        p = art.provenance
        assert p.served_from == "explorer"
        assert p.islands == 2 and p.migration_topology == "ring"
        assert p.mesh_devices >= 1 and p.migration_rounds == 1
        assert session.stats["mesh_dispatches"] == 1
        d = art.to_dict()
        for k in ("mesh_devices", "islands", "migration_topology",
                  "migration_rounds"):
            assert k in d["provenance"]
        # islands=1 requests never touch the mesh engine by default
        plain = session.run(_request(seed=3))
        assert plain.provenance.migration_topology == ""
        assert session.stats["mesh_dispatches"] == 1


class TestDesignService:
    def test_coalesces_concurrent_requests_into_one_dispatch(self):
        reqs = [_request(4096, seed=0, requirements=REQS, layout=True),
                _request(4096, seed=1, requirements=REQS, layout=True)]
        svc = DesignService()
        tickets = [svc.submit(r) for r in reqs]
        done = svc.run()
        assert svc.stats()["explorer_dispatches"] == 1
        for r, t in zip(reqs, tickets):
            art = done[t]
            assert art.provenance.coalesced == 2
            # grid-shape buckets never exceed the distinct shapes of the
            # request's own surviving specs
            assert 1 <= art.provenance.layout_dispatches <= len(art.pareto)
            distilled, rows = _legacy(r)
            assert art.pareto.to_rows() == distilled.to_rows()
            assert list(art.layout_rows) == rows

    def test_bucketing_bounded_by_distinct_grid_shapes(self):
        from repro.api.session import _bucket_key, _grid_sig

        reqs = [_request(4096, seed=0, requirements=REQS, layout=True),
                _request(4096, seed=1, requirements=REQS, layout=True)]
        svc = DesignService()
        for r in reqs:
            svc.submit(r)
        done = svc.run()
        buckets = {_bucket_key(s, art.request.coarse, art.request.capacity)
                   for art in done.values() for s in art.pareto.specs}
        exact = {(art.request.coarse, art.request.capacity)
                 + _grid_sig(s, art.request.coarse)
                 for art in done.values() for s in art.pareto.specs}
        assert svc.stats()["layout_dispatches"] == len(buckets)
        # quantization merges exact shapes, never splits them
        assert len(buckets) <= len(exact) <= sum(
            len(a.pareto) for a in done.values())

    def test_max_coalesce_splits_batches(self):
        svc = DesignService(max_coalesce=1)
        for sd in range(2):
            svc.submit(_request(4096, seed=sd, layout=False))
        svc.run()
        assert svc.stats()["explorer_dispatches"] == 2

    def test_poison_request_cannot_starve_the_batch(self):
        svc = DesignService()
        bad = svc.submit(_request(
            4096, requirements=Requirements(min_tops=1e9), layout=True))
        good = svc.submit(_request(4096, seed=1, requirements=REQS,
                                   layout=True))
        done = svc.run()
        assert len(svc) == 0
        assert not done[bad].ok
        assert "removed every Pareto point" in done[bad].error
        assert done[bad].layout_rows is None and len(done[bad].pareto) == 0
        assert done[good].ok and len(done[good].layout_rows) >= 2

    def test_tickets_demux_to_their_own_requests(self):
        svc = DesignService()
        ra = _request(4096, seed=0, layout=False)
        rb = _request(16384, seed=0, layout=False)
        ta, tb = svc.submit(ra), svc.submit(rb)
        done = svc.run()
        assert done[ta].pareto.array_size == 4096
        assert done[tb].pareto.array_size == 16384
        assert svc.collect(ta) is done[ta]


class TestDeprecationShims:
    def test_explore_warns_and_matches_api(self):
        with pytest.deprecated_call():
            res = explorer.explore(4096, pop_size=POP, generations=GENS)
        art = default_session().run(_request(4096, layout=False))
        assert res.to_rows() == art.pareto.to_rows()

    def test_explore_sizes_warns(self):
        with pytest.deprecated_call():
            # crossover_prob/mutation_prob were explore_batch kwargs; the
            # request type carries them so old call sites keep working
            out = explorer.explore_sizes((4096, 16384), pop_size=POP,
                                         generations=GENS,
                                         crossover_prob=0.8)
        assert set(out) == {4096, 16384}

    def test_distill_and_layout_warns_and_matches(self):
        with pytest.deprecated_call():
            distilled, layouts = explorer.distill_and_layout(
                4096, pop_size=POP, generations=GENS,
                min_tops=0.5, min_snr_db=10.0)
        assert len(distilled) == len(layouts) >= 2
