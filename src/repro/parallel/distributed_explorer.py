"""Distributed design-space exploration: island-model NSGA-II over a
device mesh.

The paper explores one array size on one Xeon in ~30 min.  At pod scale
the natural formulation is an island model: every device evolves an
independent NSGA-II population (different seed / array size), with
periodic migration of Pareto elites — embarrassingly parallel evaluation
(the estimator is a closed-form vmap) plus one small all-gather per
migration round.  Implemented with shard_map over the flattened mesh; the
per-device program is the same operand-traced `run_cell`/`evolve_from`
step the single-device and batched explorers use, so the island sweep
shares their one-compile contract: `run_round` and `evolve` are each
traced exactly once, regardless of the number of migration rounds (the
seed implementation re-defined — and therefore re-traced — the evolve
closure inside the round loop).

This is the "agile exploration" story at framework scale: one pod sweep
covers every (array size x seed x SNR-floor) cell a deployment would ask
for, in one step's wall-clock.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import nsga2, pareto
from repro.parallel.axes import shard_map
from repro.core.constants import CAL28


def _axis_names(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def explore_islands(mesh: Mesh, array_size: int, *, pop_size: int = 64,
                    generations: int = 30, migrate_every: int = 10,
                    seed: int = 0, cal=CAL28):
    """Run one NSGA-II island per device; migrate elites via all-gather.

    Returns (genes (n_islands*P, 3), objs (n_islands*P, 4)) host arrays —
    the union population; the global Pareto front is extracted by the
    caller (`pareto.non_dominated_mask`).
    """
    cfg = nsga2.NSGA2Config(array_size=array_size, pop_size=pop_size,
                            generations=migrate_every, cal=cal)
    statics = nsga2.EvolveStatics.from_config(cfg)
    space = nsga2.space_operands(cfg)
    n_dev = int(np.prod(list(mesh.shape.values())))
    axes = _axis_names(mesh)
    spec_island = P(axes)          # leading dim sharded over all axes
    spec_repl = P()                # design-space operands: replicated

    @functools.partial(
        shard_map, mesh=mesh, check_vma=False,
        in_specs=(spec_island, spec_repl),
        out_specs=(spec_island, spec_island))
    def run_round(keys, space):
        genes, objs = nsga2.run_cell(keys[0], space, statics=statics,
                                     n_gens=cfg.generations)
        return genes[None], objs[None]

    @functools.partial(
        shard_map, mesh=mesh, check_vma=False,
        in_specs=(spec_island, spec_island, spec_island, spec_repl),
        out_specs=(spec_island, spec_island))
    def evolve(keys, genes, objs, space):
        """Continue evolving migrated populations (defined ONCE, traced
        once; the migrated population is re-ranked a single time at entry
        via `evolve_from`)."""
        g, o = nsga2.evolve_from(keys[0], genes[0], objs[0], space, statics,
                                 cfg.generations)
        return g[None], o[None]

    @functools.partial(
        shard_map, mesh=mesh, check_vma=False,
        in_specs=(spec_island, spec_island, spec_island),
        out_specs=(spec_island, spec_island))
    def migrate(keys, genes, objs):
        """All-gather elites from every island; replace worst locals."""
        g, o = genes[0], objs[0]
        ranks = pareto.non_dominated_rank(o)
        crowd = pareto.crowding_distance(o, ranks)
        order = jnp.lexsort((-crowd, ranks))
        n_elite = max(2, cfg.pop_size // 8)
        elite_g = g[order[:n_elite]]
        elite_o = o[order[:n_elite]]
        all_g = elite_g
        all_o = elite_o
        for ax in axes:
            all_g = jax.lax.all_gather(all_g, ax).reshape(-1, g.shape[-1])
            all_o = jax.lax.all_gather(all_o, ax).reshape(-1, o.shape[-1])
        # replace the worst |migrants| locals with gathered elites
        n_mig = min(all_g.shape[0], cfg.pop_size // 2)
        key = keys[0]
        pick = jax.random.choice(key, all_g.shape[0], (n_mig,), replace=False)
        g = g.at[order[-n_mig:]].set(all_g[pick])
        o = o.at[order[-n_mig:]].set(all_o[pick])
        return g[None], o[None]

    def _island_keys(s: int):
        k = jax.random.split(jax.random.key(s), n_dev)
        return jax.device_put(k, NamedSharding(mesh, spec_island))

    rounds = max(1, generations // migrate_every)
    genes, objs = run_round(_island_keys(seed), space)
    for r in range(rounds - 1):
        genes, objs = migrate(_island_keys(seed + 1000 + r), genes, objs)
        # continue evolving from migrated populations
        genes, objs = evolve(_island_keys(seed + 2000 + r), genes, objs, space)

    g = np.asarray(jax.device_get(genes)).reshape(-1, 3)
    o = np.asarray(jax.device_get(objs)).reshape(-1, 4)
    return g, o


def pareto_front_of(genes: np.ndarray, objs: np.ndarray):
    uniq, idx = np.unique(genes, axis=0, return_index=True)
    ou = objs[idx]
    mask = np.asarray(pareto.non_dominated_mask(jnp.asarray(ou)))
    return uniq[mask], ou[mask]
