"""Persistent, cross-process artifact cache keyed by `DesignRequest.sha()`.

The in-memory caches of `repro.api.session.DesignSession` (compiled
programs, Pareto fronts) die with the process; this is the third tier
that does not: a directory of artifact JSON files that any number of
sessions — in any number of processes, on a shared filesystem — read
before exploring and write after each run.  A warm second process
serves a repeat request with **zero** explorer dispatches
(`tests/test_design_service_async.py` asserts this through a real
subprocess).

Layout (documented in `docs/benchmarks.md`):

    <root>/<request.sha()>.json     one complete DesignArtifact dump

Each entry is exactly `DesignArtifact.to_dict()` — it carries a
top-level `"schema"` stamp (`repro.api.session.ARTIFACT_SCHEMA`) and
the full request dict, so `get()` can reject entries written by a
different schema generation and guard the truncated-sha key against
collisions by comparing the embedded request with the queried one.

Concurrency: writes go through `DesignArtifact.to_json`'s temp-file +
`os.replace` path, so readers only ever observe complete files — two
processes racing to fill the same key both succeed, last writer wins
with identical content.  A corrupt / half-migrated / foreign file is a
counted miss (`cache.stats["rejects"]`, alongside `"hits"`/
`"misses"`/`"writes"` — the session mirrors hits/misses/writes into
its own `stats` as `artifact_cache_*`), never an exception: the caller
just recomputes and overwrites it.
"""
from __future__ import annotations

import collections
import json
import os
import pathlib

from repro.api.request import DesignRequest
from repro.api.session import ARTIFACT_SCHEMA, DesignArtifact


class ArtifactCache:
    """Disk store of `DesignArtifact`s, keyed by `DesignRequest.sha()`."""

    def __init__(self, root) -> None:
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.stats: collections.Counter = collections.Counter()

    def path_for(self, request: DesignRequest) -> pathlib.Path:
        return self.root / f"{request.sha()}.json"

    def get(self, request: DesignRequest) -> DesignArtifact | None:
        """The cached artifact for `request`, or `None` on any kind of
        miss (absent, unreadable, schema skew, sha collision)."""
        path = self.path_for(request)
        try:
            with open(path) as f:
                d = json.load(f)
        except FileNotFoundError:
            self.stats["misses"] += 1
            return None
        except (OSError, json.JSONDecodeError):
            self.stats["misses"] += 1
            self.stats["rejects"] += 1
            return None
        if (not isinstance(d, dict)
                or d.get("schema") != ARTIFACT_SCHEMA
                or d.get("request") != request.to_dict()):
            self.stats["misses"] += 1
            self.stats["rejects"] += 1
            return None
        try:
            artifact = DesignArtifact.from_dict(d)
        except (KeyError, TypeError, ValueError):
            self.stats["misses"] += 1
            self.stats["rejects"] += 1
            return None
        self.stats["hits"] += 1
        return artifact

    def put(self, artifact: DesignArtifact) -> pathlib.Path:
        """Store (atomically); returns the entry path."""
        path = self.path_for(artifact.request)
        artifact.to_json(path)
        self.stats["writes"] += 1
        return path

    def __contains__(self, request: DesignRequest) -> bool:
        return self.path_for(request).exists()

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("*.json"))

    def clear(self) -> int:
        """Drop every entry; returns how many were removed."""
        n = 0
        for path in self.root.glob("*.json"):
            try:
                os.unlink(path)
                n += 1
            except OSError:
                pass
        return n

    def __repr__(self) -> str:
        return f"ArtifactCache(root={str(self.root)!r}, entries={len(self)})"
