"""Template-based netlist generator (paper Sec. 3.3, "straightforward
engineering process" — spelled out here).

Hierarchy mirrors the synthesizable architecture (Fig. 6):
  macro
    column[j]  (x W)
      local_array[i]  (x H/L): L SRAM8T cells sharing one CAPLC
      rblsw[g]: CMOS switches isolating SAR cap groups on the RBL
      comp, sarlogic, dff[b] (x B_ADC): the column ADC
    rowdrv[r] (x H): RWL drivers shared across columns
Nets: per-column RBL (caps + switches + comparator), per-row RWL
(driver -> every column's cell in that row), SAR control P/N per column,
global CLK/RST.
"""
from __future__ import annotations

import dataclasses

from repro.core.acim_spec import MacroSpec

# RWL rows beyond this are uniform repeats; netlist, placer and router
# all instantiate/route only this many row drivers to bound model size.
MAX_ROW_DRIVERS = 64


@dataclasses.dataclass(frozen=True)
class Instance:
    name: str
    cell: str


@dataclasses.dataclass(frozen=True)
class Net:
    name: str
    pins: tuple[tuple[str, str], ...]      # (instance_name, pin)


@dataclasses.dataclass(frozen=True)
class Netlist:
    spec: MacroSpec
    instances: tuple[Instance, ...]
    nets: tuple[Net, ...]

    def stats(self) -> dict:
        kinds: dict[str, int] = {}
        for inst in self.instances:
            kinds[inst.cell] = kinds.get(inst.cell, 0) + 1
        return {"instances": len(self.instances), "nets": len(self.nets),
                "by_cell": kinds}


def stats_for_spec(spec: MacroSpec) -> dict:
    """Closed-form `Netlist.stats()` without building the instance list.

    The hierarchy is regular, so the counts are pure arithmetic in the
    spec; the batched layout flow (`repro.eda.batched_flow`) uses this to
    skip the per-instance Python of `generate` entirely.  Equality with
    `generate(spec).stats()` is asserted in tests/test_eda.py.
    """
    n_la = spec.n_caps
    n_sw = len(spec.sar_groups()) - 1
    n_rd = min(spec.h, MAX_ROW_DRIVERS)
    by_cell = {
        "CAPLC": spec.w * n_la,
        "SRAM8T": spec.w * spec.h,
        "RBLSW": spec.w * n_sw,
        "COMP": spec.w,
        "SARLOGIC": spec.w,
        "DFF": spec.w * spec.b_adc,
        "ROWDRV": n_rd,
    }
    by_cell = {k: v for k, v in by_cell.items() if v}
    return {"instances": sum(by_cell.values()),
            "nets": spec.w * (spec.h + 3) + n_rd,
            "by_cell": by_cell}


def generate(spec: MacroSpec) -> Netlist:
    insts: list[Instance] = []
    nets: list[Net] = []
    n_la = spec.n_caps                      # local arrays per column
    groups = spec.sar_groups()

    for j in range(spec.w):
        col = f"c{j}"
        rbl_pins: list[tuple[str, str]] = []
        for i in range(n_la):
            cap = f"{col}_la{i}_cap"
            insts.append(Instance(cap, "CAPLC"))
            rbl_pins.append((cap, "BOT"))
            for k in range(spec.l):
                cell = f"{col}_la{i}_s{k}"
                insts.append(Instance(cell, "SRAM8T"))
                nets.append(Net(f"{col}_la{i}_top{k}",
                                ((cell, "RBL"), (cap, "TOP"))))
        # SAR group isolation switches along the RBL (paper Sec. 3.1)
        for g in range(len(groups) - 1):
            sw = f"{col}_sw{g}"
            insts.append(Instance(sw, "RBLSW"))
            rbl_pins.append((sw, "A"))
        comp = f"{col}_comp"
        sar = f"{col}_sar"
        insts.append(Instance(comp, "COMP"))
        insts.append(Instance(sar, "SARLOGIC"))
        rbl_pins.append((comp, "INP"))
        nets.append(Net(f"{col}_rbl", tuple(rbl_pins)))
        nets.append(Net(f"{col}_cmp", ((comp, "OUT"), (sar, "CMP"))))
        dff_pins = []
        for b in range(spec.b_adc):
            dff = f"{col}_dff{b}"
            insts.append(Instance(dff, "DFF"))
            dff_pins.append((dff, "D"))
        nets.append(Net(f"{col}_sar_bus", tuple([(sar, "DOUT")] + dff_pins)))

    # row drivers: one RWL per row crossing every column
    for r in range(min(spec.h, MAX_ROW_DRIVERS)):  # see MAX_ROW_DRIVERS;
        drv = f"rd{r}"                      # keep netlist size bounded, the
        insts.append(Instance(drv, "ROWDRV"))  # row template is uniform
        pins = [(drv, "OUT")]
        la, k = divmod(r, spec.l)
        for j in range(spec.w):
            pins.append((f"c{j}_la{la}_s{k}", "RWL"))
        nets.append(Net(f"rwl{r}", tuple(pins)))

    return Netlist(spec, tuple(insts), tuple(nets))
