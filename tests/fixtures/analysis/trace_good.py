"""Fixture: the clean spellings of everything `trace_bad.py` does wrong.

The trace-purity pass must produce zero findings here: sets are sorted
before iteration, array stores go through ``.at[].set()``, and the only
host call sits behind a raising trace guard.
"""
import time

import jax
import jax.numpy as jnp


def _traced(*arrays):
    return any(isinstance(a, jax.core.Tracer) for a in arrays)


@jax.jit
def step(x):
    for k in sorted({1, 2, 3}):      # deterministic iteration order
        x = x + k
    return x.at[0].set(jnp.float32(0))


def timed_eval(x):
    """Host path, fenced: statements after the guard are host-only."""
    if _traced(x):
        raise TypeError("timed_eval is host-only")
    t0 = time.time()
    y = step(x)
    return y, time.time() - t0
