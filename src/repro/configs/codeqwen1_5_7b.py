"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (kv=32, MHA) d_ff=13440
vocab=92416 — qwen1.5 arch (QKV bias).  [hf:Qwen/CodeQwen1.5-7B; hf]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab=92416, head_dim=128,
    norm="rmsnorm", act="silu", mlp_gated=True, attn_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/CodeQwen1.5-7B; hf",
)

REDUCED = dataclasses.replace(
    CONFIG, name="codeqwen-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    head_dim=16,
)
