"""Fixture: every lock-discipline violation family in one class.

* ``count`` is written from the worker-thread root and read from the
  external (public API) root with no common lock -> unguarded-attr;
* ``ab()`` acquires ``_a`` then ``_b`` while ``ba()`` acquires ``_b``
  then ``_a`` -> lock-order inversion;
* ``reenter()`` re-acquires the non-reentrant ``_lock`` -> lock-reacquire.
"""
import threading


class BadService:
    def __init__(self):
        self._lock = threading.Lock()
        self._a = threading.Lock()
        self._b = threading.Lock()
        self.count = 0
        self._worker = None

    def start(self):
        self._worker = threading.Thread(target=self._loop)
        self._worker.start()

    def _loop(self):
        for _ in range(8):
            self.count += 1          # thread-root write, no lock

    def read(self):
        return self.count            # external-root read, no lock

    def ab(self):
        with self._a:
            with self._b:
                return id(self)

    def ba(self):
        with self._b:
            with self._a:
                return id(self)

    def reenter(self):
        with self._lock:
            with self._lock:
                return id(self)
