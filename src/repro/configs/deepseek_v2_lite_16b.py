"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 — MLA kv_lora=512, 2 shared + routed top-6.
[arXiv:2405.04434; hf]

Assignment note: the brief's annotation says "160 routed" but the config
column says "MoE 64e"; we follow the config column (64 routed experts,
matching the HF release) — recorded in DESIGN.md.  All layers are MoE with
2 shared experts (width 1408 each); MLA uses decoupled RoPE (rope_dim=64,
nope 128, v 128) with no q-compression (the Lite variant).
"""
import dataclasses

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, head_dim=128,
    norm="rmsnorm", act="silu", mlp_gated=True,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  capacity_factor=1.3, group_size=256),
    mla=MLAConfig(kv_lora=512, rope_dim=64, nope_dim=128, v_dim=128),
    source="arXiv:2405.04434; hf",
)

REDUCED = dataclasses.replace(
    CONFIG, name="deepseek-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=48, vocab=512,
    head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48, n_shared=1,
                  capacity_factor=1.3, group_size=64),
    mla=MLAConfig(kv_lora=32, rope_dim=8, nope_dim=16, v_dim=16),
)
