"""Shared model building blocks: norms, RoPE, masks, losses, init.

Pure-functional style: parameters are nested dicts of jax arrays; every
block exposes `init_*` and an apply function.  Layer stacks store parameters
with a leading (n_layers, ...) axis and run under `jax.lax.scan`, which keeps
the HLO size O(1) in depth — essential for compiling 88-layer configs on the
production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any

# ---------------------------------------------------------------------------
# dtype policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    params: Any = jnp.float32        # master params (optimizer works in this)
    compute: Any = jnp.bfloat16      # activations / matmul inputs
    accum: Any = jnp.float32         # softmax / norms / losses

    def cast_in(self, x: Array) -> Array:
        return x.astype(self.compute)


DEFAULT_POLICY = DTypePolicy()

# learned-position table size: covers the 32k prefill/decode shapes
MAX_LEARNED_POS = 32768


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(key: Array, shape: tuple[int, ...], in_axis: int = 0,
               dtype=jnp.float32) -> Array:
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key: Array, shape: tuple[int, ...], dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def init_layernorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: dict, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def init_norm(d: int, kind: str) -> dict:
    return init_rmsnorm(d) if kind == "rmsnorm" else init_layernorm(d)


def apply_norm(p: dict, x: Array, kind: str) -> Array:
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, Dh) with positions (..., S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                     # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> Array:
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * dim / d))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# ---------------------------------------------------------------------------
# attention masks
# ---------------------------------------------------------------------------
NEG_INF = -1e9


def causal_mask(s: int) -> Array:
    return jnp.tril(jnp.ones((s, s), jnp.bool_))


def prefix_lm_mask(s: int, prefix_len: int) -> Array:
    """Bidirectional over the first `prefix_len` positions, causal after
    (PaliGemma-style image-prefix attention)."""
    m = causal_mask(s)
    pref = (jnp.arange(s)[None, :] < prefix_len) & (jnp.arange(s)[:, None] < prefix_len)
    return m | pref


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_cross_entropy(logits: Array, labels: Array, *,
                          z_loss: float = 1e-4) -> tuple[Array, dict]:
    """Token-mean CE with optional z-loss (logit-norm regularizer used by
    production LM stacks for bf16 stability).  logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    zl = z_loss * jnp.square(lse)
    loss = jnp.mean(nll + zl)
    metrics = {"nll": jnp.mean(nll), "z_loss": jnp.mean(zl),
               "ppl_proxy": jnp.exp(jnp.minimum(jnp.mean(nll), 20.0))}
    return loss, metrics


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]
