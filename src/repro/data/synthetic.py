"""Deterministic, stateless synthetic token pipeline.

Restart-exact by construction: batch t is a pure function of (seed, step,
shard), via counter-based threefry keys — no iterator state to checkpoint.
Tokens follow a Zipfian marginal with short-range Markov structure so the
LM loss actually decreases (used by the convergence tests and the e2e
training example).

Sharding: `host_batch(step)` returns this process's slice; under jit the
global batch is assembled with `jax.make_array_from_process_local_data` (a
no-op single-process on CPU, the real path on multi-host).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2
    markov_period: int = 64     # learnable short-range structure


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
    p = ranks ** (-cfg.zipf_a)
    return (p / p.sum()).astype(np.float32)


@dataclasses.dataclass
class SyntheticStream:
    cfg: DataConfig

    def __post_init__(self):
        self._probs = jnp.asarray(_zipf_probs(self.cfg))

    def global_batch(self, step: int) -> dict:
        """Full logical batch for `step` (deterministic)."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        k1, k2 = jax.random.split(key)
        base = jax.random.choice(k1, cfg.vocab, (cfg.global_batch, cfg.seq + 1),
                                 p=self._probs)
        # inject periodic copy structure: token[t] = token[t - period] with
        # prob 1/2 -> the model can learn to halve its loss vs unigram
        copy = jax.random.bernoulli(k2, 0.5, base.shape)
        shifted = jnp.roll(base, cfg.markov_period, axis=1)
        toks = jnp.where(copy, shifted, base).astype(jnp.int32)
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}

    def host_batch(self, step: int, *, process_index: int | None = None,
                   process_count: int | None = None) -> dict:
        pi = jax.process_index() if process_index is None else process_index
        pc = jax.process_count() if process_count is None else process_count
        full = self.global_batch(step)
        per = self.cfg.global_batch // pc
        return jax.tree.map(lambda a: a[pi * per:(pi + 1) * per], full)


def batch_for(cfg: ArchConfig, seq: int, global_batch: int, step: int,
              seed: int = 1234) -> dict:
    """Family-complete batch (adds stub frames/patches where assigned)."""
    stream = SyntheticStream(DataConfig(cfg.vocab, seq, global_batch, seed))
    batch = stream.global_batch(step)
    key = jax.random.fold_in(jax.random.key(seed + 7), step)
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (global_batch, cfg.encdec.enc_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = 0.1 * jax.random.normal(
            key, (global_batch, cfg.vlm.n_patches, cfg.d_model), jnp.float32)
    return batch
