"""Pallas TPU kernel for the batched Lee maze-router wavefront.

One program instance per routing grid: the batch is the Pallas grid axis
(`grid=(B,)`), so laying out a whole distilled Pareto set expands B
wavefronts concurrently — the "parallel BFS" of the batched layout flow
(`repro.eda.batched_flow`).  Each program keeps its (H, W) occupancy,
seed, and distance planes entirely in VMEM and runs the min-plus
relaxation to its fixed point on the VPU:

    dist <- min(dist, 1 + min(N, S, E, W))        on free cells

Neighbour access is expressed as static-slice shifts (concatenate with
an `INF` edge row/lane), which lowers to cheap sublane/lane shifts —
there is no gather and no host queue.  The loop terminates when a sweep
changes nothing; every sweep advances the frontier one step, so the trip
count is the largest finite distance, bounded by H * W.

Semantics match `repro.kernels.maze_route.ref.wavefront_distance_ref`
exactly (seeds pinned to 0 even when occupied; blocked cells never
relax), and the wrapper in `ops.py` pads grids to TPU tile multiples
with blocked cells, which cannot perturb distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.maze_route.ref import INF


def _shift(x: jax.Array, dy: int, dx: int) -> jax.Array:
    """Shift a (H, W) plane by (dy, dx), filling the exposed edge with INF."""
    h, w = x.shape
    if dy == 1:
        x = jnp.concatenate([jnp.full((1, w), INF, x.dtype), x[:-1]], 0)
    elif dy == -1:
        x = jnp.concatenate([x[1:], jnp.full((1, w), INF, x.dtype)], 0)
    if dx == 1:
        x = jnp.concatenate([jnp.full((h, 1), INF, x.dtype), x[:, :-1]], 1)
    elif dx == -1:
        x = jnp.concatenate([x[:, 1:], jnp.full((h, 1), INF, x.dtype)], 1)
    return x


def _kernel(occ_ref, seed_ref, dist_ref):
    occ = occ_ref[0] != 0
    seed = seed_ref[0] != 0
    free = jnp.logical_and(jnp.logical_not(occ), jnp.logical_not(seed))
    dist0 = jnp.where(seed, 0, INF).astype(jnp.int32)

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        dist, _ = state
        best = jnp.minimum(
            jnp.minimum(_shift(dist, 1, 0), _shift(dist, -1, 0)),
            jnp.minimum(_shift(dist, 0, 1), _shift(dist, 0, -1))) + 1
        nxt = jnp.where(free, jnp.minimum(dist, best), dist)
        return nxt, jnp.any(nxt < dist)

    dist, _ = jax.lax.while_loop(cond, body, (dist0, jnp.bool_(True)))
    dist_ref[0] = dist


@functools.partial(jax.jit, static_argnames=("interpret",))
def wavefront_kernel(occ: jax.Array, seed: jax.Array, *,
                     interpret: bool = False) -> jax.Array:
    """occ, seed: (B, H, W) int8 with H % 8 == 0, W % 128 == 0 (pad with
    blocked cells; see ops).  Returns (B, H, W) int32 BFS distances."""
    b, h, w = occ.shape
    assert h % 8 == 0 and w % 128 == 0, (h, w)
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), jnp.int32),
        interpret=interpret,
    )(occ.astype(jnp.int8), seed.astype(jnp.int8))
