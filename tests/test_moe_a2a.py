"""shard_map all-to-all MoE vs drop-free reference (subprocess, 8 devices)."""
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_a2a_moe_matches_dropfree():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry as creg
        from repro.models import mlp
        from repro.parallel.moe_a2a import moe_fwd_a2a

        cfg = creg.reduced("arctic_480b")      # 8 experts, top-2, dense_ff
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p = mlp.init_moe(jax.random.key(0), cfg.d_model, cfg)
        x = 0.5 * jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))

        ref = mlp.moe_fwd_dense_eval(p, x, cfg)          # drop-free oracle
        y = moe_fwd_a2a(p, x, cfg, mesh, capacity=512)   # no drops
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=2e-3, rtol=2e-3)
        print("OK a2a MoE == drop-free reference")
    """)
    import os

    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
