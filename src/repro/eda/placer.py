"""Template-based hierarchical placer (paper Sec. 3.3, Fig. 7) as a
data-oriented template expansion.

Bottom-up, per the paper: inside each hierarchy level only the child
blocks are placed (their internals are opaque); the final macro layout
composes pre-placed templates.

  L0  local array:  L SRAM cells in a vertical strip + CAPLC alongside
  L1  column:       H/L local arrays stacked; ADC periphery (switches,
                    comparator, SAR logic, DFFs) at the column foot —
                    the peripheral ORDER is optimized (exhaustive/greedy
                    HPWL over the RBL/SAR nets, standing in for the
                    grid-based optimization of [25-27])
  L2  macro:        W columns abutted; row drivers on the left edge

Since PR 2 the expansion itself is array-programmed, in the
`nsga2.SpaceOperands` style: everything that varies per design point is
a traced scalar operand (`LayoutOperands`), everything structural is
static (`PlacerGeometry` from the cell library, `BatchDims` padded index
extents), and `rect_tensors` produces the absolute rectangles for every
template category as jnp index-grid broadcasts — no per-rect Python.
`repro.eda.batched_flow` vmaps `rect_tensors` over a stacked operand
tree to place a whole Pareto set in one dispatch; the classic
`place(spec)` entry point evaluates the same tensors at the spec's exact
extents and attaches instance names, so the sequential and batched paths
are equal by construction.

Every placement is in absolute rectangles on the F grid.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acim_spec import MacroSpec
from repro.eda.cells import Cell, library
from repro.eda.netlist import MAX_ROW_DRIVERS

Array = jax.Array

# Template categories of the expansion, in flat-concatenation order.
CATEGORIES = ("sram", "cap", "sw", "comp", "sar", "dff", "rd")
# Cell kind backing each category (index into the cell library).
CATEGORY_CELL = {"sram": "SRAM8T", "cap": "CAPLC", "sw": "RBLSW",
                 "comp": "COMP", "sar": "SARLOGIC", "dff": "DFF",
                 "rd": "ROWDRV"}


@dataclasses.dataclass(frozen=True)
class Placed:
    name: str
    cell: str
    x: int
    y: int
    w: int
    h: int

    @property
    def cx(self) -> float:
        return self.x + self.w / 2

    @property
    def cy(self) -> float:
        return self.y + self.h / 2


@dataclasses.dataclass
class Placement:
    spec: MacroSpec
    rects: list[Placed]
    width: int
    height: int

    @property
    def area_f2(self) -> int:
        return self.width * self.height

    def area_f2_per_bit(self) -> float:
        return self.area_f2 / self.spec.array_size


# ----------------------------------------------------------------------
# Static geometry (cell library) and traced per-spec operands
# ----------------------------------------------------------------------
class PlacerGeometry(NamedTuple):
    """Hashable, design-point-independent geometry from the cell library."""

    s_w: int            # SRAM8T footprint
    s_h: int
    c_w: int            # CAPLC footprint
    c_h: int
    col_w: int          # column pitch: SRAM strip + cap alongside
    order: tuple[str, ...]          # optimized periphery order (4 kinds)
    pitch: tuple[tuple[str, int], ...]   # kind -> pitch-matched height
    drv_w: int          # ROWDRV footprint
    drv_h: int
    xshift: int         # columns sit right of the driver strip

    def pitch_of(self, kind: str) -> int:
        return dict(self.pitch)[kind]


class LayoutOperands(NamedTuple):
    """Traced per-design-point scalars of the template expansion.

    All leaves are () int32 arrays, so a layout batch is just a tree of
    stacked leaves and `rect_tensors` vmaps over it without retracing
    (`repro.eda.batched_flow.stack_layout_operands`).
    """

    h: Array            # array height (cells per column)
    w: Array            # columns
    l: Array            # local-array size
    b_adc: Array        # ADC bits
    n_la: Array         # local arrays per column == H/L
    n_sw: Array         # RBL isolation switches per column
    la_h: Array         # local-array template height
    array_h: Array      # cell-array region height
    y_sw: Array         # periphery offsets below the array, per kind
    y_comp: Array
    y_sar: Array
    y_dff: Array
    cap_y: Array        # cap vertical centering inside the local array
    drv_pitch: Array    # row-driver vertical pitch
    n_rd: Array         # instantiated row drivers (min(H, 64))
    width: Array        # macro bounding box
    height: Array


class BatchDims(NamedTuple):
    """Static (shape-determining) index extents of the rect tensors —
    per-spec exact for `place`, per-batch maxima for the batched flow.

    SRAM cells are indexed (column, row) rather than (column, local
    array, cell): padding maxima multiply, and `n_la * l` factors of
    *different* specs can vastly exceed any real `h = n_la * l`, while
    the row extent is bounded by `max(h)` no matter how the batch mixes
    local-array sizes."""

    w: int
    h: int
    n_la: int
    l: int
    n_sw: int
    b: int
    rd: int

    @classmethod
    def for_specs(cls, specs) -> "BatchDims":
        return cls(
            w=max(s.w for s in specs),
            h=max(s.h for s in specs),
            n_la=max(s.n_caps for s in specs),
            l=max(s.l for s in specs),
            n_sw=max(_n_switches(s) for s in specs),
            b=max(s.b_adc for s in specs),
            rd=max(min(s.h, MAX_ROW_DRIVERS) for s in specs),
        )


def _n_switches(spec: MacroSpec) -> int:
    return len(spec.sar_groups()) - 1


def _periph_order(lib: dict[str, Cell]) -> tuple[str, ...]:
    """Order the column periphery to minimize RBL/SAR-bus HPWL.

    The RBL enters from the top (array side): switches must sit nearest,
    then comparator, SAR logic, DFF chain.  We search all orders of the 4
    kinds (4! = 24) and keep the HPWL-minimal one — a miniature of the
    paper's grid-based placement optimization, with the interconnection
    model: RBL touches SW+COMP from the top; CMP->SAR; SAR->DFFs.
    """
    kinds = ["RBLSW", "COMP", "SARLOGIC", "DFF"]
    best, best_cost = None, None
    for order in itertools.permutations(kinds):
        y, pos = 0, {}
        for k in order:
            pos[k] = y
            y += lib[k].height
        # HPWL of: RBL (top=0 to SW and COMP), COMP->SAR, SAR->DFF
        cost = (pos["RBLSW"] + lib["RBLSW"].height
                + pos["COMP"] + lib["COMP"].height
                + abs(pos["COMP"] - pos["SARLOGIC"])
                + abs(pos["SARLOGIC"] - pos["DFF"]))
        if best_cost is None or cost < best_cost:
            best, best_cost = order, cost
    return tuple(best)


def geometry(lib: dict[str, Cell] | None = None) -> PlacerGeometry:
    """Fold the cell library into the static expansion geometry.

    Pitch-matched composition: the column periphery (switches,
    comparator+SAR, DFFs) is reshaped to the array column width — the
    standard CIM pitch-matching discipline; Eq. 10's A_COMP/H
    amortization is exactly this geometry.
    """
    lib = lib or library()
    s, c, drv = lib["SRAM8T"], lib["CAPLC"], lib["ROWDRV"]
    col_w = s.width + c.width
    pitch = tuple(
        (k, max(1, (lib[k].area + col_w - 1) // col_w))
        for k in ("RBLSW", "COMP", "SARLOGIC", "DFF"))
    return PlacerGeometry(
        s_w=s.width, s_h=s.height, c_w=c.width, c_h=c.height, col_w=col_w,
        order=_periph_order(lib), pitch=pitch,
        drv_w=drv.width, drv_h=drv.height, xshift=drv.width + 2)


def layout_operands(spec: MacroSpec,
                    geom: PlacerGeometry | None = None) -> LayoutOperands:
    """Fold one design point into the traced operand tree (exact ints)."""
    geom = geom or geometry()
    n_la = spec.n_caps
    n_sw = _n_switches(spec)
    la_h = max(spec.l * geom.s_h, geom.c_h)
    array_h = n_la * la_h
    counts = {"RBLSW": n_sw, "COMP": 1, "SARLOGIC": 1, "DFF": spec.b_adc}
    y, periph_y = 0, {}
    for k in geom.order:
        periph_y[k] = y
        y += counts[k] * geom.pitch_of(k) + 1
    periph_h = y
    i32 = lambda v: jnp.int32(v)  # noqa: E731
    return LayoutOperands(
        h=i32(spec.h), w=i32(spec.w), l=i32(spec.l), b_adc=i32(spec.b_adc),
        n_la=i32(n_la), n_sw=i32(n_sw), la_h=i32(la_h), array_h=i32(array_h),
        y_sw=i32(periph_y["RBLSW"]), y_comp=i32(periph_y["COMP"]),
        y_sar=i32(periph_y["SARLOGIC"]), y_dff=i32(periph_y["DFF"]),
        cap_y=i32((la_h - geom.c_h) // 2),
        drv_pitch=i32(max(la_h // max(spec.l, 1), geom.drv_h)),
        n_rd=i32(min(spec.h, MAX_ROW_DRIVERS)),
        width=i32(spec.w * geom.col_w + geom.drv_w + 2),
        height=i32(array_h + periph_h))


# ----------------------------------------------------------------------
# The vmappable template expansion
# ----------------------------------------------------------------------
def _stack_xywh(x, y, w, h):
    """Broadcast four int32 index-grid planes into a (..., 4) rect tensor."""
    x, y, w, h = jnp.broadcast_arrays(
        *(jnp.asarray(v, jnp.int32) for v in (x, y, w, h)))
    return jnp.stack([x, y, w, h], axis=-1)


def rect_tensors(ops: LayoutOperands, dims: BatchDims,
                 geom: PlacerGeometry) -> dict[str, tuple[Array, Array]]:
    """Expand one design point into per-category rect tensors.

    Returns {category: (rects, mask)} where `rects[..., :]` is
    (x, y, w, h) int32 on the F grid, indexed [j, i, k] (column, local
    array, cell) down to [r] (row driver) per category, and `mask` marks
    entries that exist for this design point (index < the operand
    extent).  Pure function of traced operands — `jax.vmap` it over a
    stacked `LayoutOperands` batch to place many specs in one dispatch.
    """
    j = jnp.arange(dims.w, dtype=jnp.int32)
    row = jnp.arange(dims.h, dtype=jnp.int32)
    i = jnp.arange(dims.n_la, dtype=jnp.int32)
    g = jnp.arange(dims.n_sw, dtype=jnp.int32)
    b = jnp.arange(dims.b, dtype=jnp.int32)
    r = jnp.arange(dims.rd, dtype=jnp.int32)
    col_x = geom.xshift + j * geom.col_w                       # (W,)
    jm, im = j < ops.w, i < ops.n_la

    p_sw, p_comp = geom.pitch_of("RBLSW"), geom.pitch_of("COMP")
    p_sar, p_dff = geom.pitch_of("SARLOGIC"), geom.pitch_of("DFF")

    # row -> (local array, cell-in-array) from the traced L operand
    la_of_row, k_of_row = row // ops.l, row % ops.l
    sram = _stack_xywh(
        col_x[:, None],
        (la_of_row * ops.la_h + k_of_row * geom.s_h)[None, :],
        geom.s_w, geom.s_h)
    cap = _stack_xywh(
        col_x[:, None] + geom.s_w,
        i[None, :] * ops.la_h + ops.cap_y, geom.c_w, geom.c_h)
    sw = _stack_xywh(
        col_x[:, None],
        ops.array_h + ops.y_sw + g[None, :] * p_sw, geom.col_w, p_sw)
    comp = _stack_xywh(col_x, ops.array_h + ops.y_comp, geom.col_w, p_comp)
    sar = _stack_xywh(col_x, ops.array_h + ops.y_sar, geom.col_w, p_sar)
    dff = _stack_xywh(
        col_x[:, None],
        ops.array_h + ops.y_dff + b[None, :] * p_dff, geom.col_w, p_dff)
    rd = _stack_xywh(jnp.zeros_like(r), r * ops.drv_pitch,
                     geom.drv_w, geom.drv_h)

    return {
        "sram": (sram, jm[:, None] & (row < ops.h)[None, :]),
        "cap": (cap, jm[:, None] & im[None, :]),
        "sw": (sw, jm[:, None] & (g < ops.n_sw)[None, :]),
        "comp": (comp, jm),
        "sar": (sar, jm),
        "dff": (dff, jm[:, None] & (b < ops.b_adc)[None, :]),
        "rd": (rd, r < ops.n_rd),
    }


def category_names(cat: str, dims: BatchDims, spec: MacroSpec):
    """Instance names of a category tensor at the spec's *exact* extents
    (`dims == dims_for_spec(spec)`), flattened in index order."""
    if cat == "sram":
        return [f"c{j}_la{r // spec.l}_s{r % spec.l}" for j in range(dims.w)
                for r in range(dims.h)]
    if cat == "cap":
        return [f"c{j}_la{i}_cap" for j in range(dims.w)
                for i in range(dims.n_la)]
    if cat == "sw":
        return [f"c{j}_sw{g}" for j in range(dims.w)
                for g in range(dims.n_sw)]
    if cat == "comp":
        return [f"c{j}_comp" for j in range(dims.w)]
    if cat == "sar":
        return [f"c{j}_sar" for j in range(dims.w)]
    if cat == "dff":
        return [f"c{j}_dff{b}" for j in range(dims.w)
                for b in range(dims.b)]
    if cat == "rd":
        return [f"rd{r}" for r in range(dims.rd)]
    raise KeyError(cat)


def dims_for_spec(spec: MacroSpec) -> BatchDims:
    return BatchDims.for_specs([spec])


def place(spec: MacroSpec) -> Placement:
    """Single-spec placement with named instances.

    Evaluates the same `rect_tensors` expansion the batched flow vmaps,
    at the spec's exact extents (every mask entry true), then attaches
    instance names on the host.
    """
    geom = geometry()
    ops = layout_operands(spec, geom)
    dims = dims_for_spec(spec)
    tensors = rect_tensors(ops, dims, geom)
    rects: list[Placed] = []
    for cat in CATEGORIES:
        vals = np.asarray(tensors[cat][0]).reshape(-1, 4)
        cell = CATEGORY_CELL[cat]
        rects.extend(
            Placed(name, cell, int(x), int(y), int(w), int(h))
            for name, (x, y, w, h)
            in zip(category_names(cat, dims, spec), vals))
    return Placement(spec, rects, int(ops.width), int(ops.height))
