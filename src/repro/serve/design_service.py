"""Multi-tenant design service: a staged-pipeline, deadline-coalescing
front door.

The design-flow counterpart of `repro.serve.engine.ServeEngine`'s slot
model: concurrent users `submit()` `DesignRequest`s and collect
ticketed `DesignArtifact`s, while the service amortizes the heavy work
across tenants.  Two driving modes share one queue:

  * **synchronous drain** — `step()` takes one coalesced batch (up to
    `max_coalesce` requests), `run()` drains everything.  This is the
    PR-3 shape and stays the right tool for scripted batch jobs
    (`explore_sizes`, the benchmarks' cold/warm sweeps).
  * **staged pipeline** — `serve()` starts an admission pump with
    latency-bounded coalescing windows (dispatch at `max_coalesce`
    queued OR `coalesce_window_s` past the oldest request) feeding four
    stage workers over bounded queues:

        admission ─> explore ─> distill ─> layout ─> finalize
                      (batch)    (batch)   (bucket)   (batch)

    Each stage runs the *same* `DesignSession` stage function the
    sequential `run_many` driver uses (`explore_stage`,
    `distill_stage`, `layout_stage`, `finalize_stage` — see
    `repro.api.session`), so pipelined and sequential execution cannot
    diverge: artifacts are ticket-for-ticket equal (asserted in
    `tests/test_design_service_pipeline.py`).  What the pipeline buys
    is **overlap**: batch N+1's exploration runs while batch N's layout
    buckets are still in flight, and layout buckets *stream* — the
    distill worker submits each bucket to the layout worker the moment
    it is formed, instead of blocking until the whole union is laid
    out.  `serve(pipelined=False)` falls back to the PR-4 serial pump
    (one thread, one coalesced batch at a time) for comparison —
    `benchmarks/service_bench.py` records both.

Stage-safety: the `DesignSession` is not thread-safe in general, but
the stages partition its state — only the explore worker touches the
program/front caches, only the distill worker forms buckets, only the
layout worker dispatches layouts, only the finalize worker writes the
artifact cache — and each `stats` counter key has a single writer
stage.  `run()`/`step()` are refused while a pump is active so no
second dispatcher can break that partition.

Failure semantics: a request whose requirements remove every Pareto
point completes with `artifact.error` set (non-strict mode) and cannot
poison its batch.  An *unexpected* exception inside any stage stops
the pipeline (first failure wins): it is surfaced to blocked
`collect()` callers and re-raised from `close()`, and every in-flight
batch is restored — in admission order, at the FRONT of the queue — so
no ticket is lost or reordered.

Accounting: `service.stats()` returns a point-in-time **snapshot** —
session + service counters (`explorer_dispatches`,
`layout_dispatches`, `run_cell_traces`, cache hits/misses, the
`service_batches` / `service_batch_requests` pair whose ratio is the
realized coalescing factor) plus live pipeline gauges (queue depths,
per-stage occupancy and cumulative busy time, and the explore/layout
overlap clock the benchmark's overlap fraction is computed from).
"""
from __future__ import annotations

import collections
import contextlib
import queue
import threading
import time

from repro.api.request import DesignRequest
from repro.api.session import DesignArtifact, DesignSession

_STAGES = ("explore", "distill", "layout", "finalize")


class UnknownTicket(KeyError):
    """Raised for a ticket this service never issued, or whose artifact
    was already collected (and popped — pass `keep_done=True` to keep)."""

    def __str__(self) -> str:  # KeyError repr-quotes its message otherwise
        return self.args[0] if self.args else ""


class PendingTicket(RuntimeError):
    """Raised when a ticket's artifact is not ready: the request is still
    queued or in flight.  Distinct from `UnknownTicket` so callers can
    tell "wait longer / drain the queue" from "you never submitted this"."""


class _Batch:
    """One coalesced batch moving through the staged pipeline."""

    __slots__ = ("entries", "admitted_at", "explored", "distilled",
                 "results", "remaining", "waits")

    def __init__(self, entries):
        self.entries = entries          # [(ticket, request, t_submit)]
        self.admitted_at = time.monotonic()
        self.explored = None            # ExploredBatch after explore
        self.distilled = None           # DistilledBatch after distill
        self.results = []               # [BucketResult], layout worker only
        self.remaining = 0              # buckets not yet laid out
        self.waits = {}                 # request -> explore queue wait (s)


class DesignService:
    """Queue-backed multi-tenant layer over a `DesignSession`."""

    def __init__(self, session: DesignSession | None = None, *,
                 max_coalesce: int = 16, coalesce_window_s: float = 0.05,
                 pipeline_depth: int = 2):
        if max_coalesce <= 0:
            raise ValueError("max_coalesce must be positive")
        if coalesce_window_s < 0:
            raise ValueError("coalesce_window_s must be >= 0")
        if pipeline_depth <= 0:
            raise ValueError("pipeline_depth must be positive")
        self.session = session or DesignSession()
        self.max_coalesce = max_coalesce
        self.coalesce_window_s = coalesce_window_s
        # bound of the per-stage batch queues: how many coalesced batches
        # may be in flight ahead of (and including) the explore stage —
        # the pipeline's lookahead.  Bucket-granular queues are bounded
        # at 4x so a many-bucket batch cannot balloon memory.
        self.pipeline_depth = pipeline_depth
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)   # queue grew / closing
        self._done_cv = threading.Condition(self._lock)  # artifacts landed
        # serializes session access on the synchronous run()/step() path;
        # the pipelined path instead relies on the stage partition of
        # session state (module docstring) and refuses run()/step() while
        # a pump is active
        self._dispatch = threading.Lock()
        self._queue: list[tuple[int, DesignRequest, float]] = []
        self._pending: set[int] = set()   # issued, not yet in `done`
        self._next_ticket = 0
        self.done: dict[int, DesignArtifact] = {}
        self._pump: threading.Thread | None = None
        self._sync_dispatchers = 0   # run()/step() drains in progress
        self._stage_threads: list[threading.Thread] = []
        self._queues: dict[str, queue.Queue] = {}
        self._inflight: list[_Batch] = []   # admitted, not yet finalized
        self._pipelined = False
        self._closing = False
        self._pump_error: BaseException | None = None
        # occupancy clocks (under self._lock): when each stage went busy,
        # cumulative busy seconds, and the explore∧layout overlap clock
        self._busy_since: dict[str, float] = {}
        self._busy_s: collections.Counter = collections.Counter()
        self._overlap_since: float | None = None
        self._overlap_s = 0.0

    # -- accounting ------------------------------------------------------
    def stats(self) -> dict:
        """A point-in-time **snapshot** of counters and pipeline gauges.

        Returns a fresh dict each call (taken under the service lock) —
        mutating it cannot corrupt the service, unlike the live Counter
        view this used to be.  Counter keys come from the session
        (`explorer_dispatches`, `layout_dispatches`, cache hits/misses,
        `service_batches`/`service_batch_requests`, ...); gauge keys:

          * `queue_depth` — submissions not yet admitted to a batch;
          * `inflight_batches` — admitted, not yet finalized;
          * `done_count`, `pump_alive`, `pipelined`;
          * `stage_queue_depth` / `stage_busy` / `stage_busy_s` — per
            stage: items waiting, busy right now, cumulative busy time;
          * `pipeline_overlap_s` — wall-clock during which the explore
            and layout stages were busy *simultaneously*, and
            `pipeline_overlap_fraction` — that, over the smaller of the
            two stages' busy time (0.0 when either never ran).

        The snapshot is a `collections.Counter` copy, so counter keys
        that never fired read as 0 instead of raising."""
        with self._lock:
            now = time.monotonic()
            snap = collections.Counter(self.session.stats)
            snap["queue_depth"] = len(self._queue)
            snap["inflight_batches"] = len(self._inflight)
            snap["done_count"] = len(self.done)
            snap["pump_alive"] = self._pump_alive()
            snap["pipelined"] = self._pipelined
            snap["stage_queue_depth"] = {
                s: (self._queues[s].qsize() if s in self._queues else 0)
                for s in _STAGES}
            snap["stage_busy"] = {s: s in self._busy_since for s in _STAGES}
            busy_s = {s: self._busy_s[s]
                      + (now - self._busy_since[s]
                         if s in self._busy_since else 0.0)
                      for s in _STAGES}
            snap["stage_busy_s"] = busy_s
            overlap = self._overlap_s + (now - self._overlap_since
                                         if self._overlap_since is not None
                                         else 0.0)
            snap["pipeline_overlap_s"] = overlap
            floor = min(busy_s["explore"], busy_s["layout"])
            snap["pipeline_overlap_fraction"] = (overlap / floor
                                                 if floor > 0 else 0.0)
            return snap

    def __len__(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- submission ------------------------------------------------------
    def submit(self, request: DesignRequest) -> int:
        """Enqueue a request; returns the ticket to collect its artifact.

        Thread-safe; wakes the `serve()` pump (if running) so the
        coalescing window starts counting from the oldest queued request."""
        with self._lock:
            if self._closing:
                raise RuntimeError("DesignService is closing; "
                                   "no new submissions accepted")
            if self._pump_error is not None:
                # nothing will serve this ticket: the pipeline stopped.
                # Refuse admission until close() surfaces (and clears)
                # the error.
                raise RuntimeError(
                    "DesignService serve() pump failed; call close() to "
                    "surface the error (in-flight batches are restored to "
                    "the queue), then serve() or run() again"
                ) from self._pump_error
            ticket = self._next_ticket
            self._next_ticket += 1
            self._queue.append((ticket, request, time.monotonic()))
            self._pending.add(ticket)
            self._work.notify_all()
        return ticket

    # -- synchronous drain -----------------------------------------------
    def step(self) -> dict[int, DesignArtifact]:
        """Dispatch one coalesced batch (up to `max_coalesce` requests) and
        return its per-ticket artifacts.

        A request whose requirements remove every Pareto point cannot
        poison the batch: it completes with `artifact.error` set (the
        session's non-strict mode) while the other tenants are served.
        On an unexpected exception the batch is restored — in order, at
        the front of the queue — so no tenant's submission is lost.

        Not valid while a `serve()` pump is running: the pump's stage
        workers are the only dispatchers — use `collect()`/`poll()`."""
        self._begin_sync("step")
        try:
            return self._dispatch_once()
        finally:
            self._end_sync()

    def _begin_sync(self, name: str) -> None:
        """Claim the session for a synchronous run()/step() drain.  Taken
        under the lock so the serve()-vs-sync mutual exclusion is not a
        check-then-act race: serve() refuses while a drain is active,
        and a drain refuses while a pump is alive."""
        with self._lock:
            if self._pump_alive():
                raise RuntimeError(f"{name}() while the serve() pump is "
                                   f"active; the pump is the only "
                                   f"dispatcher — use collect()/poll() "
                                   f"instead")
            self._sync_dispatchers += 1

    def _end_sync(self) -> None:
        with self._lock:
            self._sync_dispatchers -= 1

    def _dispatch_once(self) -> dict[int, DesignArtifact]:
        with self._lock:
            batch = self._queue[:self.max_coalesce]
            del self._queue[:self.max_coalesce]
        if not batch:
            return {}
        try:
            with self._dispatch:
                artifacts = self.session.run_many([r for _, r, _ in batch],
                                                  bucket_layouts=True,
                                                  strict=False)
        except Exception:
            with self._lock:
                self._queue[:0] = batch
                self._work.notify_all()
            raise
        out = {ticket: artifacts[r] for ticket, r, _ in batch}
        with self._lock:
            self.done.update(out)
            self._pending.difference_update(out)
            self.session.stats["service_batches"] += 1
            self.session.stats["service_batch_requests"] += len(out)
            self._done_cv.notify_all()
        return out

    def run(self) -> dict[int, DesignArtifact]:
        """Drain the whole queue synchronously; returns a snapshot of every
        completed (uncollected) ticket.  Not valid while a `serve()` pump
        is running — use `collect()`/`poll()` there."""
        self._begin_sync("run")
        try:
            while self._dispatch_once():
                pass
        finally:
            self._end_sync()
        with self._lock:
            return dict(self.done)

    # -- ticket lifecycle ------------------------------------------------
    def _check_known(self, ticket: int) -> None:
        # lock held
        if not 0 <= ticket < self._next_ticket:
            raise UnknownTicket(f"ticket {ticket} was never issued by this "
                                f"service (tickets 0..{self._next_ticket - 1})")
        if ticket not in self._pending and ticket not in self.done:
            raise UnknownTicket(f"ticket {ticket} was already collected "
                                f"(use collect(..., keep_done=True) to keep "
                                f"artifacts around)")

    def poll(self, ticket: int) -> DesignArtifact | None:
        """Non-blocking, non-destructive readiness probe: the artifact if
        ready, `None` while the ticket is still queued / in flight.
        Raises `UnknownTicket` for a ticket this service never issued, and
        (like `collect`) surfaces a dead pipeline as `RuntimeError` — a
        poll-only consumer must not spin forever on a ticket that nothing
        is going to serve."""
        with self._lock:
            self._check_known(ticket)
            art = self.done.get(ticket)
            if art is None and self._pump_error is not None:
                raise RuntimeError(
                    f"ticket {ticket} cannot complete: the serve() pump "
                    f"failed (close() restores in-flight batches to the "
                    f"queue; drain with run()/step() or serve() again)"
                ) from self._pump_error
            return art

    def collect(self, ticket: int, *, timeout: float | None = None,
                keep_done: bool = False) -> DesignArtifact:
        """Return (and pop) the ticket's artifact.

        With a `serve()` pump running — or a `timeout` given — blocks
        until the artifact lands, the timeout expires (`PendingTicket`),
        or the pipeline fails (`RuntimeError` chaining the stage's
        exception; `close()` restores the in-flight batches).  Without a
        pump and without a timeout, a still-pending ticket raises
        `PendingTicket` immediately instead of deadlocking — drain with
        `run()`/`step()`.

        Popping on collect keeps `done` bounded in a long-lived service;
        pass `keep_done=True` to leave the artifact collectable again."""
        deadline = (None if timeout is None
                    else time.monotonic() + max(timeout, 0.0))
        with self._lock:
            while True:
                self._check_known(ticket)
                art = self.done.get(ticket)
                if art is not None:
                    if not keep_done:
                        del self.done[ticket]
                    return art
                if self._pump_error is not None:
                    raise RuntimeError(
                        f"ticket {ticket} cannot complete: the serve() pump "
                        f"failed (close() restores in-flight batches to the "
                        f"queue; drain with run()/step() or serve() again)"
                    ) from self._pump_error
                if deadline is None and not self._pump_alive():
                    raise PendingTicket(
                        f"ticket {ticket} is still pending and no serve() "
                        f"pump is running; drain the queue with run()/step() "
                        f"or pass collect(..., timeout=...) under serve()")
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise PendingTicket(f"ticket {ticket} still pending "
                                        f"after {timeout:g}s")
                # bounded wait so a pump that dies without notifying
                # (or a run()-mode caller) cannot strand us
                self._done_cv.wait(timeout=0.1 if remaining is None
                                   else min(remaining, 0.1))

    # -- the staged pipeline ---------------------------------------------
    def _pump_alive(self) -> bool:
        # the pipeline is "alive" (able to complete tickets) while the
        # admission pump runs OR any stage worker is still draining —
        # during close() the pump exits first but finalize keeps landing
        # artifacts, and collectors must not see a dead service then
        pump = self._pump
        if pump is not None and pump.is_alive():
            return True
        return any(t.is_alive() for t in self._stage_threads)

    def serve(self, *, pipelined: bool = True) -> "DesignService":
        """Start the serve pump (idempotent); returns `self` so
        `with DesignService(...).serve() as svc:` reads naturally.

        `pipelined=True` (default) starts the staged pipeline executor:
        admission pump + explore/distill/layout/finalize workers over
        bounded queues, overlapping consecutive batches and streaming
        layout buckets.  `pipelined=False` is the serial pump (one
        thread, one coalesced batch at a time through `run_many`) —
        kept for comparison benchmarks and as a minimal fallback.

        Idempotent for the same mode; asking for the *other* mode while
        a pump is alive raises (close() first to switch)."""
        with self._lock:
            if self._pump_alive():
                if pipelined != self._pipelined:
                    mode = "pipelined" if self._pipelined else "serial"
                    raise RuntimeError(
                        f"serve(pipelined={pipelined}) while a {mode} pump "
                        f"is already running; close() first to switch modes")
                return self
            if self._closing:
                # a concurrent close() is joining the old pump; starting a
                # second one here would orphan that drain (and race two
                # dispatchers on the session)
                raise RuntimeError("serve() while close() is in progress; "
                                   "wait for close() to return")
            if self._sync_dispatchers:
                # the converse of the step()/run() refusal: a synchronous
                # drain is mid-flight on the session, and the stage
                # workers must not race it
                raise RuntimeError("serve() while a run()/step() drain is "
                                   "in progress; wait for it to return")
            self._pump_error = None
            self._pipelined = pipelined
            if pipelined:
                d = self.pipeline_depth
                self._queues = {"explore": queue.Queue(maxsize=d),
                                "distill": queue.Queue(maxsize=d),
                                "layout": queue.Queue(maxsize=4 * d),
                                "finalize": queue.Queue(maxsize=4 * d)}
                self._stage_threads = [
                    threading.Thread(target=fn,
                                     name=f"design-service-{stage}",
                                     daemon=True)
                    for stage, fn in (("explore", self._explore_worker),
                                      ("distill", self._distill_worker),
                                      ("layout", self._layout_worker),
                                      ("finalize", self._finalize_worker))]
                for t in self._stage_threads:
                    t.start()
            self._pump = threading.Thread(target=self._pump_loop,
                                          name="design-service-pump",
                                          daemon=True)
            self._pump.start()
        return self

    def _pump_loop(self) -> None:
        """Admission: wait out the coalescing window, then either hand the
        batch to the explore queue (pipelined) or dispatch it inline
        (serial)."""
        pipelined = self._pipelined
        try:
            while True:
                with self._lock:
                    while True:
                        if self._pump_error is not None:
                            # a stage failed: stop forming batches and
                            # wait for close() to restore + surface
                            if self._closing:
                                return
                            self._work.wait()
                            continue
                        if self._closing:
                            if not self._queue:
                                return          # graceful: queue drained
                            break               # final drain dispatches
                        n = len(self._queue)
                        if n >= self.max_coalesce:
                            break               # batch is full
                        if n:
                            oldest = self._queue[0][2]
                            wait = (self.coalesce_window_s
                                    - (time.monotonic() - oldest))
                            if wait <= 0:
                                break           # deadline of oldest request
                            self._work.wait(timeout=wait)
                        else:
                            self._work.wait()
                if pipelined:
                    self._admit_batch()
                else:
                    self._dispatch_once()
        except Exception as e:   # serial path; _dispatch_once restored it
            with self._lock:
                self._pump_error = e
                self._done_cv.notify_all()
        finally:
            if pipelined:
                # one sentinel, forwarded stage to stage, drains and
                # stops the whole chain in order
                self._queues["explore"].put(None)

    def _admit_batch(self) -> None:
        with self._lock:
            entries = self._queue[:self.max_coalesce]
            del self._queue[:self.max_coalesce]
            if not entries:
                return
            batch = _Batch(entries)
            self._inflight.append(batch)
        # blocking put = backpressure: at most `pipeline_depth` batches
        # queue ahead of the explore stage; never block under the lock
        self._queues["explore"].put(batch)

    @contextlib.contextmanager
    def _stage(self, name: str):
        """Occupancy bookkeeping around one unit of stage work."""
        with self._lock:
            self._mark(name, busy=True)
        try:
            yield
        finally:
            with self._lock:
                self._mark(name, busy=False)

    def _mark(self, name: str, *, busy: bool) -> None:
        # lock held.  Maintains per-stage busy clocks and the
        # explore∧layout overlap clock (the pipelining win is exactly the
        # wall-clock both are busy at once).
        now = time.monotonic()
        if busy:
            self._busy_since[name] = now
        else:
            self._busy_s[name] += now - self._busy_since.pop(name)
        both = "explore" in self._busy_since and "layout" in self._busy_since
        if both and self._overlap_since is None:
            self._overlap_since = now
        elif not both and self._overlap_since is not None:
            self._overlap_s += now - self._overlap_since
            self._overlap_since = None

    def _stage_failure(self, exc: BaseException) -> None:
        """First stage failure wins: stop the pipeline, wake everyone.
        The in-flight batches (including the failing one) are restored to
        the queue front by close()."""
        with self._lock:
            if self._pump_error is None:
                self._pump_error = exc
            self._work.notify_all()     # admission: stop forming batches
            self._done_cv.notify_all()  # collectors: surface the error

    def _explore_worker(self) -> None:
        q_in, q_out = self._queues["explore"], self._queues["distill"]
        while True:
            batch = q_in.get()
            if batch is None:
                q_out.put(None)
                return
            if self._pump_error is not None:
                continue   # skip; close() restores it from _inflight
            try:
                start = time.monotonic()
                wait = start - batch.admitted_at
                batch.waits = {r: wait for _, r, _ in batch.entries}
                with self._stage("explore"):
                    batch.explored = self.session.explore_stage(
                        [r for _, r, _ in batch.entries])
                q_out.put(batch)
            except Exception as e:
                self._stage_failure(e)

    def _distill_worker(self) -> None:
        q_in, q_out = self._queues["distill"], self._queues["layout"]
        while True:
            batch = q_in.get()
            if batch is None:
                q_out.put(None)
                return
            if self._pump_error is not None:
                continue
            try:
                with self._stage("distill"):
                    batch.distilled = self.session.distill_stage(
                        batch.explored, strict=False)
                batch.remaining = len(batch.distilled.buckets)
                if not batch.distilled.buckets:
                    q_out.put((batch, None, time.monotonic()))
                else:
                    # stream: every bucket is submitted to the layout
                    # worker the moment it exists — bucket 1 of batch N
                    # is routing while the rest are still enqueuing and
                    # batch N+1 is exploring
                    for bucket in batch.distilled.buckets:
                        q_out.put((batch, bucket, time.monotonic()))
            except Exception as e:
                self._stage_failure(e)

    def _layout_worker(self) -> None:
        q_in, q_out = self._queues["layout"], self._queues["finalize"]
        while True:
            item = q_in.get()
            if item is None:
                q_out.put(None)
                return
            batch, bucket, t_enq = item
            if self._pump_error is not None:
                continue
            try:
                if bucket is None:           # no layout work in this batch
                    q_out.put(batch)
                    continue
                wait = time.monotonic() - t_enq
                with self._stage("layout"):
                    res = self.session.layout_stage(bucket)
                res.queue_wait_s = wait
                batch.results.append(res)    # this worker only: no race
                batch.remaining -= 1
                if batch.remaining == 0:     # last bucket -> finalize
                    q_out.put(batch)
            except Exception as e:
                self._stage_failure(e)

    def _finalize_worker(self) -> None:
        q_in = self._queues["finalize"]
        while True:
            batch = q_in.get()
            if batch is None:
                return
            if self._pump_error is not None:
                continue
            try:
                with self._stage("finalize"):
                    arts = self.session.finalize_stage(
                        batch.distilled, batch.results,
                        waits=batch.waits, pipelined=True)
                out = {t: arts[r] for t, r, _ in batch.entries}
                with self._lock:
                    self.done.update(out)
                    self._pending.difference_update(out)
                    self.session.stats["service_batches"] += 1
                    self.session.stats["service_batch_requests"] += len(out)
                    if batch in self._inflight:
                        self._inflight.remove(batch)
                    self._done_cv.notify_all()
            except Exception as e:
                self._stage_failure(e)

    def close(self) -> None:
        """Graceful shutdown: stop admitting, drain every queued batch
        through all stages, join the pump and the stage workers.
        Idempotent; a no-op if `serve()` was never called.  If a stage
        failed, every in-flight batch is restored to the queue front
        (tickets intact, in admission order) and the stage's exception
        is re-raised here."""
        with self._lock:
            pump = self._pump
            workers = list(self._stage_threads)
            if pump is not None:
                self._closing = True
            self._work.notify_all()
        if pump is not None:
            # keep self._pump set while joining: a concurrent collect()
            # must still see a live pipeline (no spurious PendingTicket
            # during the final drain), and a concurrent serve() must not
            # start a second dispatcher (it sees _closing and refuses)
            pump.join()
            for t in workers:
                t.join()
        with self._lock:
            if self._pump is pump:
                self._pump = None
                self._stage_threads = []
                self._queues = {}
            self._closing = False
            err, self._pump_error = self._pump_error, None
            if self._inflight:
                # restore every non-finalized batch — in admission order,
                # at the FRONT of the queue: no ticket lost or reordered
                self._queue[:0] = [e for b in self._inflight
                                   for e in b.entries]
                self._inflight = []
            self._busy_since = {}
            self._overlap_since = None
        if err is not None:
            raise RuntimeError(
                "serve() pump failed; in-flight tickets were restored — "
                "drain with run()/step() or serve() again") from err

    def __enter__(self) -> "DesignService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
