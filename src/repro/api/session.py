"""Compiled-program sessions: long-lived, cache-owning request execution.

`DesignSession` is the single supported way to run a `DesignRequest`
end to end.  It owns two caches:

  * a *program cache* keyed by `DesignRequest.shape_signature()` — one
    entry per compiled sweep program.  Array size, seed, and calibration
    are traced operands (`repro.core.nsga2.SpaceOperands`), so a repeat
    request or a signature-compatible variant request dispatches the
    cached program with **zero new traces** (observable through the
    `repro.core.nsga2.TRACE_COUNTS` probe, recorded per run in the
    artifact provenance);
  * a *front cache* keyed by `DesignRequest.explore_key()` — the
    distillation-independent Pareto front, so a repeat query (or the
    same exploration under different application requirements) costs no
    device dispatch at all;
  * optionally a third, *persistent* tier: an
    `repro.api.artifact_cache.ArtifactCache` (disk store keyed by
    `DesignRequest.sha()`), consulted before exploring and written
    after each run, so a fleet of processes shares exploration results
    across restarts — served artifacts carry
    `provenance.served_from == "artifact_cache"`.

Execution is factored into four first-class **stages** with explicit
inter-stage payload types, so the sequential drivers and the staged
pipeline executor (`repro.serve.design_service`) run the *same* code
and cannot diverge:

  * `explore_stage(requests)` — dedupe, consult the persistent
    artifact cache, and fold every cache-miss request in the same
    `explore_group()` into ONE `explore_cells` dispatch
    (-> `ExploredBatch`);
  * `distill_stage(batch)` — apply each request's requirements and
    form the layout buckets: under `bucket_layouts=True` the union of
    surviving specs is bucketed by quantized routing-grid shape
    (shapes quantized to powers of two so bucketing cannot degenerate
    into per-spec dispatches — heterogeneous Pareto sets no longer pay
    padded-batch waste for the biggest member); otherwise one
    whole-request bucket per request (-> `DistilledBatch`, whose
    `buckets` list is the streamable unit of layout work);
  * `layout_stage(bucket)` — one `LayoutBucket` through the batched
    flow (`eda.batched_flow.iter_layout_buckets`), independently
    dispatchable per bucket (-> `BucketResult`);
  * `finalize_stage(batch, bucket_results)` — demux per-request
    artifacts, stamp provenance, fill the persistent cache.

`run()` and `run_many()` are thin sequential drivers over these
stages; the service's pipeline executor drives the same stage
functions from per-stage workers so batch N+1's exploration overlaps
batch N's layout and buckets stream as they are formed.

Timing lives here, in the artifact provenance, not in the library flow
modules: `repro.eda.batched_flow` is pure compute.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import json
import os
import tempfile
import threading
import time
from typing import Iterable

from repro.core import nsga2
from repro.runtime.lock_sanitizer import make_lock
from repro.core.batched_explorer import explore_cells, sweep_program
from repro.core.explorer import ParetoResult
from repro.api.request import DesignRequest
from repro.core.acim_spec import MacroSpec
from repro.eda.batched_flow import BatchedLayoutResult, iter_layout_buckets


# Stamped into every serialized artifact; `repro.api.artifact_cache`
# refuses entries whose stamp differs, so a fleet upgrade cannot feed a
# new reader stale-layout JSON.  Bump on any to_dict/from_dict change.
# 2: provenance gained the staged-pipeline fields (explore_wait_s,
#    layout_wait_s, pipelined).
# 3: provenance gained the fault-tolerance fields (attempts,
#    retried_buckets, shed_buckets, worker_id).
# 4: provenance gained the routing-engine fields (route_engine,
#    route_rounds, route_collisions).
# 5: provenance gained the mesh-exploration fields (mesh_devices,
#    islands, migration_topology, migration_rounds) and the tiered-
#    cache `served_from` values ("artifact_cache_l1"/"_l2"); requests
#    gained the islands/migrate_every genes.
ARTIFACT_SCHEMA = 5


@dataclasses.dataclass(frozen=True)
class Provenance:
    """How an artifact was produced (the session's receipt).

    Wall-clock fields are this request's *fair share* of the shared
    work (an explorer dispatch split over the requests it coalesced, a
    layout bucket split over the specs it laid out), so summing
    `total_s` across a batch's artifacts approximates the real cost
    instead of multiply-counting it.  Count fields are dispatch-scoped:
    coalesced requests served by the same dispatch all report its
    trace/dispatch counts (dedupe by dispatch — e.g. keep one artifact
    per `coalesced` group — before summing them)."""

    request_sha: str
    explore_s: float            # fair share of the exploration dispatch
    layout_s: float             # fair share of the layout buckets touched
    total_s: float
    new_traces: int             # run_cell traces of the serving dispatch
    explorer_dispatches: int    # 0 when served from the front cache
    layout_dispatches: int      # grid-shape buckets this request touched
    front_cache_hit: bool
    coalesced: int              # requests sharing the exploration (>= 1)
    # which tier produced the artifact's content: "explorer" (a device
    # dispatch), "front_cache" (this process's in-memory front cache), or
    # "artifact_cache" (the persistent cross-process store)
    served_from: str = "explorer"
    # staged-pipeline facts (zero on the sequential drivers): how long
    # the request sat in inter-stage queues before its explore batch was
    # picked up / before its layout buckets dispatched (mean over the
    # buckets the request touched), and whether the artifact was
    # produced by the staged pipeline executor at all
    explore_wait_s: float = 0.0
    layout_wait_s: float = 0.0
    pipelined: bool = False
    # fault-tolerance facts (schema 3): total layout attempts across the
    # buckets this request touched (>= bucket count when anything was
    # retried; 0 for cache-served / front-only requests), how many of
    # those buckets needed a retry, how many were shed to a peer layout
    # worker by the straggler policy, and which layout worker completed
    # the request's first bucket ("" outside the pipelined worker pool)
    attempts: int = 0
    retried_buckets: int = 0
    shed_buckets: int = 0
    worker_id: str = ""
    # routing-engine facts (schema 4), aggregated over the layout
    # buckets this request touched: which wavefront scheduler routed
    # them ("concurrent" = conflict-aware frontier batching, "scan" =
    # one lax.scan dispatch per net slot; "" for cache-served /
    # front-only requests), how many wavefront dispatch rounds they
    # took in total, and how many buffered routes a capacity crossing
    # invalidated and re-routed (the collision-retry count)
    route_engine: str = ""
    route_rounds: int = 0
    route_collisions: int = 0
    # mesh-exploration facts (schema 5), dispatch-scoped like the rest:
    # how many mesh devices the serving explore dispatch ran on (0 for
    # the single-device vmap engine and for cache-served artifacts),
    # the island count it evolved, the migration topology ("ring" for
    # island evolution, "sharded" for mesh-sharded cells, "" off-mesh),
    # and how many elite migrations fired
    mesh_devices: int = 0
    islands: int = 1
    migration_topology: str = ""
    migration_rounds: int = 0


@dataclasses.dataclass(frozen=True, eq=False)
class DesignArtifact:
    """The uniform result of one request: distilled front + layouts +
    provenance.

    `layout_rows` is the serializable layout product (one metrics row
    per spec, aligned with `pareto.specs`); `layouts` additionally holds
    the in-memory `BatchedLayoutResult` tensors when the request was
    laid out as a single batch (it is dropped by JSON round-trips and
    by the bucketed multi-tenant path).  `error` is set instead of
    raising on the non-strict (multi-tenant) path when the request's
    requirements removed every Pareto point.
    """

    request: DesignRequest
    pareto: ParetoResult                      # distilled frontier
    layout_rows: tuple[dict, ...] | None      # aligned with pareto.specs
    provenance: Provenance
    layouts: BatchedLayoutResult | None = dataclasses.field(
        default=None, repr=False)
    error: str | None = None

    @property
    def ok(self) -> bool:
        return self.error is None

    def summary(self) -> dict:
        """Provenance-free content view, for equality checks."""
        return {"array_size": self.pareto.array_size,
                "specs": [s.as_tuple() for s in self.pareto.specs],
                "front": self.pareto.to_rows(),
                "layout": (None if self.layout_rows is None
                           else list(self.layout_rows))}

    def to_dict(self) -> dict:
        return {"schema": ARTIFACT_SCHEMA,
                "request": self.request.to_dict(),
                "pareto": {"array_size": self.pareto.array_size,
                           "points": self.pareto.to_rows()},
                "layout_rows": (None if self.layout_rows is None
                                else list(self.layout_rows)),
                "provenance": dataclasses.asdict(self.provenance),
                "error": self.error}

    def to_json(self, path) -> None:
        """Atomic dump: a crash mid-write can never leave a truncated file
        at `path` (the persistent artifact cache depends on this)."""
        _atomic_dump(self.to_dict(), path)

    @classmethod
    def from_dict(cls, d: dict) -> "DesignArtifact":
        schema = d.get("schema", ARTIFACT_SCHEMA)   # pre-stamp files pass
        if schema != ARTIFACT_SCHEMA:
            raise ValueError(f"artifact schema {schema} != supported "
                             f"{ARTIFACT_SCHEMA}; re-run the request")
        rows = d["layout_rows"]
        return cls(request=DesignRequest.from_dict(d["request"]),
                   pareto=ParetoResult.from_rows(d["pareto"]["array_size"],
                                                 d["pareto"]["points"]),
                   layout_rows=None if rows is None else tuple(rows),
                   provenance=Provenance(**d["provenance"]),
                   error=d.get("error"))

    @classmethod
    def from_json(cls, path) -> "DesignArtifact":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def _atomic_dump(payload: dict, path) -> None:
    """Temp-file + `os.replace` JSON write: readers only ever see either
    the previous complete file or the new complete file.  The temp file
    lives in the target's directory so the replace stays on one
    filesystem (rename atomicity)."""
    path = os.fspath(path)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


# Bounded: a long-lived service sees an unbounded stream of distinct
# (spec, coarse) pairs, and an unbounded memo keyed by MacroSpec grows
# with it forever.  4096 entries cover hundreds of concurrent Pareto
# sets.  Hand-rolled (not lru_cache) so a hit/miss can be attributed to
# the *calling* session's stats Counter exactly — several sessions in
# one process share the memo without cross-counting each other.
GRID_SIG_CACHE_SIZE = 4096
_GRID_SIG_LOCK = make_lock("api.session._GRID_SIG_LOCK")
_GRID_SIG_MEMO: collections.OrderedDict = collections.OrderedDict()


def _grid_sig(spec: MacroSpec, coarse: int,
              stats: collections.Counter | None = None) -> tuple[int, int]:
    """Routing-grid shape of a spec's macro, without placing it.
    Memoized process-wide with an LRU bound; pass a session's `stats`
    to count the lookup as that session's "grid_sig_hits"/"_misses"."""
    key = (spec, coarse)
    with _GRID_SIG_LOCK:
        val = _GRID_SIG_MEMO.get(key)
        if val is not None:
            _GRID_SIG_MEMO.move_to_end(key)
            if stats is not None:
                stats["grid_sig_hits"] += 1
            return val
    from repro.eda.placer import geometry, layout_operands
    from repro.eda.router import grid_shape

    ops = layout_operands(spec, geometry())
    val = grid_shape(int(ops.width), int(ops.height), coarse)
    with _GRID_SIG_LOCK:
        if stats is not None:
            stats["grid_sig_misses"] += 1
        _GRID_SIG_MEMO[key] = val
        _GRID_SIG_MEMO.move_to_end(key)
        while len(_GRID_SIG_MEMO) > GRID_SIG_CACHE_SIZE:
            _GRID_SIG_MEMO.popitem(last=False)
    return val


def _bucket_key(spec: MacroSpec, coarse: int, capacity: int,
                stats: collections.Counter | None = None) -> tuple:
    """Layout-bucket key: the routing-grid shape quantized to the next
    power of two per axis.  Exact-shape buckets would degenerate to one
    dispatch (and one compile) per distinct spec on heterogeneous
    fronts; quantizing bounds the padded-cell waste at <2x per axis
    while keeping the bucket count logarithmic in the shape spread."""
    gh, gw = _grid_sig(spec, coarse, stats)
    return (coarse, capacity,
            1 << (gh - 1).bit_length(), 1 << (gw - 1).bit_length())


# ----------------------------------------------------------------------
# Inter-stage payload types: the explicit contracts between the four
# stages.  The sequential drivers (`run`/`run_many`) and the staged
# pipeline executor (`repro.serve.design_service`) both move exactly
# these values between exactly these stage functions.
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayoutBucket:
    """One streamable unit of layout work: the specs sharing a quantized
    routing-grid shape (shared bucket, `request is None`) or one
    request's whole distilled set (`request` set — the single-request
    path, which keeps the in-memory layout tensors)."""

    key: tuple
    coarse: int
    capacity: int
    specs: tuple[MacroSpec, ...]
    request: DesignRequest | None = None


@dataclasses.dataclass
class BucketResult:
    """`layout_stage`'s product for one bucket."""

    bucket: LayoutBucket
    rows: dict                        # MacroSpec -> metrics row
    elapsed_s: float
    result: BatchedLayoutResult | None = None   # whole-request buckets only
    queue_wait_s: float = 0.0         # stamped by the pipelined executor
    # fault-tolerance facts, stamped by the pipelined worker pool: which
    # layout attempt produced this result (1 = first try), whether the
    # bucket was shed to a peer worker mid-flight, and which worker
    # completed it first
    attempts: int = 1
    shed: bool = False
    worker_id: str = ""
    # routing facts from the bucket's `BatchedRouting`: which engine
    # routed it and what it cost (rounds; collision-retries)
    engine: str = ""
    rounds: int = 0
    collisions: int = 0


@dataclasses.dataclass
class ExploredBatch:
    """`explore_stage` -> `distill_stage` payload."""

    requests: list                    # deduped cache-miss remainder, in order
    served: dict                      # DesignRequest -> DesignArtifact
    fronts: dict                      # DesignRequest -> ParetoResult
    info: dict                        # DesignRequest -> explore-info dict


@dataclasses.dataclass
class DistilledBatch:
    """`distill_stage` -> `layout_stage`/`finalize_stage` payload.

    `buckets` is ordered (first-seen) and each entry is independently
    dispatchable — the pipeline executor submits every bucket to the
    layout worker as soon as `distill_stage` returns, instead of
    blocking on the whole union.  `spec_keys[r]` aligns with
    `distilled[r].specs`, naming the bucket each spec landed in (the
    demux map `finalize_stage` uses)."""

    explored: ExploredBatch
    distilled: dict                   # DesignRequest -> ParetoResult
    errors: dict                      # DesignRequest -> message
    buckets: list                     # [LayoutBucket], formation order
    spec_keys: dict                   # DesignRequest -> tuple[bucket key, ...]


class _SweepProgram:
    """One program-cache entry: the compiled sweep for a shape signature."""

    def __init__(self, request: DesignRequest):
        self.statics = nsga2.EvolveStatics(
            pop_size=request.pop_size,
            crossover_prob=request.crossover_prob,
            mutation_prob=request.mutation_prob,
            use_pallas_dominance=request.use_pallas_dominance,
            use_pallas_rank=request.use_pallas_rank)
        self.n_gens = request.generations
        self.fn = functools.partial(sweep_program, statics=self.statics,
                                    n_gens=self.n_gens)
        self.dispatches = 0


class DesignSession:
    """Long-lived request executor owning the program and front caches,
    optionally backed by a persistent cross-process artifact cache."""

    def __init__(self, *, artifact_cache=None, recorder=None, mesh=None):
        """`artifact_cache` is an `repro.api.artifact_cache.ArtifactCache`
        (or anything with its `get(request)`/`put(artifact)` shape —
        e.g. a two-tier `TieredArtifactCache`, whose hits are stamped
        `served_from="artifact_cache_l1"` / `"artifact_cache_l2"`), a
        directory path to open one at, or `None` for in-memory caches
        only.  With a cache, `run`/`run_many` consult it *before*
        exploring — a warm repeat request is served with zero explorer
        dispatches and `provenance.served_from == "artifact_cache"` —
        and write every successful artifact back after the run.

        `mesh` opts the explore stage onto the device-mesh engine
        (`repro.parallel.distributed_explorer.explore_cells_mesh`): a
        `jax.sharding.Mesh`, an int device cap for the auto-built 1-D
        mesh, or `True` for all local devices.  Island requests
        (`DesignRequest.islands > 1`) use the mesh engine even when
        `mesh` is None (auto mesh) — fronts are bit-identical for any
        device count, so the knob is pure throughput.

        `recorder` is an optional `repro.telemetry.spans.SpanRecorder`:
        with one attached, the stage functions record `cat="session"`
        spans (one per coalesced explore dispatch, distillation, layout
        bucket, finalize pass) — the sequential drivers' side of the
        stage Gantt.  A `DesignService` built with telemetry attaches
        its recorder here automatically."""
        self._programs: dict[tuple, _SweepProgram] = {}
        self._fronts: dict[tuple, ParetoResult] = {}
        self.recorder = recorder
        self.stats: collections.Counter = collections.Counter()
        # Counter increments are read-modify-write and the counters are
        # written from every service thread (stage workers, the layout
        # pool, the pump) as well as the session's own stages, so ALL
        # mutations go through bump() and all snapshots copy under this
        # lock — a lock-free insert of a new key can otherwise race a
        # concurrent `Counter(self.stats)` copy mid-iteration.
        self.stats_lock = make_lock("DesignSession.stats_lock")
        if artifact_cache is not None and not hasattr(artifact_cache, "put"):
            from repro.api.artifact_cache import ArtifactCache
            artifact_cache = ArtifactCache(artifact_cache)
        self.artifact_cache = artifact_cache
        self.mesh = mesh
        self._resolved_mesh = None

    def _mesh_for_dispatch(self):
        """The resolved `jax.sharding.Mesh` for mesh dispatches (built
        lazily so sessions that never touch the mesh engine never
        inspect devices)."""
        if self._resolved_mesh is None:
            from jax.sharding import Mesh

            from repro.parallel import distributed_explorer as dx
            if isinstance(self.mesh, Mesh):
                self._resolved_mesh = self.mesh
            else:
                cap = (self.mesh if isinstance(self.mesh, int)
                       and not isinstance(self.mesh, bool) else None)
                self._resolved_mesh = dx.default_mesh(max_devices=cap)
        return self._resolved_mesh

    def bump(self, key: str, n: int = 1) -> None:
        """Increment a stats counter under `stats_lock`.  The single
        mutation path for `self.stats`: session stages and every
        service thread serialize here, so increments never lose updates
        and snapshot copies never see a dict mid-resize."""
        with self.stats_lock:
            self.stats[key] += n

    def _span(self, name: str, **tags):
        """A `cat="session"` telemetry span, or a no-op without a
        recorder — the stage functions stay zero-overhead when tracing
        is off."""
        if self.recorder is None:
            return contextlib.nullcontext()
        return self.recorder.span(name, cat="session", **tags)

    # -- program cache ---------------------------------------------------
    def program_for(self, request: DesignRequest) -> _SweepProgram:
        sig = request.shape_signature()
        prog = self._programs.get(sig)
        if prog is None:
            prog = self._programs[sig] = _SweepProgram(request)
            self.bump("program_cache_misses")
        else:
            self.bump("program_cache_hits")
        return prog

    # -- exploration (coalesced across requests) -------------------------
    def _fronts_for(self, requests: list[DesignRequest]):
        """Resolve every request's (undistilled) front; missing fronts of
        the same explore group fold into one dispatch.  Returns
        (fronts, per-request explore info)."""
        info = {r: {"explore_s": 0.0, "new_traces": 0, "dispatches": 0,
                    "cache_hit": True, "coalesced": 1} for r in requests}
        pending: dict[tuple, list[DesignRequest]] = {}
        for r in requests:
            if r.explore_key() in self._fronts:
                self.bump("front_cache_hits")
            else:
                pending.setdefault(r.explore_group(), []).append(r)
        for group in pending.values():
            r0 = group[0]
            cells = list(dict.fromkeys(r.cell for r in group))
            on_mesh = r0.islands > 1 or self.mesh is not None
            n0 = nsga2.TRACE_COUNTS["run_cell"]
            t0 = time.perf_counter()
            facts: dict = {}
            if on_mesh:
                from repro.parallel import distributed_explorer as dx
                mesh = self._mesh_for_dispatch()
                with self._span("explore_dispatch", cells=len(cells),
                                coalesced=len(group), engine="mesh",
                                islands=r0.islands):
                    fronts, facts = dx.explore_cells_mesh(
                        cells, mesh=mesh, islands=r0.islands,
                        migrate_every=r0.migrate_every,
                        pop_size=r0.pop_size, generations=r0.generations,
                        crossover_prob=r0.crossover_prob,
                        mutation_prob=r0.mutation_prob, cal=r0.cal,
                        use_pallas_dominance=r0.use_pallas_dominance,
                        use_pallas_rank=r0.use_pallas_rank)
                self.bump("mesh_dispatches")
            else:
                prog = self.program_for(r0)
                with self._span("explore_dispatch", cells=len(cells),
                                coalesced=len(group)):
                    fronts = explore_cells(
                        cells, pop_size=r0.pop_size,
                        generations=r0.generations,
                        crossover_prob=r0.crossover_prob,
                        mutation_prob=r0.mutation_prob, cal=r0.cal,
                        use_pallas_dominance=r0.use_pallas_dominance,
                        use_pallas_rank=r0.use_pallas_rank,
                        program=prog.fn)
                prog.dispatches += 1
            dt = time.perf_counter() - t0
            traces = nsga2.TRACE_COUNTS["run_cell"] - n0
            self.bump("explorer_dispatches")
            self.bump("run_cell_traces", traces)
            for cell, front in fronts.items():
                key = r0.explore_group() + cell
                self._fronts[key] = front
            for r in group:
                info[r] = {"explore_s": dt / len(group), "new_traces": traces,
                           "dispatches": 1, "cache_hit": False,
                           "coalesced": len(group), **facts}
        return {r: self._fronts[r.explore_key()] for r in requests}, info

    def fronts_for(self, requests: Iterable[DesignRequest]
                   ) -> dict[DesignRequest, ParetoResult]:
        """Coalesced exploration only (no distillation, no layout)."""
        fronts, _ = self._fronts_for(list(requests))
        return fronts

    # -- layout ----------------------------------------------------------
    def layout(self, specs, *, coarse: int = 64, capacity: int = 4,
               engine: str | None = None) -> BatchedLayoutResult:
        """One batched layout dispatch chain for a spec set.  Safe to
        call from several layout-pool workers concurrently (the batched
        flow is pure compute; the stats counter is locked).

        `engine` passes through to `eda.batched_flow.batched_route`
        ("concurrent" / "scan" / None for the backend auto choice); the
        choice is recorded in the artifact provenance either way."""
        self.bump("layout_dispatches")
        (res,) = iter_layout_buckets([(tuple(specs), coarse, capacity)],
                                     engine=engine)
        return res

    # -- the four stages --------------------------------------------------
    def explore_stage(self, requests: Iterable[DesignRequest]
                      ) -> ExploredBatch:
        """Stage 1 — dedupe, consult the persistent artifact cache, and
        fold every cache-miss request in the same explore group into one
        `explore_cells` dispatch.

        Requests found in the artifact cache land in `.served` with
        provenance re-stamped (`served_from="artifact_cache"`, zero
        dispatches); the remainder carries its fronts + explore info."""
        all_requests = list(dict.fromkeys(requests))
        served: dict[DesignRequest, DesignArtifact] = {}
        if self.artifact_cache is not None:
            tiered = hasattr(self.artifact_cache, "get_with_tier")
            for r in all_requests:
                t0 = time.perf_counter()
                if tiered:
                    hit, tier = self.artifact_cache.get_with_tier(r)
                else:
                    hit, tier = self.artifact_cache.get(r), None
                if hit is None:
                    self.bump("artifact_cache_misses")
                    if tiered:
                        self.bump("artifact_cache_l1_misses")
                        self.bump("artifact_cache_l2_misses")
                    continue
                self.bump("artifact_cache_hits")
                source = "artifact_cache"
                if tier is not None:
                    source = f"artifact_cache_{tier}"
                    self.bump(f"artifact_cache_{tier}_hits")
                    if tier == "l2":
                        self.bump("artifact_cache_l1_misses")
                        self.bump("artifact_cache_promotions")
                prov = dataclasses.replace(
                    hit.provenance, explore_s=0.0, layout_s=0.0,
                    total_s=time.perf_counter() - t0, new_traces=0,
                    explorer_dispatches=0, layout_dispatches=0,
                    front_cache_hit=False, coalesced=1,
                    explore_wait_s=0.0, layout_wait_s=0.0, pipelined=False,
                    attempts=0, retried_buckets=0, shed_buckets=0,
                    worker_id="", route_engine="", route_rounds=0,
                    route_collisions=0, mesh_devices=0,
                    migration_topology="", migration_rounds=0,
                    served_from=source)
                served[r] = dataclasses.replace(hit, provenance=prov)
        remainder = [r for r in all_requests if r not in served]
        fronts, info = (self._fronts_for(remainder) if remainder
                        else ({}, {}))
        return ExploredBatch(requests=remainder, served=served,
                             fronts=fronts, info=info)

    def distill_stage(self, explored: ExploredBatch, *,
                      strict: bool = True, bucket_layouts: bool = True
                      ) -> DistilledBatch:
        """Stage 2 — apply each request's requirements and form the
        layout buckets.

        A request whose requirements remove every Pareto point raises
        `ValueError` under `strict=True`; under `strict=False` (the
        multi-tenant path) it is recorded in `.errors` and the rest of
        the batch proceeds.  Buckets are the quantized grid-shape union
        (`bucket_layouts=True`) or one whole-request bucket each."""
        distilled: dict[DesignRequest, ParetoResult] = {}
        errors: dict[DesignRequest, str] = {}
        for r in explored.requests:
            d = (explored.fronts[r] if r.requirements.is_noop
                 else explored.fronts[r].filter(
                     **r.requirements.as_filter_kwargs()))
            if r.layout and not len(d):
                msg = (f"requirements {r.requirements} removed every Pareto "
                       f"point for request {r.sha()} "
                       f"(array_size={r.array_size}); relax them or set "
                       f"layout=False")
                if strict:
                    raise ValueError(msg)
                errors[r] = msg
            distilled[r] = d

        laid = [r for r in explored.requests
                if r.layout and r not in errors]
        buckets: list[LayoutBucket] = []
        spec_keys: dict[DesignRequest, tuple] = {}
        if bucket_layouts:
            members: dict[tuple, dict] = {}   # key -> ordered spec set
            for r in laid:
                keys = []
                for spec in distilled[r].specs:
                    key = _bucket_key(spec, r.coarse, r.capacity, self.stats)
                    members.setdefault(key, {})[spec] = None
                    keys.append(key)
                spec_keys[r] = tuple(keys)
            buckets = [LayoutBucket(key=k, coarse=k[0], capacity=k[1],
                                    specs=tuple(specs))
                       for k, specs in members.items()]
        else:
            for r in laid:
                key = ("request", r.sha())
                buckets.append(LayoutBucket(key=key, coarse=r.coarse,
                                            capacity=r.capacity,
                                            specs=distilled[r].specs,
                                            request=r))
                spec_keys[r] = tuple(key for _ in distilled[r].specs)
        return DistilledBatch(explored=explored, distilled=distilled,
                              errors=errors, buckets=buckets,
                              spec_keys=spec_keys)

    def layout_stage(self, bucket: LayoutBucket) -> BucketResult:
        """Stage 3 — one bucket through the batched flow: a single
        `generate_layouts` dispatch chain, independent of every other
        bucket (what lets the pipeline executor stream them)."""
        t0 = time.perf_counter()
        with self._span("layout_bucket", bucket=bucket.key,
                        specs=len(bucket.specs)):
            res = self.layout(bucket.specs, coarse=bucket.coarse,
                              capacity=bucket.capacity)
        dt = time.perf_counter() - t0
        return BucketResult(bucket=bucket,
                            rows=dict(zip(res.specs, res.metrics_rows())),
                            elapsed_s=dt,
                            result=(res if bucket.request is not None
                                    else None),
                            engine=res.routing.engine,
                            rounds=int(res.routing.rounds),
                            collisions=int(res.routing.collisions))

    def finalize_stage(self, batch: DistilledBatch,
                       bucket_results: Iterable[BucketResult], *,
                       waits: dict | None = None, pipelined: bool = False,
                       failed: dict | None = None
                       ) -> dict[DesignRequest, DesignArtifact]:
        """Stage 4 — demux bucket rows back to per-request artifacts,
        stamp provenance (fair-share wall-clock, queue waits), and fill
        the persistent artifact cache.

        `waits` optionally maps request -> explore-queue wait seconds
        (the pipelined executor's measurement); layout queue waits ride
        in on each `BucketResult.queue_wait_s`.

        `failed` maps bucket key -> `(message, attempts)` for buckets
        whose layout exhausted the retry budget (the pipelined
        executor's per-bucket isolation).  A request touching a failed
        bucket completes with `artifact.error` set (its distilled front
        is still attached; `layout_rows` is None) — batch-mates whose
        buckets all succeeded finalize normally, and error artifacts
        are never written to the persistent cache."""
        explored = batch.explored
        results = {br.bucket.key: br for br in bucket_results}
        waits = waits or {}
        failed = failed or {}
        out: dict[DesignRequest, DesignArtifact] = {}
        for r, art in explored.served.items():
            if pipelined:
                prov = dataclasses.replace(
                    art.provenance, pipelined=True,
                    explore_wait_s=waits.get(r, 0.0))
                art = dataclasses.replace(art, provenance=prov)
            out[r] = art
        for r in explored.requests:
            i = explored.info[r]
            keys = batch.spec_keys.get(r, ())
            uniq = list(dict.fromkeys(keys))
            bad = [k for k in uniq if k in failed]
            touched = [results[k] for k in uniq if k in results]
            layout_s = sum(results[k].elapsed_s / len(results[k].bucket.specs)
                           for k in keys if k in results)
            layout_wait = (sum(br.queue_wait_s for br in touched)
                           / len(touched) if touched else 0.0)
            rows_for = (tuple(results[k].rows[s] for k, s
                              in zip(keys, batch.distilled[r].specs))
                        if keys and not bad else None)
            layouts = next((br.result for br in touched
                            if br.bucket.request is r), None)
            error = batch.errors.get(r)
            if bad and error is None:
                error = (f"{len(bad)} of {len(uniq)} layout bucket(s) "
                         f"failed for request {r.sha()}: "
                         + "; ".join(failed[k][0] for k in bad))
            attempts = (sum(br.attempts for br in touched)
                        + sum(failed[k][1] for k in bad))
            retried = (sum(1 for br in touched if br.attempts > 1)
                       + sum(1 for k in bad if failed[k][1] > 1))
            prov = Provenance(
                request_sha=r.sha(), explore_s=i["explore_s"],
                layout_s=layout_s,
                total_s=i["explore_s"] + layout_s,
                new_traces=i["new_traces"],
                explorer_dispatches=i["dispatches"],
                layout_dispatches=len(touched),
                front_cache_hit=i["cache_hit"], coalesced=i["coalesced"],
                served_from=("front_cache" if i["cache_hit"]
                             else "explorer"),
                explore_wait_s=waits.get(r, 0.0),
                layout_wait_s=layout_wait, pipelined=pipelined,
                attempts=attempts, retried_buckets=retried,
                shed_buckets=sum(1 for br in touched if br.shed),
                worker_id=(touched[0].worker_id if touched else ""),
                route_engine="/".join(sorted({br.engine for br in touched
                                              if br.engine})),
                route_rounds=sum(br.rounds for br in touched),
                route_collisions=sum(br.collisions for br in touched),
                mesh_devices=i.get("mesh_devices", 0),
                islands=i.get("islands", r.islands),
                migration_topology=i.get("migration_topology", ""),
                migration_rounds=i.get("migration_rounds", 0))
            art = DesignArtifact(request=r, pareto=batch.distilled[r],
                                 layout_rows=rows_for,
                                 provenance=prov, layouts=layouts,
                                 error=error)
            if self.artifact_cache is not None and art.ok:
                self.artifact_cache.put(art)
                self.bump("artifact_cache_writes")
                if hasattr(self.artifact_cache, "get_with_tier"):
                    self.bump("artifact_cache_l2_writes")
            out[r] = art
        self.bump("requests_served", len(out))
        return out

    def error_artifact(self, request: DesignRequest, message: str, *,
                       pipelined: bool = False,
                       explore_wait_s: float = 0.0) -> DesignArtifact:
        """A terminal failure artifact: an empty frontier, no layouts,
        `error` set, `provenance.served_from == "error"`.  The pipelined
        executor produces these when a whole batch stage (explore /
        distill / finalize) exhausts its retry budget — the batch's
        tickets complete with a diagnosis instead of poisoning the
        pipeline.  Never written to the persistent cache (`art.ok` is
        False)."""
        prov = Provenance(
            request_sha=request.sha(), explore_s=0.0, layout_s=0.0,
            total_s=0.0, new_traces=0, explorer_dispatches=0,
            layout_dispatches=0, front_cache_hit=False, coalesced=1,
            served_from="error", explore_wait_s=explore_wait_s,
            pipelined=pipelined)
        return DesignArtifact(
            request=request,
            pareto=ParetoResult.from_rows(request.array_size, []),
            layout_rows=None, provenance=prov, error=message)

    # -- the end-to-end drivers -------------------------------------------
    def run_many(self, requests: Iterable[DesignRequest], *,
                 bucket_layouts: bool = True, strict: bool = True
                 ) -> dict[DesignRequest, DesignArtifact]:
        """Execute a request batch sequentially through the four stages:
        one coalesced exploration dispatch per explore group, then
        grid-shape-bucketed (or per-request) layout, demuxed into
        per-request artifacts.

        This is the same stage code the pipelined
        `repro.serve.design_service.DesignService` executor drives from
        per-stage workers — the sequential and pipelined paths cannot
        diverge because there is only one implementation of each stage.

        A request whose requirements remove every Pareto point raises
        `ValueError` under `strict=True`; under `strict=False` (the
        multi-tenant path) it gets an artifact with `error` set and the
        rest of the batch is served normally.

        With a persistent `artifact_cache`, requests found there are
        served directly (zero explorer/layout dispatches, provenance
        re-stamped `served_from="artifact_cache"`); the remainder runs
        the normal coalesced pipeline and is written back."""
        explored = self.explore_stage(requests)
        with self._span("distill", requests=len(explored.requests)):
            batch = self.distill_stage(explored, strict=strict,
                                       bucket_layouts=bucket_layouts)
        results = [self.layout_stage(b) for b in batch.buckets]
        with self._span("finalize", buckets=len(results)):
            return self.finalize_stage(batch, results)

    def run(self, request: DesignRequest) -> DesignArtifact:
        """Execute one request end to end (single-batch layout, so the
        artifact carries the full `BatchedLayoutResult` — unless it was
        served from the persistent artifact cache, which stores only the
        serializable `layout_rows`; check `provenance.served_from`)."""
        return self.run_many([request], bucket_layouts=False)[request]
