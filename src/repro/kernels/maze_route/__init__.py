from repro.kernels.maze_route.ops import INF, wavefront_distance
from repro.kernels.maze_route.ref import wavefront_distance_ref

__all__ = ["INF", "wavefront_distance", "wavefront_distance_ref"]
