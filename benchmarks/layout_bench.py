"""Layout benchmark: sequential per-spec `generate_layout` vs the batched
`generate_layouts` flow on a distilled Pareto set.

The layout counterpart of `benchmarks/explorer_bench.py`: PR 1 made the
MOGA sweep one compiled program; this measures the other half of paper
Fig. 4 — feeding the distilled Pareto set through placement / routing /
DRC.  The sequential baseline is B independent `flow.generate_layout`
calls (host netlist generation, named placement, one wavefront dispatch
per net); the batched path is `repro.api.DesignSession.layout` over
`eda.batched_flow.generate_layouts` (one vmapped placement dispatch,
one scanned routing program expanding all B wavefronts together,
closed-form netlist stats).  Two views:

  * end-to-end cold — includes compilation, what a fresh session pays;
  * warm — a second run with all programs compiled, the steady-state
    cost of laying out another same-shaped Pareto set.

Both paths must agree per spec (routing stats, DRC verdict, bounding
box) — recorded as `results_equal` and asserted in CI alongside
`batched_speedup_warm`.  Results land in `BENCH_layout.json` at the repo
root so future PRs have a perf trajectory.

  PYTHONPATH=src python -m benchmarks.layout_bench [--smoke] [--out PATH]

`--smoke` uses a smaller 8-spec set (array size 4096) for CI.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import time

import jax

from repro.api import DesignSession
from repro.core.acim_spec import MacroSpec
from repro.eda.flow import generate_layout

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

# 8-spec Pareto sets (h, w, l, b_adc): distilled fronts at a fixed array
# size, pinned here so the benchmark does not depend on explorer runtime.
SPECS_FULL = tuple(MacroSpec(*s) for s in [
    (128, 128, 2, 3), (128, 128, 4, 3), (256, 64, 2, 5), (256, 64, 4, 4),
    (256, 64, 8, 3), (512, 32, 8, 3), (512, 32, 16, 2), (512, 32, 32, 2),
])
SPECS_SMOKE = tuple(MacroSpec(*s) for s in [
    (64, 64, 2, 3), (64, 64, 2, 4), (64, 64, 4, 2), (64, 64, 8, 3),
    (128, 32, 2, 3), (128, 32, 4, 3), (128, 32, 8, 3), (128, 32, 16, 3),
])


def _sequential(specs):
    return [generate_layout(s) for s in specs]


def _spec_summary_seq(lr):
    return (lr.placement.width, lr.placement.height,
            len(lr.placement.rects), len(lr.routing.wires),
            len(lr.routing.failed), lr.routing.total_wirelength,
            lr.drc.overlaps, lr.drc.out_of_bounds)


def _spec_summaries_bat(res):
    out = []
    rect_counts = [sum(int(m[i].sum()) for _, m in res.tensors.values())
                   for i in range(len(res))]
    for i in range(len(res)):
        out.append((int(res.widths[i]), int(res.heights[i]), rect_counts[i],
                    int(res.routing.routed[i]), int(res.routing.failed[i]),
                    int(res.routing.wirelength[i]),
                    int(res.drc_overlaps[i]), int(res.drc_oob[i])))
    return out


def run(smoke: bool = False) -> dict:
    specs = SPECS_SMOKE if smoke else SPECS_FULL

    t0 = time.perf_counter()
    seq = _sequential(specs)
    seq_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    seq = _sequential(specs)
    seq_warm = time.perf_counter() - t0

    session = DesignSession()
    t0 = time.perf_counter()
    bat = session.layout(specs)
    bat_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = session.layout(specs)
    bat_warm = time.perf_counter() - t0

    results_equal = ([_spec_summary_seq(lr) for lr in seq]
                     == _spec_summaries_bat(bat))
    return {
        "specs": [s.as_tuple() for s in specs],
        "smoke": smoke,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "sequential": {"end_to_end_cold_s": seq_cold,
                       "end_to_end_warm_s": seq_warm},
        "batched": {"end_to_end_cold_s": bat_cold,
                    "end_to_end_warm_s": bat_warm},
        "batched_speedup_cold": seq_cold / bat_cold,
        "batched_speedup_warm": seq_warm / bat_warm,
        "batched_le_sequential": (bat_warm <= seq_warm
                                  and bat_cold <= seq_cold),
        "results_equal": results_equal,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller 8-spec set for CI")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_layout.json"))
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    for side in ("sequential", "batched"):
        r = result[side]
        print(f"{side}: cold={r['end_to_end_cold_s']:.3f}s "
              f"warm={r['end_to_end_warm_s']:.3f}s")
    print(f"speedup(warm)={result['batched_speedup_warm']:.2f}x "
          f"results_equal={result['results_equal']} -> {args.out}")


if __name__ == "__main__":
    main()
