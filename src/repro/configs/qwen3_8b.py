"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm, GQA, no QKV bias.  [hf:Qwen/Qwen3-8B; hf]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab=151936, head_dim=128,
    norm="rmsnorm", act="silu", mlp_gated=True, qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B; hf",
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen3-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16,
)
