"""Atomic, sharded, elastic checkpointing.

Layout:  <dir>/step_<n>/
            manifest.json        step, flat param names, shapes, dtypes,
                                 tree structure hash, config name
            arrays.npz           one entry per flattened leaf (host values)
         <dir>/LATEST            atomic pointer file (rename-committed)

Properties:
  * atomic: written to step_<n>.tmp.<pid>, fsync'd, renamed — a crash never
    corrupts the latest checkpoint;
  * elastic: restore() takes the *target* shardings; arrays saved on one
    mesh restore onto any other mesh/topology (tests: save (2,4) ->
    restore (4,2) and (8,));
  * quantized optimizer states and any pytree of arrays are supported
    (names are flattened key paths).

On a real multi-host pod, each process saves only addressable shards (the
`process_slice` hook); this container is single-process so the full value
path is exercised.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict[str, jax.Array]:
    flat = {}

    def add(path, leaf):
        flat[jax.tree_util.keystr(path)] = leaf

    jax.tree_util.tree_map_with_path(add, tree)
    return flat


def _treedef_fingerprint(tree: PyTree) -> str:
    spec = jax.tree_util.tree_structure(tree)
    return hashlib.sha256(str(spec).encode()).hexdigest()[:16]


def save(directory: str | os.PathLike, step: int, tree: PyTree,
         *, extra: dict | None = None) -> pathlib.Path:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step:08d}"
    tmp = d / f"step_{step:08d}.tmp.{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # npz can't represent ml_dtypes (bfloat16 loads back as void): widen to
    # f32 on disk; restore() casts back to the target struct dtype.
    disk = {k: (v.astype(np.float32) if v.dtype.name == "bfloat16" else v)
            for k, v in host.items()}
    np.savez(tmp / "arrays.npz", **disk)
    manifest = {
        "step": step,
        "tree_fingerprint": _treedef_fingerprint(tree),
        "names": sorted(host),
        "shapes": {k: list(v.shape) for k, v in host.items()},
        "dtypes": {k: str(v.dtype) for k, v in host.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    with open(tmp / "manifest.json", "rb") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    latest_tmp = d / f"LATEST.tmp.{os.getpid()}"
    latest_tmp.write_text(final.name)
    latest_tmp.rename(d / "LATEST")
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    d = pathlib.Path(directory)
    ptr = d / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (d / name / "manifest.json").exists():
        # fall back to scanning (LATEST may point at a preempted write)
        steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*")
                       if (p / "manifest.json").exists())
        return steps[-1] if steps else None
    return int(name.split("_")[1])


def restore(directory: str | os.PathLike, step: int, target_struct: PyTree,
            shardings: PyTree | None = None) -> PyTree:
    """Restore into `target_struct`'s tree/shape/dtype; `shardings` (same
    tree) places each leaf — pass the *new* mesh's shardings for elastic
    restore."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    if manifest["tree_fingerprint"] != _treedef_fingerprint(target_struct):
        raise ValueError("checkpoint tree structure mismatch")
    data = np.load(d / "arrays.npz")

    flat_struct = _flatten(target_struct)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for name, struct in flat_struct.items():
        arr = data[name]
        if tuple(arr.shape) != tuple(struct.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {struct.shape}")
        arr = arr.astype(struct.dtype)
        sh = flat_shard.get(name)
        out[name] = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)

    leaves_order = []

    def collect(path, leaf):
        leaves_order.append(out[jax.tree_util.keystr(path)])

    jax.tree_util.tree_map_with_path(collect, target_struct)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_struct), leaves_order)
