"""Project-wide call graph rooted at JAX trace regions.

Builds, from ASTs alone (nothing is imported), a conservative call
graph over every function in the scanned tree, marking the **traced
roots**: functions that enter a JAX trace —

  * decorated ``@jax.jit`` / ``@functools.partial(jax.jit, ...)`` /
    ``@jax.vmap`` / ``@jax.checkpoint``;
  * passed callable-first to a trace wrapper call: ``jax.jit(f)``,
    ``jax.vmap(f)``, ``jax.lax.scan(f, ...)``, ``pl.pallas_call(f)``,
    ``shard_map(f, ...)`` — including lambdas and nested ``def``s.

Edges follow direct calls: bare names (nested defs, then module
globals), ``from x import f`` bindings, and ``mod.f`` where ``mod`` is
an imported project module.  Method calls through objects are not
resolved (conservative under-approximation: the passes that consume
the graph flag what they can prove, never guess).

**Trace-guard pruning**: statements after ``if _traced(...): raise``
(or an ``isinstance(x, jax.core.Tracer)`` test that raises) in the same
block are *host-only* — a traced execution cannot reach them — so calls
there do not extend traced reachability.  This is exactly the
`kernels/*/ops.py` dispatch contract (`docs/kernels.md`): the host-impl
branch is fenced off by a raising trace check, and
`repro.analysis.trace_purity` separately verifies the fence exists.
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis.core import Module, dotted, import_map

# Normalized dotted names whose first callable argument enters a trace.
TRACE_WRAPPERS = {
    "jax.jit", "jax.vmap", "jax.pmap", "jax.checkpoint", "jax.grad",
    "jax.value_and_grad", "jax.lax.scan", "jax.lax.map",
    "jax.lax.while_loop", "jax.lax.fori_loop", "jax.lax.cond",
    "jax.experimental.pallas.pallas_call",
    "jax.experimental.shard_map.shard_map",
}
# Also accepted unnormalized (conventional aliases), so fixture modules
# and unusual import spellings still root correctly.
_ALIAS_WRAPPERS = {"jit", "vmap", "pallas_call", "shard_map", "scan"}


@dataclasses.dataclass
class CallSite:
    node: ast.Call
    norm: str | None       # normalized dotted target ("time.time"), if any
    fid: str | None        # resolved project function id, if any
    host_only: bool        # lexically fenced behind a trace-guard raise


@dataclasses.dataclass
class FuncInfo:
    fid: str               # "module.name:qualname"
    module: Module
    qualname: str
    node: ast.AST          # FunctionDef / AsyncFunctionDef / Lambda
    calls: list[CallSite] = dataclasses.field(default_factory=list)
    traced_root: str | None = None   # why this function roots a trace


def _is_trace_guard(stmt: ast.stmt) -> bool:
    """``if <trace check>: raise ...`` — the ops-contract fence."""
    if not isinstance(stmt, ast.If):
        return False
    if not any(isinstance(s, ast.Raise) for s in stmt.body):
        return False
    for node in ast.walk(stmt.test):
        if isinstance(node, ast.Call):
            name = dotted(node.func) or ""
            if "traced" in name.split(".")[-1].lower():
                return True
            if name.endswith("isinstance") or name == "isinstance":
                tail = node.args[1] if len(node.args) > 1 else None
                if tail is not None and "Tracer" in ast.dump(tail):
                    return True
    return False


class CallGraph:
    def __init__(self, modules: dict[str, Module]):
        self.modules = modules
        self.functions: dict[str, FuncInfo] = {}
        self._module_scope: dict[str, dict[str, str]] = {}  # mod -> name->fid
        self._imports: dict[str, dict[str, str]] = {}
        for mod in modules.values():
            self._imports[mod.name] = import_map(mod.tree)
            self._collect(mod)
        for mod in modules.values():
            self._link(mod)

    # -- pass 1: enumerate functions ----------------------------------
    def _collect(self, mod: Module) -> None:
        scope: dict[str, str] = {}
        self._module_scope[mod.name] = scope

        def walk(node: ast.AST, prefix: str) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{prefix}{child.name}"
                    fid = f"{mod.name}:{qual}"
                    self.functions[fid] = FuncInfo(fid, mod, qual, child)
                    if not prefix:
                        scope[child.name] = fid
                    walk(child, qual + ".")
                elif isinstance(child, ast.ClassDef):
                    walk(child, f"{prefix}{child.name}.")
                elif isinstance(child, ast.Lambda):
                    qual = f"{prefix}<lambda@{child.lineno}>"
                    fid = f"{mod.name}:{qual}"
                    self.functions[fid] = FuncInfo(fid, mod, qual, child)
                    walk(child, qual + ".")
                else:
                    walk(child, prefix)

        walk(mod.tree, "")

    # -- name resolution ----------------------------------------------
    def _resolve_module(self, here: str, target: str) -> str:
        """Resolve a possibly-relative dotted module path."""
        if not target.startswith("."):
            return target
        level = len(target) - len(target.lstrip("."))
        base = here.split(".")
        # a module's imports resolve against its package
        base = base[:-1] if len(base) >= level else []
        base = base[: len(base) - (level - 1)] if level > 1 else base
        rest = target.lstrip(".")
        return ".".join(base + ([rest] if rest else []))

    def _resolve_name(self, mod: Module, scope_chain: list[str],
                      name: str) -> tuple[str | None, str | None]:
        """A bare name -> (project fid, normalized dotted), best effort."""
        for outer in reversed(scope_chain):
            fid = f"{mod.name}:{outer}.{name}" if outer else None
            if fid and fid in self.functions:
                return fid, None
        fid = self._module_scope[mod.name].get(name)
        if fid:
            return fid, None
        origin = self._imports[mod.name].get(name)
        if origin:
            origin = self._resolve_module(mod.name, origin)
            head, _, tail = origin.rpartition(".")
            if head in self.modules and f"{head}:{tail}" in self.functions:
                return f"{head}:{tail}", origin
            return None, origin
        return None, name    # builtin / unknown global

    def _resolve_call(self, mod: Module, scope_chain: list[str],
                      call: ast.Call) -> tuple[str | None, str | None]:
        name = dotted(call.func)
        if name is None:
            return None, None
        if "." not in name:
            return self._resolve_name(mod, scope_chain, name)
        root, _, rest = name.partition(".")
        origin = self._imports[mod.name].get(root)
        if origin is None:
            return None, name            # e.g. self.x(), obj.m()
        origin = self._resolve_module(mod.name, origin)
        norm = f"{origin}.{rest}"
        if origin in self.modules:
            head, _, tail = norm.rpartition(".")
            if head in self.modules and f"{head}:{tail}" in self.functions:
                return f"{head}:{tail}", norm
        return None, norm

    # -- pass 2: edges + traced roots ---------------------------------
    def _link(self, mod: Module) -> None:
        graph = self

        def func_of(scope_chain: list[str]) -> FuncInfo | None:
            if not scope_chain:
                return None
            return graph.functions.get(f"{mod.name}:{scope_chain[-1]}")

        def handle_call(call: ast.Call, scope_chain: list[str],
                        host_only: bool) -> None:
            fid, norm = graph._resolve_call(mod, scope_chain, call)
            info = func_of(scope_chain)
            if info is not None:
                info.calls.append(CallSite(call, norm, fid, host_only))
            # does this call enter a trace with a callable argument?
            wrapper = norm or (dotted(call.func) or "")
            short = wrapper.split(".")[-1]
            if wrapper in TRACE_WRAPPERS or short in _ALIAS_WRAPPERS:
                for arg in call.args[:1]:
                    graph._root_arg(mod, scope_chain, arg,
                                    f"passed to {wrapper or short}()")
                for kw in call.keywords:
                    if kw.arg in ("f", "fun", "func", "body_fun", "kernel"):
                        graph._root_arg(mod, scope_chain, kw.value,
                                        f"passed to {wrapper or short}()")
            # functools.partial(jax.jit, ...) used as a decorator factory
            if short == "partial" and call.args:
                inner = dotted(call.args[0])
                if inner:
                    _, inner_norm = graph._resolve_call(
                        mod, scope_chain,
                        ast.Call(func=call.args[0], args=[], keywords=[]))
                    if (inner_norm or inner) in TRACE_WRAPPERS:
                        for arg in call.args[1:2]:
                            graph._root_arg(mod, scope_chain, arg,
                                            f"partial({inner})")

        def visit_block(stmts: list[ast.stmt], scope_chain: list[str],
                        host_only: bool) -> None:
            fenced = host_only
            for stmt in stmts:
                visit_node(stmt, scope_chain, fenced)
                if _is_trace_guard(stmt):
                    fenced = True

        def visit_node(node: ast.AST, scope_chain: list[str],
                       host_only: bool) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = (f"{scope_chain[-1]}.{node.name}" if scope_chain
                        else node.name)
                info = graph.functions[f"{mod.name}:{qual}"]
                graph._apply_decorators(mod, scope_chain, info)
                for dec in node.decorator_list:
                    visit_node(dec, scope_chain, host_only)
                visit_block(node.body, scope_chain + [qual], False)
                return
            if isinstance(node, ast.ClassDef):
                qual = (f"{scope_chain[-1]}.{node.name}" if scope_chain
                        else node.name)
                # method qualnames nest under the class, not the function
                visit_block(node.body, scope_chain[:-1] + [qual]
                            if scope_chain else [qual], host_only)
                return
            if isinstance(node, ast.Lambda):
                qual = (f"{scope_chain[-1]}.<lambda@{node.lineno}>"
                        if scope_chain else f"<lambda@{node.lineno}>")
                visit_node(node.body, scope_chain + [qual], host_only)
                return
            if isinstance(node, ast.Call):
                handle_call(node, scope_chain, host_only)
            for stmt_field in ("body", "orelse", "finalbody"):
                block = getattr(node, stmt_field, None)
                if (isinstance(block, list) and block
                        and isinstance(block[0], ast.stmt)):
                    visit_block(block, scope_chain, host_only)
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.stmt):
                    continue               # handled by the block visitor
                visit_node(child, scope_chain, host_only)

        visit_block(mod.tree.body, [], False)

    def _apply_decorators(self, mod: Module, scope_chain: list[str],
                          info: FuncInfo) -> None:
        for dec in info.node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            name = dotted(target) or ""
            _, norm = self._resolve_call(
                mod, scope_chain,
                ast.Call(func=target, args=[], keywords=[])) \
                if name else (None, None)
            full = norm or name
            short = full.split(".")[-1]
            if full in TRACE_WRAPPERS or short in _ALIAS_WRAPPERS:
                info.traced_root = f"decorated @{full or short}"
            elif short == "partial" and isinstance(dec, ast.Call) \
                    and dec.args:
                inner = dotted(dec.args[0]) or ""
                _, inner_norm = self._resolve_call(
                    mod, scope_chain,
                    ast.Call(func=dec.args[0], args=[], keywords=[]))
                if (inner_norm or inner) in TRACE_WRAPPERS:
                    info.traced_root = f"decorated @partial({inner})"

    def _root_arg(self, mod: Module, scope_chain: list[str],
                  arg: ast.expr, why: str) -> None:
        if isinstance(arg, ast.Lambda):
            qual = (f"{scope_chain[-1]}.<lambda@{arg.lineno}>"
                    if scope_chain else f"<lambda@{arg.lineno}>")
            info = self.functions.get(f"{mod.name}:{qual}")
            if info is not None and info.traced_root is None:
                info.traced_root = why
            return
        name = dotted(arg)
        if name is None:
            return
        if "." in name:
            fid, _ = self._resolve_call(
                mod, scope_chain, ast.Call(func=arg, args=[], keywords=[]))
        else:
            fid, _ = self._resolve_name(mod, scope_chain, name)
        if fid is not None:
            info = self.functions[fid]
            if info.traced_root is None:
                info.traced_root = why

    # -- reachability ---------------------------------------------------
    def traced_reachable(self) -> dict[str, str]:
        """fid -> provenance string ("root: ..." or "via <caller fid>")
        for every function a traced execution can reach.  Host-only
        (guard-fenced) call sites do not extend reachability."""
        frontier = [(fid, f"root: {info.traced_root}")
                    for fid, info in self.functions.items()
                    if info.traced_root is not None]
        seen: dict[str, str] = {}
        while frontier:
            fid, why = frontier.pop()
            if fid in seen:
                continue
            seen[fid] = why
            for site in self.functions[fid].calls:
                if site.host_only or site.fid is None:
                    continue
                if site.fid not in seen:
                    frontier.append((site.fid, f"via {fid}"))
        return seen
