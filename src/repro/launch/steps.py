"""Step builders: distributed train / prefill / decode steps for any
(arch x shape x mesh) cell.

Each builder returns (jitted_fn, in_shardings-consistent ShapeDtypeStruct
trees) so the same code path serves the real trainer/server AND the
multi-pod dry-run (`launch/dryrun.py` lowers with the struct trees; the
trainer feeds real arrays with identical shardings).

Logical activation rules are installed around tracing via
`repro.parallel.axes.set_rules`, so `with_sharding_constraint`s bind to the
target mesh; sequence (Megatron-style SP) is mapped to "model" for the
attention families during training, and `qseq` for 32k prefill.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch import shapes as shp
from repro.models.registry import build_model
from repro.optim import adamw
from repro.parallel.axes import set_rules
from repro.parallel.sharding import ShardingPolicy, make_policy

PyTree = Any


def _seq_parallel(cfg: ArchConfig) -> bool:
    """Megatron-SP residual-stream sharding.  REFUTED hypothesis (see
    EXPERIMENTS.md §Perf): under GSPMD the seq<->heads resharding at the
    attention einsums triggers involuntary full rematerialization
    (replicate-then-slice), exploding temp memory 8x.  Kept off; per-layer
    activation pressure is handled by microbatching instead."""
    return False


def default_opt_cfg(cfg: ArchConfig) -> adamw.AdamWConfig:
    """Per-arch optimizer memory policy: the 480B config needs int8
    blockwise moments + FSDP to fit 16 GB/chip (see DESIGN.md §6)."""
    if cfg.name == "arctic-480b":
        return adamw.AdamWConfig(quantized_moments=True)
    if cfg.name == "granite-34b":
        return adamw.AdamWConfig(moment_dtype=jnp.bfloat16)
    return adamw.AdamWConfig()


# master-parameter dtype (bf16 for the 480B config: with int8 moments this
# is what fits 16 GB/chip; stochastic-rounding caveat recorded in DESIGN.md)
PARAM_DTYPE = {"arctic-480b": jnp.bfloat16}

# per-arch logical-rule overrides for training
ARCH_TRAIN_RULES = {
    "arctic-480b": {"embed_carry": "model"},
    "granite-34b": {"embed_carry": "model"},
}

# §Perf hillclimb variants (EXPERIMENTS.md): selected per-cell overrides.
# The hypothesis->napkin-math->measure log lives in EXPERIMENTS.md §Perf.
PERF_TRAIN_OVERRIDES = {
    # it1: kill the FSDP regather-per-microbatch (mb 4 -> 1; the sharded
    #      residual carry makes the larger per-mb activations fit)
    # it2: padded merged heads 56->64 (sharding.py `padded_heads`) — always
    #      on now via the default rules
    # it3: bf16 parameter cast in loss -> bf16 grad collectives & gathers
    "arctic-480b": dict(microbatches=1, cast_bf16=True),
    # it1: mb 4 -> 1 (TP all-reduce volume /4); it2: ZeRO-3 model axis
    "qwen2.5-3b": dict(microbatches=1, model_strategy="fsdp"),
    # tiny model: TP is pure overhead -> ZeRO-3 + no accumulation
    "xlstm-125m": dict(microbatches=1, model_strategy="fsdp"),
    # rollout of the confirmed qwen2.5 recipe to the other <=3B archs
    # (ZeRO-3 only viable while the hoisted bf16 layer stack fits: <=~3B)
    "paligemma-3b": dict(microbatches=1, model_strategy="fsdp"),
    "zamba2-2.7b": dict(microbatches=2, model_strategy="fsdp"),
    "whisper-large-v3": dict(microbatches=1, model_strategy="fsdp"),
}


def accum_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.name == "arctic-480b" else jnp.float32


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TrainStep:
    fn: Any                    # jitted (state, batch) -> (state, metrics)
    state_struct: PyTree       # ShapeDtypeStructs with shardings
    batch_struct: PyTree
    policy: ShardingPolicy


def make_train_state_struct(cfg: ArchConfig, policy: ShardingPolicy,
                            opt_cfg: adamw.AdamWConfig):
    api = build_model(cfg)
    pshape = jax.eval_shape(api.init, jax.random.key(0))
    pdt = PARAM_DTYPE.get(cfg.name)
    if pdt is not None:
        pshape = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, pdt if (s.dtype == jnp.float32 and s.ndim >= 2)
                else s.dtype), pshape)
    pshard = policy.param_shardings(pshape)
    oshape = jax.eval_shape(functools.partial(adamw.init, cfg=opt_cfg), pshape)
    if opt_cfg.quantized_moments:
        # moments: {"q": like-param, "s": param minus last-dim sharding}
        def qshard(sh):
            spec = sh.spec
            sspec = P(*(list(spec)[:-1] + [None])) if len(spec) else P()
            return {"q": sh, "s": NamedSharding(policy.mesh, sspec)}

        mshard = jax.tree.map(qshard, pshard,
                              is_leaf=lambda x: isinstance(x, NamedSharding))
        oshard = {"m": mshard, "v": mshard,
                  "count": NamedSharding(policy.mesh, P())}
    else:
        oshard = {"m": pshard, "v": pshard,
                  "count": NamedSharding(policy.mesh, P())}

    def with_sh(tree, shtree):
        return jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            tree, shtree)

    state = {"params": with_sh(pshape, pshard),
             "opt": with_sh(oshape, oshard),
             "step": jax.ShapeDtypeStruct((), jnp.int32,
                                          sharding=NamedSharding(policy.mesh, P()))}
    return state, {"params": pshard, "opt": oshard,
                   "step": NamedSharding(policy.mesh, P())}


def make_train_step(cfg: ArchConfig, mesh, *, opt_cfg: adamw.AdamWConfig | None = None,
                    microbatches: int = 1, remat: bool = True,
                    fsdp: bool | None = None, model_strategy: str = "tp",
                    cast_bf16: bool = False,
                    extra_rules: dict | None = None) -> TrainStep:
    opt_cfg = opt_cfg or default_opt_cfg(cfg)
    policy = make_policy(mesh, cfg, fsdp=fsdp, model_strategy=model_strategy)
    api = build_model(cfg, remat=remat, mlstm_chunked=(cfg.family == "ssm"))
    rules = policy.activation_rules()
    if _seq_parallel(cfg):
        rules["seq"] = policy.tp
    rules.update(ARCH_TRAIN_RULES.get(cfg.name, {}))
    if extra_rules:
        rules.update(extra_rules)

    state_struct, state_shard = make_train_state_struct(cfg, policy, opt_cfg)

    def train_step(state, batch):
        with set_rules(mesh, rules):
            def loss_fn(params, mb):
                if policy.compute_dtype_cast or cast_bf16:
                    params = jax.tree.map(
                        lambda p: p.astype(jnp.bfloat16)
                        if (p.ndim >= 2 and p.dtype == jnp.float32) else p,
                        params)
                loss, metrics = api.loss(params, mb)
                return loss, metrics

            if microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state["params"], batch)
            else:
                mb_batch = jax.tree.map(
                    lambda a: a.reshape((microbatches, a.shape[0] // microbatches)
                                        + a.shape[1:]), batch)

                acc_dt = accum_dtype(cfg)

                def mb_step(carry, mb):
                    gacc, lacc = carry
                    (loss, metrics), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(state["params"], mb)
                    gacc = jax.tree.map(
                        lambda a, b: a + b.astype(acc_dt), gacc, g)
                    return (gacc, lacc + loss), metrics

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                  state["params"])
                (grads, loss), mstack = jax.lax.scan(mb_step, (g0, 0.0), mb_batch)
                grads = jax.tree.map(lambda g: g / microbatches, grads)
                loss = loss / microbatches
                metrics = jax.tree.map(lambda a: a[-1], mstack)

            new_p, new_opt, opt_metrics = adamw.update(
                grads, state["opt"], state["params"], opt_cfg)
            metrics = dict(metrics, **opt_metrics, loss=loss)
            new_state = {"params": new_p, "opt": new_opt,
                         "step": state["step"] + 1}
            return new_state, metrics

    bstruct = shp.batch_struct(cfg, shp.SHAPES["train_4k"])
    bshard = policy.batch_specs(bstruct)
    bstruct = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        bstruct, bshard)

    fn = jax.jit(train_step,
                 in_shardings=(state_shard, bshard),
                 out_shardings=(state_shard, None),
                 donate_argnums=(0,))
    return TrainStep(fn=fn, state_struct=state_struct, batch_struct=bstruct,
                     policy=policy)


# ---------------------------------------------------------------------------
# prefill (forward-only logits)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PrefillStep:
    fn: Any
    params_struct: PyTree
    batch_struct: PyTree
    policy: ShardingPolicy


def _to_serving_dtype(pshape):
    """Serving holds weights in bf16 (halves HBM; matmuls run bf16 anyway)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 and s.ndim >= 2
            else s.dtype), pshape)


def make_prefill_step(cfg: ArchConfig, mesh, shape: shp.ShapeSpec,
                      fsdp: bool | None = None) -> PrefillStep:
    policy = make_policy(mesh, cfg, fsdp=fsdp)
    # default rules: heads over "model" when divisible, else qseq (context
    # parallel); blockwise attention bounds score memory either way.
    rules = policy.activation_rules()
    api = build_model(cfg)
    pshape = _to_serving_dtype(jax.eval_shape(api.init, jax.random.key(0)))
    pshard = policy.param_shardings(pshape)
    pstruct = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        pshape, pshard)

    from repro.models import lm, paligemma, whisper

    def prefill(params, batch):
        with set_rules(mesh, rules):
            if cfg.family == "audio":
                enc = whisper.encode(params, batch["frames"], cfg)
                return whisper.decode_fwd(params, batch["inputs"], enc, cfg,
                                          attn_impl="blockwise")
            if cfg.family == "vlm":
                hidden, _ = lm.lm_hidden(params, batch["inputs"], cfg,
                                         prefix_embeds=batch["patches"],
                                         attn_impl="blockwise")
                return lm.lm_logits(params, hidden, cfg)
            hidden, _ = lm.lm_hidden(params, batch["inputs"], cfg,
                                     attn_impl="blockwise",
                                     mlstm_chunked=(cfg.family == "ssm"))
            return lm.lm_logits(params, hidden, cfg)

    bstruct = shp.batch_struct(cfg, shape)
    bstruct.pop("targets")
    bshard = policy.batch_specs(bstruct)
    bstruct = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        bstruct, bshard)
    fn = jax.jit(prefill, in_shardings=(pshard, bshard))
    return PrefillStep(fn=fn, params_struct=pstruct, batch_struct=bstruct,
                       policy=policy)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServeStep:
    fn: Any
    params_struct: PyTree
    state_struct: PyTree
    tokens_struct: Any
    policy: ShardingPolicy


def make_serve_step(cfg: ArchConfig, mesh, shape: shp.ShapeSpec,
                    fsdp: bool | None = None) -> ServeStep:
    policy = make_policy(mesh, cfg, fsdp=fsdp)
    rules = policy.activation_rules(decode_batch=shape.batch)
    api = build_model(cfg)
    pshape = _to_serving_dtype(jax.eval_shape(api.init, jax.random.key(0)))
    pshard = policy.param_shardings(pshape)
    sshape = jax.eval_shape(
        functools.partial(api.init_decode_state, shape.batch, shape.seq))
    sshard = policy.decode_state_specs(sshape, shape.batch)

    def serve_step(params, state, tokens):
        with set_rules(mesh, rules):
            return api.decode_step(params, state, tokens)

    batch_ax = rules["batch"]
    tshard = NamedSharding(mesh, P(batch_ax))
    tstruct = jax.ShapeDtypeStruct((shape.batch,), jnp.int32, sharding=tshard)

    def with_sh(tree, shtree):
        return jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            tree, shtree)

    fn = jax.jit(serve_step,
                 in_shardings=(pshard, sshard, tshard),
                 out_shardings=(None, sshard),
                 donate_argnums=(1,))
    return ServeStep(fn=fn, params_struct=with_sh(pshape, pshard),
                     state_struct=with_sh(sshape, sshard),
                     tokens_struct=tstruct, policy=policy)
