"""Analytic FLOP / HBM-byte model per (arch x shape) cell.

Why analytic: XLA's HloCostAnalysis counts each `while` body ONCE — with
scan-over-layers (and grad-accumulation scans) the reported FLOPs/bytes are
low by the product of trip counts (verified: a 10-trip scan of matmuls
reports exactly 1/10th).  Rather than unrolling 88-layer stacks at 512
devices (hours of compile), the dry-run records the raw cost_analysis AND
these analytic terms; a unit test cross-checks the analytic model against
cost_analysis on a small unrolled configuration to <15%.

Conventions (global, per step):
  train FLOPs  = (2 fwd + 2 recompute-under-remat/3 + 4 bwd) matmul flops
                 = 6 * N_mat * T * remat_factor(4/3)  + attention/SSD terms
  N_mat        = matmul parameters (active for MoE; embedding lookup and
                 positional tables excluded, LM head included)
  attention    = 6 * L * B * S^2 * H * dh * (0.5 causal) * remat_factor
  bytes        = parameter traffic (fwd/bwd/recompute reads per microbatch
                 + optimizer read/write) + activation traffic
                 (~8 bytes/elem/layer heuristic for read+write over
                 norm/attn/mlp internals) + dense-score traffic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.shapes import ShapeSpec, microbatches_for
from repro.models.registry import count_params, embedding_params


@dataclasses.dataclass(frozen=True)
class CellCost:
    flops: float              # global per step
    hbm_bytes: float          # global per step
    notes: str = ""


def matmul_params(cfg: ArchConfig, active: bool = True) -> float:
    return count_params(cfg, active_only=active and cfg.moe is not None) \
        - embedding_params(cfg) + cfg.vocab * cfg.d_model  # head back in


def _attn_flops_fwd(cfg: ArchConfig, b: int, s: int, causal: bool = True) -> float:
    l = cfg.n_layers
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    per = 4.0 * b * s * s * h * dh * (0.5 if causal else 1.0)
    if cfg.family == "hybrid":
        # only the shared block attends, once per group
        n_attn = cfg.n_layers // cfg.hybrid.shared_attn_every
        return per / l * n_attn if l else 0.0
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "audio":
        f = cfg.encdec.enc_frames
        enc = 4.0 * b * f * f * h * dh * cfg.encdec.n_enc_layers
        dec_self = per * 1.0
        cross = 4.0 * b * s * f * h * dh * cfg.n_layers
        return enc + dec_self + cross
    return per * l


def _ssd_flops_fwd(cfg: ArchConfig, b: int, s: int) -> float:
    if cfg.family == "hybrid":
        ss = cfg.ssm
        d_inner = ss.expand * cfg.d_model
        nh = d_inner // ss.head_dim
        ch = min(ss.chunk, s)
        # intra-chunk quasi-attention + inter-chunk state products
        intra = 4.0 * b * s * ch * nh * (ss.state + ss.head_dim)
        inter = 4.0 * b * s * nh * ss.state * ss.head_dim
        return (intra + inter) * cfg.n_layers
    if cfg.family == "ssm":
        x = cfg.xlstm
        inner = int(x.proj_factor * cfg.d_model)
        nh = cfg.n_heads
        dh = inner // nh
        ch = x.chunk
        n_pairs = cfg.n_layers // 2
        mlstm = (4.0 * b * s * ch * nh * dh          # intra scores+values
                 + 4.0 * b * s * nh * dh * dh)       # state in/out products
        slstm = 8.0 * b * s * nh * dh * dh           # recurrent gate matmuls
        return (mlstm + slstm) * n_pairs
    return 0.0


def _moe_dispatch_flops_fwd(cfg: ArchConfig, t: float) -> float:
    if cfg.moe is None:
        return 0.0
    from repro.models.mlp import moe_capacity

    m = cfg.moe
    c = moe_capacity(m)
    return 4.0 * t * m.n_experts * c * cfg.d_model * cfg.n_layers \
        / max(m.group_size / min(m.group_size, t), 1)


def train_cost(cfg: ArchConfig, shape: ShapeSpec) -> CellCost:
    b, s = shape.batch, shape.seq
    t = float(b * s)
    nm = matmul_params(cfg)
    remat = 4.0 / 3.0
    fwd = 2.0 * nm * t + _attn_flops_fwd(cfg, b, s) + _ssd_flops_fwd(cfg, b, s) \
        + _moe_dispatch_flops_fwd(cfg, t)
    flops = 3.0 * fwd * remat

    mb = microbatches_for(cfg, shape)
    p_total = count_params(cfg)        # stored params (all experts)
    p_bytes = 4.0                      # f32 master
    opt_bytes = 16.0                   # m,v read+write (f32) avg
    # per microbatch: fwd read + bwd read + remat re-read of weights
    w_traffic = p_total * p_bytes * 3.0 * mb + p_total * (opt_bytes + 2 * p_bytes)
    act_traffic = cfg.n_layers * t * cfg.d_model * 2.0 * 8.0
    score_traffic = 0.0
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        # dense-materialized fp32 scores read+write, fwd+bwd (baseline impl)
        score_traffic = 2.0 * b * s * s * cfg.n_heads * 4.0 * 2.0 * (
            1 if cfg.family != "hybrid" else 0)
        if cfg.family == "moe":
            pass
    return CellCost(flops, w_traffic + act_traffic + score_traffic)


def prefill_cost(cfg: ArchConfig, shape: ShapeSpec) -> CellCost:
    b, s = shape.batch, shape.seq
    t = float(b * s)
    nm = matmul_params(cfg)
    flops = 2.0 * nm * t + _attn_flops_fwd(cfg, b, s) \
        + _ssd_flops_fwd(cfg, b, s) + _moe_dispatch_flops_fwd(cfg, t)
    w 	= count_params(cfg) * 2.0      # bf16 serving weights, read once
    act = cfg.n_layers * t * cfg.d_model * 2.0 * 6.0
    return CellCost(flops, w + act)


def decode_cost(cfg: ArchConfig, shape: ShapeSpec) -> CellCost:
    b, s = shape.batch, shape.seq
    nm = matmul_params(cfg)
    flops = 2.0 * nm * b
    # attention over the cache (linear per token)
    h, dh, kv = cfg.n_heads, cfg.resolved_head_dim, cfg.n_kv_heads
    cache_bytes = 0.0
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.mla is not None:
            m = cfg.mla
            flops += 4.0 * b * s * h * (m.kv_lora + m.rope_dim) * cfg.n_layers
            cache_bytes = b * s * (m.kv_lora + m.rope_dim) * 2.0 * cfg.n_layers
        else:
            flops += 4.0 * b * s * h * dh * cfg.n_layers
            cache_bytes = 2.0 * b * s * kv * dh * 2.0 * cfg.n_layers
    if cfg.family == "audio":
        f = cfg.encdec.enc_frames
        flops += (4.0 * b * s * h * dh + 4.0 * b * f * h * dh) * cfg.n_layers
        cache_bytes = (2.0 * b * s * kv * dh + 2.0 * b * f * h * dh) * 2.0 \
            * cfg.n_layers
    if cfg.family == "hybrid":
        hy = cfg.hybrid
        n_attn = cfg.n_layers // hy.shared_attn_every
        flops += 4.0 * b * s * hy.attn_heads * (cfg.d_model // hy.attn_heads) \
            * n_attn
        ss = cfg.ssm
        d_inner = ss.expand * cfg.d_model
        nh = d_inner // ss.head_dim
        state = b * nh * ss.state * ss.head_dim * 4.0 * cfg.n_layers
        cache_bytes = 2.0 * b * s * hy.attn_kv_heads * (
            cfg.d_model // hy.attn_heads) * 2.0 * n_attn + 2.0 * state
        flops += 6.0 * b * nh * ss.state * ss.head_dim * cfg.n_layers
    if cfg.family == "ssm":
        x = cfg.xlstm
        inner = int(x.proj_factor * cfg.d_model)
        nh = cfg.n_heads
        dh_i = inner // nh
        n_pairs = cfg.n_layers // 2
        flops += (6.0 * b * nh * dh_i * dh_i + 8.0 * b * nh * dh_i * dh_i) \
            * n_pairs
        cache_bytes = 2.0 * b * nh * dh_i * dh_i * 4.0 * n_pairs
    weights = count_params(cfg) * 2.0          # bf16, read once per token
    return CellCost(flops, weights + cache_bytes + b * cfg.n_layers
                    * cfg.d_model * 2.0 * 6.0)


def cell_cost(cfg: ArchConfig, shape: ShapeSpec) -> CellCost:
    if shape.kind == "train":
        return train_cost(cfg, shape)
    if shape.kind == "prefill":
        return prefill_cost(cfg, shape)
    return decode_cost(cfg, shape)
