"""NSGA-II (Deb et al.) specialized for the EasyACIM design space, in JAX.

The paper uses an off-the-shelf NSGA-II over (H, W, L, B_ADC) with the
Eq. 12 constraints.  Here the whole *run* — init, evaluation, tournament
selection, crossover, mutation, repair, elitist environmental selection,
looped over generations — is one jit-compiled program (`run_cell`);
populations are plain int32 gene arrays so the explorer can also be sharded
across a device mesh (see `repro.parallel.distributed_explorer`).

One-compile sweep contract
--------------------------
Everything that varies across a design-space sweep cell — the array size,
the gene box bounds it implies, and the calibration constants — is carried
as *traced operand arrays* (`SpaceOperands`), never as static config.  The
only static arguments are structural (population size, generation count,
variation probabilities, kernel selection).  Consequently:

  * a sequential sweep over array sizes compiles the generation program
    once and re-dispatches it per size, and
  * `repro.core.batched_explorer.explore_batch` can `jax.vmap` `run_cell`
    over a stacked `SpaceOperands` batch so a whole (array_size x seed)
    sweep is ONE compilation and ONE device program.

Ranks and crowding distances are threaded through the generation carry:
environmental selection ranks the combined 2P population once, and the
surviving P parents inherit their (exact — see `generation_step_op`) ranks
instead of being re-ranked at the top of the next generation.

Gene encoding (all powers of two, matching the binary-ratioed CDAC):
    gene[0] = h_exp   -> H = 2**h_exp
    gene[1] = l_exp   -> L = 2**l_exp
    gene[2] = b_adc
W is implied by the H*W = array_size equality constraint (Eq. 12), so it is
not a free gene — this is exact constraint elimination rather than penalty
handling.  The two inequality constraints (H >= L, H/L >= 2^B) are handled
by *repair* (clamping), which keeps every individual feasible; a
constrained-domination path (Deb's rules) is also provided for generality
and is exercised by the tests.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator, pareto
from repro.core.constants import CAL28, CalibConstants

Array = jax.Array

# Trace-count probe: incremented (as a Python side effect) every time the
# generation program body is traced.  `benchmarks/explorer_bench.py` and the
# batched-explorer tests read deltas of this counter to assert the
# one-compile sweep contract.
TRACE_COUNTS: collections.Counter = collections.Counter()

# Single source of truth for the variation-probability defaults shared by
# NSGA2Config and EvolveStatics.
DEFAULT_CROSSOVER_PROB = 0.9
DEFAULT_MUTATION_PROB = 0.2


@dataclasses.dataclass(frozen=True)
class NSGA2Config:
    array_size: int
    pop_size: int = 256
    generations: int = 80
    crossover_prob: float = DEFAULT_CROSSOVER_PROB
    mutation_prob: float = DEFAULT_MUTATION_PROB
    tournament_pairs: int = 2
    seed: int = 0
    cal: CalibConstants = CAL28
    use_pallas_dominance: bool = False  # Pallas kernel for the P^2 hot spot
    use_pallas_rank: bool = False       # fused Pallas rank-and-crowd path

    @property
    def log2_size(self) -> int:
        s = int(np.log2(self.array_size))
        if 2**s != self.array_size:
            raise ValueError("array_size must be a power of two")
        return s

    @property
    def h_exp_bounds(self) -> tuple[int, int]:
        lo = int(np.log2(self.cal.h_min))
        hi = min(int(np.log2(self.cal.h_max)),
                 self.log2_size - int(np.log2(self.cal.w_min)))
        return lo, hi

    @property
    def l_exp_bounds(self) -> tuple[int, int]:
        return int(np.log2(self.cal.l_min)), int(np.log2(self.cal.l_max))

    @property
    def b_bounds(self) -> tuple[int, int]:
        return self.cal.b_min, self.cal.b_max


class Population(NamedTuple):
    genes: Array   # (P, 3) int32  [h_exp, l_exp, b]
    objs: Array    # (P, 4) float32, minimization orientation


class SpaceOperands(NamedTuple):
    """Traced per-cell design-space operands (see module docstring).

    All leaves are arrays, so a sweep batch is just a tree of stacked
    leaves and `run_cell` vmaps over it without retracing.
    """

    array_size: Array              # () float32
    gene_lo: Array                 # (3,) int32  [h_exp, l_exp, b] lower bounds
    gene_hi: Array                 # (3,) int32  upper bounds (inclusive)
    cal: estimator.CalOperands     # traced calibration scalars


class EvolveStatics(NamedTuple):
    """Structural (hashable, shape-determining) NSGA-II parameters."""

    pop_size: int = 256
    crossover_prob: float = DEFAULT_CROSSOVER_PROB
    mutation_prob: float = DEFAULT_MUTATION_PROB
    use_pallas_dominance: bool = False
    use_pallas_rank: bool = False

    @classmethod
    def from_config(cls, cfg: NSGA2Config) -> "EvolveStatics":
        return cls(pop_size=cfg.pop_size, crossover_prob=cfg.crossover_prob,
                   mutation_prob=cfg.mutation_prob,
                   use_pallas_dominance=cfg.use_pallas_dominance,
                   use_pallas_rank=cfg.use_pallas_rank)


def space_operands(cfg: NSGA2Config) -> SpaceOperands:
    """Fold a static config into the traced operand tree."""
    h_lo, h_hi = cfg.h_exp_bounds
    l_lo, l_hi = cfg.l_exp_bounds
    b_lo, b_hi = cfg.b_bounds
    return SpaceOperands(
        array_size=jnp.float32(cfg.array_size),
        gene_lo=jnp.array([h_lo, l_lo, b_lo], jnp.int32),
        gene_hi=jnp.array([h_hi, l_hi, b_hi], jnp.int32),
        cal=estimator.cal_operands(cfg.cal),
    )


# ----------------------------------------------------------------------
# Operand-traced primitives (the vmappable hot path)
# ----------------------------------------------------------------------
def repair_op(genes: Array, space: SpaceOperands) -> Array:
    """Project genes onto the feasible set (Eq. 12 inequality constraints)."""
    lo, hi = space.gene_lo, space.gene_hi
    h = jnp.clip(genes[:, 0], lo[0], hi[0])
    # H >= L and room for at least b_min ADC bits: L <= H / 2^b_min
    l = jnp.clip(genes[:, 1], lo[1], jnp.minimum(hi[1], h - lo[2]))
    b = jnp.clip(genes[:, 2], lo[2], jnp.minimum(hi[2], h - l))   # H/L >= 2^B
    return jnp.stack([h, l, b], axis=1)


def decode_op(genes: Array, space: SpaceOperands):
    """Genes -> (H, W, L, B) float32 arrays."""
    h = 2.0 ** genes[:, 0].astype(jnp.float32)
    w = space.array_size / h
    l = 2.0 ** genes[:, 1].astype(jnp.float32)
    b = genes[:, 2].astype(jnp.float32)
    return h, w, l, b


def evaluate_op(genes: Array, space: SpaceOperands) -> Array:
    h, w, l, b = decode_op(genes, space)
    return estimator.objectives_from_operands(h, w, l, b, space.cal)


def init_population_op(key: Array, space: SpaceOperands, pop_size: int) -> Array:
    lo, hi = space.gene_lo, space.gene_hi
    kh, kl, kb = jax.random.split(key, 3)
    h = jax.random.randint(kh, (pop_size,), lo[0], hi[0] + 1)
    l = jax.random.randint(kl, (pop_size,), lo[1], hi[1] + 1)
    b = jax.random.randint(kb, (pop_size,), lo[2], hi[2] + 1)
    return repair_op(jnp.stack([h, l, b], 1), space)


def rank_and_crowd(objs: Array, statics: EvolveStatics):
    """(ranks, crowding) for a population, via the configured backend."""
    if statics.use_pallas_rank:
        from repro.kernels.pareto_dom import ops as dom_ops

        return dom_ops.rank_and_crowd(objs)
    if statics.use_pallas_dominance:
        from repro.kernels.pareto_dom import ops as dom_ops

        dom = dom_ops.dominance_matrix(objs)
    else:
        dom = pareto.dominance_matrix(objs)
    ranks = pareto.non_dominated_rank(objs, dom=dom)
    crowd = pareto.crowding_distance(objs, ranks)
    return ranks, crowd


def _tournament(key: Array, ranks: Array, crowd: Array, n: int) -> Array:
    """Binary tournament on (rank asc, crowding desc); returns n winner idx."""
    p = ranks.shape[0]
    idx = jax.random.randint(key, (n, 2), 0, p)
    a, b = idx[:, 0], idx[:, 1]
    a_better = (ranks[a] < ranks[b]) | ((ranks[a] == ranks[b]) & (crowd[a] > crowd[b]))
    return jnp.where(a_better, a, b)


def _variation_op(key: Array, parents: Array, space: SpaceOperands,
                  statics: EvolveStatics) -> Array:
    """Uniform crossover + random-reset mutation on integer genes."""
    p = parents.shape[0]
    kx, kswap, kmut, kval = jax.random.split(key, 4)
    mates = parents[jnp.roll(jnp.arange(p), 1)]
    do_cx = jax.random.bernoulli(kx, statics.crossover_prob, (p, 1))
    swap = jax.random.bernoulli(kswap, 0.5, parents.shape)
    children = jnp.where(do_cx & swap, mates, parents)
    # mutation: re-draw a gene uniformly within its box bounds
    lo, hi = space.gene_lo, space.gene_hi
    u = jax.random.uniform(kval, children.shape)
    rand_gene = (lo + (u * (hi - lo + 1)).astype(jnp.int32)).astype(jnp.int32)
    mut = jax.random.bernoulli(kmut, statics.mutation_prob, children.shape)
    children = jnp.where(mut, rand_gene, children)
    return repair_op(children, space)


def generation_step_op(key: Array, genes: Array, objs: Array, ranks: Array,
                       crowd: Array, space: SpaceOperands,
                       statics: EvolveStatics):
    """One NSGA-II generation with (ranks, crowd) threaded through the carry.

    The incoming (ranks, crowd) describe the parent population, so the
    tournament needs no ranking work; environmental selection ranks the
    combined 2P pool once and the survivors inherit *exact* ranks: the
    elitist truncation keeps every point of rank < r plus part of rank r,
    and all dominators of a kept point have strictly smaller rank, hence
    are also kept — re-peeling the survivors cannot change their ranks.
    Crowding is recomputed on the survivor set (neighbour gaps do change),
    which is a single sort batch, not a P^2 pass.
    """
    ksel, kvar = jax.random.split(key)
    parents_idx = _tournament(ksel, ranks, crowd, statics.pop_size)
    children = _variation_op(kvar, genes[parents_idx], space, statics)
    child_objs = evaluate_op(children, space)
    comb_genes = jnp.concatenate([genes, children], 0)
    comb_objs = jnp.concatenate([objs, child_objs], 0)
    # elitist (mu+lambda) truncation by (rank, -crowding)
    comb_ranks, comb_crowd = rank_and_crowd(comb_objs, statics)
    order = jnp.lexsort((-comb_crowd, comb_ranks))
    keep = order[: statics.pop_size]
    genes_k, objs_k, ranks_k = comb_genes[keep], comb_objs[keep], comb_ranks[keep]
    crowd_k = pareto.crowding_distance(objs_k, ranks_k)
    return genes_k, objs_k, ranks_k, crowd_k


def evolve_from(key: Array, genes: Array, objs: Array, space: SpaceOperands,
                statics: EvolveStatics, n_gens: int):
    """Rank once, then evolve `n_gens` generations (traced; no re-ranking)."""
    ranks, crowd = rank_and_crowd(objs, statics)

    def body(i, state):
        k, g, o, r, c = state
        k, sub = jax.random.split(k)
        g, o, r, c = generation_step_op(sub, g, o, r, c, space, statics)
        return k, g, o, r, c

    _, genes, objs, _, _ = jax.lax.fori_loop(
        0, n_gens, body, (key, genes, objs, ranks, crowd))
    return genes, objs


def run_cell(key: Array, space: SpaceOperands, *, statics: EvolveStatics,
             n_gens: int):
    """One full NSGA-II run for one design-space cell, fully traced.

    This is THE generation program: `run` jits it directly, the batched
    explorer vmaps it over a stacked `SpaceOperands` tree, and the island
    explorer runs it per device under `shard_map`.  Tracing it bumps
    `TRACE_COUNTS["run_cell"]`.
    """
    # lint: disable=inplace-store -- deliberate trace-count probe on a host dict
    TRACE_COUNTS["run_cell"] += 1
    kinit, kgen = jax.random.split(key)
    genes = init_population_op(kinit, space, statics.pop_size)
    objs = evaluate_op(genes, space)
    return evolve_from(kgen, genes, objs, space, statics, n_gens)


@functools.partial(jax.jit, static_argnames=("statics", "n_gens"))
def run_cell_jit(key, space, *, statics, n_gens):
    """Jitted `run_cell` — the sequential single-cell device program."""
    return run_cell(key, space, statics=statics, n_gens=n_gens)


def run(cfg: NSGA2Config, key: Array | None = None) -> Population:
    """Full NSGA-II run; returns the final population (feasible by repair).

    Sequential single-cell path: one compile serves every array size /
    calibration (both are operands), so `explore_sizes` re-dispatches the
    same executable per size.
    """
    if key is None:
        key = jax.random.key(cfg.seed)
    genes, objs = run_cell_jit(key, space_operands(cfg),
                               statics=EvolveStatics.from_config(cfg),
                               n_gens=cfg.generations)
    return Population(genes, objs)


# ----------------------------------------------------------------------
# Config-static compatibility wrappers (tests, examples, external callers)
# ----------------------------------------------------------------------
def repair(genes: Array, cfg: NSGA2Config) -> Array:
    return repair_op(genes, space_operands(cfg))


def decode(genes: Array, cfg: NSGA2Config):
    return decode_op(genes, space_operands(cfg))


def evaluate(genes: Array, cfg: NSGA2Config) -> Array:
    return evaluate_op(genes, space_operands(cfg))


def init_population(key: Array, cfg: NSGA2Config) -> Array:
    return init_population_op(key, space_operands(cfg), cfg.pop_size)


def constraint_violation(genes: Array, cfg: NSGA2Config) -> Array:
    """Total violation (0 for feasible) — used by the constrained-dom path."""
    h = genes[:, 0]
    l = genes[:, 1]
    b = genes[:, 2]
    v1 = jnp.maximum(l - h, 0)            # H >= L
    v2 = jnp.maximum(b - (h - l), 0)      # H/L >= 2^B
    return (v1 + v2).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("cfg",))
def generation_step(key: Array, genes: Array, objs: Array, cfg: NSGA2Config):
    """One NSGA-II generation: select -> vary -> evaluate -> elitist truncate.

    Legacy entry point (re-ranks the parents each call); prefer
    `generation_step_op` with a carried (ranks, crowd) pair.
    """
    statics = EvolveStatics.from_config(cfg)
    space = space_operands(cfg)
    ranks, crowd = rank_and_crowd(objs, statics)
    genes, objs, _, _ = generation_step_op(key, genes, objs, ranks, crowd,
                                           space, statics)
    return genes, objs
