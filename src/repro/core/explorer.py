"""MOGA-based design-space explorer (paper Sec. 3.2) with agile filtering.

`explore()` runs NSGA-II for a user-given array size and returns a
`ParetoResult`: the deduplicated Pareto-frontier set with both raw objective
values and human-oriented metrics.  `ParetoResult.filter(...)` implements the
paper's "agile interaction": users prune the frontier with application
requirements (min SNR, min throughput, max energy, max area) before handing
the survivors to the netlist generator / placer / router
(`repro.eda.flow.generate_layout`).

One-compile sweep contract: `explore()` and `explore_sizes()` are thin
wrappers over `repro.core.batched_explorer.explore_batch` — the array size,
gene bounds, and calibration constants are traced operands of a single
compiled NSGA-II program (`repro.core.nsga2.run_cell`), so a whole
(array_size x seed) sweep is one trace, one compile, and one device
dispatch.  The per-cell fronts are identical to the sequential
`nsga2.run` reference path.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator, nsga2, pareto
from repro.core.acim_spec import MacroSpec
from repro.core.constants import CAL28, CalibConstants


@dataclasses.dataclass(frozen=True)
class ParetoResult:
    array_size: int
    specs: tuple[MacroSpec, ...]          # deduplicated Pareto-frontier set
    metrics: dict                          # name -> np.ndarray aligned w/ specs

    def __len__(self) -> int:
        return len(self.specs)

    def filter(self, *, min_snr_db: float = -np.inf, min_tops: float = 0.0,
               max_energy_fj: float = np.inf, max_area: float = np.inf,
               min_tops_per_w: float = 0.0) -> "ParetoResult":
        """Agile user distillation of the Pareto set (paper Fig. 4, arrow
        'remove undesired solutions')."""
        m = self.metrics
        keep = ((m["snr_db"] >= min_snr_db) & (m["tops"] >= min_tops)
                & (m["energy_fj_per_mac"] <= max_energy_fj)
                & (m["area_f2_per_bit"] <= max_area)
                & (m["tops_per_w"] >= min_tops_per_w))
        idx = np.nonzero(keep)[0]
        return ParetoResult(
            self.array_size,
            tuple(self.specs[i] for i in idx),
            {k: v[idx] for k, v in m.items()},
        )

    def best(self, metric: str, maximize: bool = True) -> MacroSpec:
        v = self.metrics[metric]
        i = int(np.argmax(v) if maximize else np.argmin(v))
        return self.specs[i]

    def to_rows(self) -> list[dict]:
        rows = []
        for i, s in enumerate(self.specs):
            row = {"h": s.h, "w": s.w, "l": s.l, "b_adc": s.b_adc}
            row.update({k: float(v[i]) for k, v in self.metrics.items()})
            rows.append(row)
        return rows

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"array_size": self.array_size, "points": self.to_rows()},
                      f, indent=1)


def _dedup_pareto(genes: np.ndarray, objs: np.ndarray):
    """Unique genes restricted to the non-dominated set."""
    uniq, idx = np.unique(genes, axis=0, return_index=True)
    objs_u = objs[idx]
    mask = np.asarray(pareto.non_dominated_mask(jnp.asarray(objs_u)))
    return uniq[mask], objs_u[mask]


def pareto_result_from_population(array_size: int, genes: np.ndarray,
                                  objs: np.ndarray,
                                  cal: CalibConstants = CAL28) -> ParetoResult:
    """Distill a final NSGA-II population into a `ParetoResult`."""
    genes, _ = _dedup_pareto(np.asarray(genes), np.asarray(objs))
    h = (2 ** genes[:, 0]).astype(np.int64)
    w = (array_size // h).astype(np.int64)
    l = (2 ** genes[:, 1]).astype(np.int64)
    b = genes[:, 2].astype(np.int64)
    specs = tuple(MacroSpec(int(hh), int(ww), int(ll), int(bb))
                  for hh, ww, ll, bb in zip(h, w, l, b))
    rep = estimator.evaluate_report(h.astype(np.float32), w.astype(np.float32),
                                    l.astype(np.float32), b.astype(np.float32), cal)
    metrics = {k: np.asarray(v) for k, v in rep.items()}
    return ParetoResult(array_size, specs, metrics)


def explore(array_size: int, *, pop_size: int = 256, generations: int = 80,
            seed: int = 0, cal: CalibConstants = CAL28,
            use_pallas_dominance: bool = False,
            use_pallas_rank: bool = False) -> ParetoResult:
    """Run the MOGA explorer for one array size (paper: < 30 min on a Xeon;
    here: seconds, thanks to the fully vectorized generation step).

    Thin wrapper over `explore_batch` with a single (size, seed) cell."""
    from repro.core.batched_explorer import explore_batch

    out = explore_batch((array_size,), (seed,), pop_size=pop_size,
                        generations=generations, cal=cal,
                        use_pallas_dominance=use_pallas_dominance,
                        use_pallas_rank=use_pallas_rank)
    return out[(array_size, seed)]


def explore_sizes(sizes=(4096, 16384, 65536), *, seed: int = 0,
                  **kw) -> dict[int, ParetoResult]:
    """Fig. 9(a)(b)-style sweep over array sizes — one compiled program
    covers the whole sweep (see `repro.core.batched_explorer`)."""
    from repro.core.batched_explorer import explore_batch

    out = explore_batch(tuple(sizes), (seed,), **kw)
    return {s: out[(int(s), seed)] for s in sizes}


def distill_and_layout(array_size: int, *, pop_size: int = 256,
                       generations: int = 80, seed: int = 0,
                       cal: CalibConstants = CAL28, coarse: int = 64,
                       capacity: int = 4, use_pallas_dominance: bool = False,
                       use_pallas_rank: bool = False, **filter_kw):
    """Paper Fig. 4 end to end: MOGA sweep -> agile distillation ->
    batched layout generation.

    `filter_kw` are `ParetoResult.filter` thresholds (the user's
    application requirements); the surviving Pareto set is laid out in
    one batched dispatch chain (`repro.eda.batched_flow
    .generate_layouts`) instead of one `generate_layout` call per spec.
    Returns `(distilled: ParetoResult, layouts: BatchedLayoutResult)`
    with `layouts.metrics_rows()` aligned to `distilled.specs`.
    """
    from repro.eda.batched_flow import generate_layouts

    res = explore(array_size, pop_size=pop_size, generations=generations,
                  seed=seed, cal=cal,
                  use_pallas_dominance=use_pallas_dominance,
                  use_pallas_rank=use_pallas_rank)
    distilled = res.filter(**filter_kw) if filter_kw else res
    if not len(distilled):
        raise ValueError(
            f"agile filter {filter_kw!r} removed every Pareto point for "
            f"array_size={array_size}; relax the requirements")
    return distilled, generate_layouts(distilled.specs, coarse=coarse,
                                       capacity=capacity)


def full_design_space(array_size: int, cal: CalibConstants = CAL28):
    """Exhaustive enumeration of the (small, power-of-two) feasible space.

    The feasible space per array size is tiny (< 400 points), so exhaustive
    evaluation is tractable; the explorer's value is (a) fidelity to the
    paper's flow, (b) scaling to non-power-of-two/continuous extensions, and
    (c) this enumeration gives the tests a ground-truth Pareto front to
    compare NSGA-II against.
    """
    cfg = nsga2.NSGA2Config(array_size=array_size, cal=cal)
    h_lo, h_hi = cfg.h_exp_bounds
    l_lo, l_hi = cfg.l_exp_bounds
    b_lo, b_hi = cfg.b_bounds
    pts = [(he, le, b)
           for he in range(h_lo, h_hi + 1)
           for le in range(l_lo, min(l_hi, he) + 1)
           for b in range(b_lo, min(b_hi, he - le) + 1)]
    genes = jnp.asarray(np.array(pts, np.int32))
    objs = nsga2.evaluate(genes, cfg)
    return genes, objs
