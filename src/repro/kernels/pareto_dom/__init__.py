from repro.kernels.pareto_dom.ops import dominance_matrix
from repro.kernels.pareto_dom.ref import dominance_matrix_ref

__all__ = ["dominance_matrix", "dominance_matrix_ref"]
