"""Pallas kernel microbenchmarks (interpret-mode wall time on CPU is NOT a
TPU perf claim — correctness/overhead tracking only; TPU perf is covered by
the roofline analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acim_spec import MacroSpec
from repro.kernels.acim_matmul import acim_matmul, acim_matmul_ref
from repro.kernels.pareto_dom import (dominance_matrix, dominance_matrix_ref,
                                      non_dominated_rank,
                                      non_dominated_rank_ref)


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def main() -> None:
    print("name,us_per_call,derived")
    spec = MacroSpec(256, 64, 2, 5)
    x = jnp.where(jax.random.bernoulli(jax.random.key(0), 0.5, (256, 512)),
                  1.0, -1.0)
    w = jnp.where(jax.random.bernoulli(jax.random.key(1), 0.5, (512, 64)),
                  1.0, -1.0)
    t_k = _time(lambda a, b: acim_matmul(a, b, spec), x, w)
    t_r = _time(lambda a, b: acim_matmul_ref(a, b, n=128, b_adc=5), x, w)
    print(f"acim_matmul_pallas_interp,{t_k:.0f},(256x512x64 n=128 b=5)")
    print(f"acim_matmul_ref,{t_r:.0f},oracle")

    f = jax.random.normal(jax.random.key(2), (512, 4))
    t_k = _time(lambda a: dominance_matrix(a), f)
    t_r = _time(lambda a: dominance_matrix_ref(a), f)
    print(f"pareto_dom_pallas_interp,{t_k:.0f},(P=512 M=4)")
    print(f"pareto_dom_ref,{t_r:.0f},oracle")

    t_k = _time(lambda a: non_dominated_rank(a), f)
    t_r = _time(lambda a: non_dominated_rank_ref(a), f)
    print(f"pareto_rank_fused_pallas_interp,{t_k:.0f},(P=512 M=4 bit-packed peel)")
    print(f"pareto_rank_ref,{t_r:.0f},oracle")


if __name__ == "__main__":
    main()
