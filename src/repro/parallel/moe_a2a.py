"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The GSPMD path (`models/mlp.py::moe_fwd`) expresses dispatch as one-hot
einsums and lets the partitioner infer collectives.  This module is the
collective-optimal formulation real MoE frameworks use: tokens are packed
into per-destination-shard capacity buffers locally, exchanged with ONE
`jax.lax.all_to_all` over the expert ("model") axis, run through the local
expert shard, and exchanged back — moving only k/E of the activations
instead of whole dispatch tensors.

Semantics match `moe_fwd` up to capacity-drop ordering; with generous
capacity both equal the drop-free reference (tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.common import act_fn
from repro.models.mlp import router_probs
from repro.parallel.axes import shard_map


def moe_fwd_a2a(p: dict, x: jax.Array, cfg: ArchConfig, mesh: Mesh, *,
                expert_axis: str = "model", batch_axis: str = "data",
                capacity: int | None = None) -> jax.Array:
    """x: (B, S, D) replicated-over-expert-axis, sharded over batch_axis.

    Returns y like x.  Router aux losses are omitted here (the GSPMD path
    computes them; this variant is the serving/perf path).
    """
    m = cfg.moe
    n_shards = mesh.shape[expert_axis]
    e_local = m.n_experts // n_shards
    assert m.n_experts % n_shards == 0

    b, s, d = x.shape
    if capacity is None:
        capacity = int(np.ceil(b * s * m.top_k * m.capacity_factor
                               / m.n_experts)) * 4

    in_specs = (
        jax.tree.map(lambda _: P(expert_axis), {k: p[k] for k in
                                                ("wi", "wg", "wo")}),
        P(),                       # router (replicated)
        P(batch_axis),             # x sharded over batch
    )

    @functools.partial(shard_map, mesh=mesh, check_vma=False,
                       in_specs=in_specs, out_specs=P(batch_axis))
    def run(experts, router, x):
        bl, sl, _ = x.shape
        t = bl * sl
        xt = x.reshape(t, d)
        logits, probs, top_p, top_i = router_probs({"router": router}, xt,
                                                   m)
        # slot of each (token, k) claim inside its expert queue
        claims = jax.nn.one_hot(top_i, m.n_experts, dtype=jnp.float32)
        flat = claims.reshape(t * m.top_k, m.n_experts)
        pos = jnp.cumsum(flat, axis=0) - flat
        slot = jnp.einsum("te,te->t", pos, flat).astype(jnp.int32)
        expert = top_i.reshape(-1)
        keep = slot < capacity

        # pack send buffer: (n_shards, e_local, capacity, D)
        dst = expert // e_local
        e_in_shard = expert % e_local
        send = jnp.zeros((n_shards, e_local, capacity, d), x.dtype)
        tok_idx = jnp.repeat(jnp.arange(t), m.top_k)
        send = send.at[dst, e_in_shard, jnp.where(keep, slot, capacity - 1)
                       ].add(jnp.where(keep[:, None], xt[tok_idx], 0.0))

        recv = jax.lax.all_to_all(send, expert_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (n_shards(src), e_local, capacity, D) tokens for MY experts
        h = recv.reshape(n_shards * e_local * capacity, d) if False else recv
        wi, wg, wo = (experts["wi"][0:e_local], experts["wg"][0:e_local],
                      experts["wo"][0:e_local])
        hi = jnp.einsum("secd,edf->secf", recv, wi.astype(x.dtype))
        hg = jnp.einsum("secd,edf->secf", recv, wg.astype(x.dtype))
        ye = jnp.einsum("secf,efd->secd", act_fn(cfg.act)(hg) * hi,
                        wo.astype(x.dtype))
        back = jax.lax.all_to_all(ye, expert_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # back: (n_shards(dst-as-src), e_local, capacity, D) == send layout
        gathered = back[dst, e_in_shard,
                        jnp.where(keep, slot, capacity - 1)]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        w = (top_p.reshape(-1) * keep)[:, None].astype(x.dtype)
        yt = jnp.zeros((t, d), x.dtype).at[tok_idx].add(gathered * w)
        y = yt.reshape(bl, sl, d)
        if m.n_shared:
            sp = p["shared"]
            y = y + (act_fn(cfg.act)(x @ sp["wg"].astype(x.dtype))
                     * (x @ sp["wi"].astype(x.dtype))) @ sp["wo"].astype(x.dtype)
        if m.dense_ff:
            dp = p["dense"]
            y = y + (act_fn(cfg.act)(x @ dp["wg"].astype(x.dtype))
                     * (x @ dp["wi"].astype(x.dtype))) @ dp["wo"].astype(x.dtype)
        return y

    return run({k: p[k] for k in ("wi", "wg", "wo")}, p["router"], x)
