"""Logical activation axes -> mesh axes (MaxText-style logical axis rules).

Models annotate key intermediates with `logical(x, "batch", "seq", "heads",
None)`; under a `set_rules(...)` context (installed by the train/serve step
builders) each logical name maps to a mesh axis (or None) and the annotation
becomes a `with_sharding_constraint`.  Outside the context it is a no-op, so
single-device smoke tests run the exact same model code.

This is how head-count-awkward architectures (arctic: 56 heads on 16-way
TP) stay efficient: their rules map the attention *sequence* axis to
"model" (context parallelism) instead of the head axis.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_RULES: contextvars.ContextVar[Optional[tuple[Mesh, Mapping[str, object]]]] = \
    contextvars.ContextVar("logical_axis_rules", default=None)


@contextlib.contextmanager
def set_rules(mesh: Mesh, rules: Mapping[str, object]):
    """rules: logical name -> mesh axis name | tuple of axis names | None."""
    token = _RULES.set((mesh, dict(rules)))
    try:
        yield
    finally:
        _RULES.reset(token)


def current_rules():
    return _RULES.get()


def _spec_from(rules: Mapping[str, object], names: tuple) -> P:
    """Resolve names -> mesh axes, dropping duplicate axis uses (first dim
    keeps the axis; later dims fall back to None)."""
    used: set = set()
    out = []
    for n in names:
        ax = rules.get(n) if isinstance(n, str) else None
        flat = ax if isinstance(ax, tuple) else (ax,) if ax else ()
        if any(a in used for a in flat):
            ax = None
            flat = ()
        used.update(flat)
        out.append(ax)
    return P(*out)


def resolve(names: tuple) -> Optional[P]:
    ctx = _RULES.get()
    if ctx is None:
        return None
    _, rules = ctx
    return _spec_from(rules, names)


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-portable shard_map: `jax.shard_map` (jax >= 0.5, `check_vma`)
    when present, else `jax.experimental.shard_map` (`check_rep`)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def logical(x: jax.Array, *names) -> jax.Array:
    """Constrain x's sharding by logical axis names (no-op w/o rules)."""
    ctx = _RULES.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = _spec_from(rules, names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
