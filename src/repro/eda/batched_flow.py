"""Batched layout generation: the explorer's distilled Pareto set through
place / route / DRC / metrics in a handful of device dispatches.

This is the layout-side counterpart of `repro.core.batched_explorer`
(paper Fig. 4: the MOGA's user-distilled Pareto set flows straight into
automated layout generation).  The sequential `repro.eda.flow
.generate_layout` runs one spec at a time in host Python; here every
stage is array-programmed over a stacked spec batch:

  * **place** — `placer.rect_tensors` (the data-oriented template
    expansion) is `jax.vmap`-ed over a stacked `LayoutOperands` tree:
    one dispatch produces the (B, ..., 4) rect tensors for all specs,
    padded to per-batch index extents (`BatchDims`) with validity masks.
  * **route** — inter-template nets are derived from the rect tensors on
    device, ordered longest-first exactly like the sequential router,
    and routed net-slot by net-slot with the `kernels.maze_route`
    wavefront expanding all B grids at once (grid-batched parallel BFS).
    The backtrace tie-break matches `router.backtrace`, so per-spec
    occupancy, success and wirelength are identical to B sequential
    `route()` calls.
  * **DRC** — a sweep-free pairwise-overlap reduction.  Every column of
    the macro is an x-translate of column 0 (the expansion is
    pitch-matched), and the sequential `drc_lite` never compares rects
    from different columns, so intra-column pair overlaps are counted
    once on column 0 and multiplied by W; bounds checks run over the
    flat (B, R, 4) rect tensor.
  * **metrics / netlist stats** — closed-form (`netlist.stats_for_spec`)
    and vectorized over the batch.

`generate_layouts(specs)` is the engine entry point for one batch;
`iter_layout_buckets(...)` streams a sequence of grid-shape buckets
through it, yielding each bucket's result incrementally (what the
staged pipeline executor consumes).  The supported front-end is
`repro.api.DesignSession` (which chains exploration into it and
buckets multi-tenant batches by routing-grid shape before calling it —
see `repro.serve.design_service`).  Per-spec results
unpack to the sequential dataclasses via `BatchedLayoutResult
.placements()` / `.drc_reports()` for interop, and
`tests/test_batched_flow.py` asserts batched == sequential per spec
(same rects, same route success, same DRC verdict).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator
from repro.core.acim_spec import MacroSpec
from repro.eda import netlist as nl_mod
from repro.eda.flow import DRCReport
from repro.eda.placer import (CATEGORIES, CATEGORY_CELL, BatchDims,
                              LayoutOperands, Placed, Placement,
                              PlacerGeometry, category_names, dims_for_spec,
                              geometry, layout_operands, rect_tensors)
from repro.eda.router import NEIGHBORS, grid_shape
from repro.kernels.maze_route import INF, wavefront_distance
from repro.kernels.maze_route.frontier import (canvas_free, canvas_index,
                                               expand_buckets, strides)

Array = jax.Array


def stack_layout_operands(specs, geom: PlacerGeometry) -> LayoutOperands:
    """Stack per-spec `LayoutOperands` trees into one batched tree."""
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[layout_operands(s, geom) for s in specs])


# ----------------------------------------------------------------------
# Placement: one vmapped dispatch for the whole batch
# ----------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("dims", "geom"))
def _place_program(ops: LayoutOperands, *, dims: BatchDims,
                   geom: PlacerGeometry):
    return jax.vmap(lambda o: rect_tensors(o, dims, geom))(ops)


def _flat_rects(tensors):
    """(B, R, 4) rects + (B, R) mask from the batched category tensors."""
    b = next(iter(tensors.values()))[0].shape[0]
    rects = jnp.concatenate(
        [tensors[c][0].reshape((b, -1, 4)) for c in CATEGORIES], axis=1)
    mask = jnp.concatenate(
        [tensors[c][1].reshape((b, -1)) for c in CATEGORIES], axis=1)
    return rects, mask


# ----------------------------------------------------------------------
# DRC: sweep-free pairwise-overlap reduction
# ----------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("dims", "geom"))
def _drc_program(tensors, ops: LayoutOperands, *, dims: BatchDims,
                 geom: PlacerGeometry):
    del geom  # geometry is baked into the tensors
    # Column 0 carries every intra-column pair; columns are x-translates.
    col = jnp.concatenate([
        tensors["sram"][0][:, 0],
        tensors["cap"][0][:, 0],
        tensors["sw"][0][:, 0],
        tensors["comp"][0][:, :1],
        tensors["sar"][0][:, :1],
        tensors["dff"][0][:, 0],
    ], axis=1)
    cmask = jnp.concatenate([
        tensors["sram"][1][:, 0],
        tensors["cap"][1][:, 0],
        tensors["sw"][1][:, 0],
        tensors["comp"][1][:, :1],
        tensors["sar"][1][:, :1],
        tensors["dff"][1][:, 0],
    ], axis=1)
    a = col[:, :, None, :]
    b = col[:, None, :, :]
    ov = ((a[..., 0] < b[..., 0] + b[..., 2])
          & (b[..., 0] < a[..., 0] + a[..., 2])
          & (a[..., 1] < b[..., 1] + b[..., 3])
          & (b[..., 1] < a[..., 1] + a[..., 3]))
    c = col.shape[1]
    upper = jnp.arange(c)[:, None] < jnp.arange(c)[None, :]
    valid = cmask[:, :, None] & cmask[:, None, :] & upper[None]
    overlaps = jnp.sum(ov & valid, axis=(1, 2)).astype(jnp.int32) * ops.w

    rects, mask = _flat_rects(tensors)
    oob = ((rects[..., 1] + rects[..., 3] > ops.height[:, None] + 1)
           | (rects[..., 0] + rects[..., 2] > ops.width[:, None] + 1))
    oob = jnp.sum(oob & mask, axis=1).astype(jnp.int32)
    return overlaps, oob


# ----------------------------------------------------------------------
# Net derivation: same nets, same longest-first order as the host flow
# ----------------------------------------------------------------------
class NetBatch(NamedTuple):
    """Routing-ready net slots, already in routing (longest-first) order.

    hub/tgt coordinates are grid cells (gy, gx); masks gate per-target
    and per-net validity (padded slots of smaller specs are invalid)."""

    hubs: Array        # (B, N, 2) int32
    tgts: Array        # (B, N, 2, 2) int32 — up to two star targets
    tmask: Array       # (B, N, 2) bool
    nmask: Array       # (B, N) bool


def _centers(t: Array):
    return t[..., 0] + t[..., 2] // 2, t[..., 1] + t[..., 3] // 2


@functools.partial(jax.jit, static_argnames=("dims", "geom", "coarse"))
def _nets_program(tensors, ops: LayoutOperands, *, dims: BatchDims,
                  geom: PlacerGeometry, coarse: int) -> NetBatch:
    del geom
    bsz = ops.w.shape[0]
    comp_x, comp_y = _centers(tensors["comp"][0])        # (B, W)
    sar_x, sar_y = _centers(tensors["sar"][0])           # (B, W)
    cap_x, cap_y = _centers(tensors["cap"][0])           # (B, W, NLA)
    sram_x, sram_y = _centers(tensors["sram"][0])        # (B, W, H)
    rd_x, rd_y = _centers(tensors["rd"][0])              # (B, RD)

    top = (ops.n_la - 1)[:, None, None]                  # (B, 1, 1)
    cap0 = jnp.stack([cap_x[:, :, 0], cap_y[:, :, 0]], -1)
    capt = jnp.stack([
        jnp.take_along_axis(cap_x, top, axis=2)[:, :, 0],
        jnp.take_along_axis(cap_y, top, axis=2)[:, :, 0]], -1)
    comp = jnp.stack([comp_x, comp_y], -1)               # (B, W, 2)
    sar = jnp.stack([sar_x, sar_y], -1)
    jvalid = jnp.arange(dims.w)[None, :] < ops.w[:, None]

    # per-column nets, interleaved (rbl_j, cmp_j) like the host net list
    rbl_t = jnp.stack([cap0, capt], axis=2)              # (B, W, 2, 2)
    cmp_t = jnp.stack([sar, sar], axis=2)
    col_hubs = jnp.stack([comp, comp], axis=2)           # (B, W, 2net, 2)
    col_tgts = jnp.stack([rbl_t, cmp_t], axis=2)         # (B, W, 2net, 2, 2)
    col_tmask = jnp.broadcast_to(
        jnp.array([[True, True], [True, False]]),
        (bsz, dims.w, 2, 2))
    col_nmask = jnp.broadcast_to(jvalid[:, :, None], (bsz, dims.w, 2))

    # row-driver nets: driver -> farthest column's cell in that row
    r = jnp.arange(dims.rd, dtype=jnp.int32)[None, :]    # (1, RD)
    flat = (ops.w[:, None] - 1) * dims.h + r             # sram[w-1, r]
    far_x = jnp.take_along_axis(sram_x.reshape((bsz, -1)), flat, axis=1)
    far_y = jnp.take_along_axis(sram_y.reshape((bsz, -1)), flat, axis=1)
    rd_hubs = jnp.stack([rd_x, rd_y], -1)                # (B, RD, 2)
    far = jnp.stack([far_x, far_y], -1)
    rd_tgts = jnp.stack([far, far], axis=2)              # (B, RD, 2, 2)
    rd_tmask = jnp.broadcast_to(jnp.array([True, False]),
                                (bsz, dims.rd, 2))
    rd_nmask = r < ops.n_rd[:, None]

    hubs = jnp.concatenate([col_hubs.reshape((bsz, -1, 2)), rd_hubs], 1)
    tgts = jnp.concatenate([col_tgts.reshape((bsz, -1, 2, 2)), rd_tgts], 1)
    tmask = jnp.concatenate([col_tmask.reshape((bsz, -1, 2)), rd_tmask], 1)
    nmask = jnp.concatenate([col_nmask.reshape((bsz, -1)), rd_nmask], 1)

    # longest (bounding box) first, in F units, stable — same key and
    # same tie order as `router.route`'s host sort
    pins = jnp.concatenate([hubs[:, :, None], tgts], axis=2)  # (B, N, 3, 2)
    pmask = jnp.concatenate([jnp.ones_like(tmask[:, :, :1]), tmask], 2)
    px = jnp.where(pmask, pins[..., 0], hubs[:, :, None, 0])
    py = jnp.where(pmask, pins[..., 1], hubs[:, :, None, 1])
    span = (px.max(2) - px.min(2)) + (py.max(2) - py.min(2))
    span = jnp.where(nmask, span, -1)
    order = jnp.argsort(-span, axis=1, stable=True)      # (B, N)

    take = lambda a: jnp.take_along_axis(  # noqa: E731
        a, order.reshape(order.shape + (1,) * (a.ndim - 2)), axis=1)
    hubs, tgts, tmask, nmask = (take(hubs), take(tgts), take(tmask),
                                take(nmask))

    # F-unit pin coords -> clipped per-spec grid cells (gy, gx)
    gh = jnp.maximum(2, ops.height // coarse + 3)[:, None]
    gw = jnp.maximum(2, ops.width // coarse + 2)[:, None]

    def to_cell(xy, gh, gw):
        gy = jnp.clip(xy[..., 1] // coarse, 0, gh - 1)
        gx = jnp.clip(xy[..., 0] // coarse, 0, gw - 1)
        return jnp.stack([gy, gx], axis=-1)

    return NetBatch(to_cell(hubs, gh, gw),
                    to_cell(tgts, gh[..., None], gw[..., None]),
                    tmask, nmask)


# ----------------------------------------------------------------------
# Routing: per net slot, one batched wavefront + on-device backtrace
# ----------------------------------------------------------------------
def _dir_field(dist: Array) -> Array:
    """Backtrace direction of every cell: the first `NEIGHBORS` entry at
    distance d-1 (router.backtrace's tie-break), int8 in {0..3}.

    Vectorized once per wavefront; the per-step walk then costs a single
    scalar gather.  Cells with d == 0 or d == INF hold an arbitrary
    direction — the walk never reads them (sources stop the walk, and
    blocked targets take their special entry step first).  BFS
    guarantees every cell with finite d > 0 has a d-1 neighbour.
    """
    gh, gw = dist.shape
    pad = jnp.pad(dist, 1, constant_values=INF)
    match = jnp.stack([pad[1 + dy:1 + dy + gh, 1 + dx:1 + dx + gw]
                       == dist - 1 for dy, dx in NEIGHBORS])
    return jnp.argmax(match, axis=0).astype(jnp.int8)


def _trace_one(dist: Array, dirf: Array, tgt: Array, active: Array):
    """Backtrace one star target on one grid; returns (inc, wl, reachable).

    Mirrors `router.target_distance` + `router.backtrace`: a blocked dst
    is enterable at +1 from its best neighbour, then the walk follows
    the precomputed direction field — identical cells, so the occupancy
    evolution matches the sequential router exactly.  The walk scatters
    its visited cells once per `chunk` steps (out-of-range rows are
    dropped), not once per step — scatter cost is per-op on CPU.
    """
    gh, gw = dist.shape
    chunk = 16
    ty, tx = tgt[0], tgt[1]
    dv = dist[ty, tx]
    win = jax.lax.dynamic_slice(jnp.pad(dist, 1, constant_values=INF),
                                (ty, tx), (3, 3))
    # NEIGHBORS order: down, up, right, left
    nd0 = jnp.stack([win[2, 1], win[0, 1], win[1, 2], win[1, 0]])
    d0 = jnp.where(dv < INF, dv, jnp.minimum(INF, jnp.min(nd0) + 1))
    reach = d0 < INF
    run = active & reach
    dy_tab = jnp.array([n[0] for n in NEIGHBORS])
    dx_tab = jnp.array([n[1] for n in NEIGHBORS])

    # blocked target: its entry step is not in the direction field
    esel = jnp.argmax(nd0 == d0 - 1)
    blocked = run & (dv >= INF)
    ey = jnp.where(blocked, ty + dy_tab[esel], ty)
    ex = jnp.where(blocked, tx + dx_tab[esel], tx)
    inc = jnp.zeros((gh, gw), jnp.int8).at[
        jnp.stack([jnp.where(run, ty, gh), jnp.where(blocked, ey, gh)]),
        jnp.stack([tx, ex])].add(jnp.int8(1), mode="drop")
    dirf_flat = dirf.reshape(-1)

    def walk(carry, _):
        y, x, d = carry
        sel = dirf_flat[y * gw + x]
        stepping = d > 0
        ny = jnp.where(stepping, y + dy_tab[sel], y)
        nx = jnp.where(stepping, x + dx_tab[sel], x)
        out = (jnp.where(stepping, ny, gh), nx)    # row gh -> dropped
        return (ny, nx, jnp.maximum(d - 1, 0)), out

    def cond(state):
        _, _, d, _ = state
        return d > 0

    def body(state):
        y, x, d, inc = state
        (y, x, d), (ys, xs) = jax.lax.scan(walk, (y, x, d), None,
                                           length=chunk)
        # NB: steps past the path's end all emit the same dropped index,
        # so unique_indices must NOT be asserted here
        return y, x, d, inc.at[ys, xs].add(jnp.int8(1), mode="drop")

    _, _, _, inc = jax.lax.while_loop(
        cond, body,
        (ey, ex, jnp.where(run, jnp.where(blocked, d0 - 1, d0), 0), inc))
    wl = jnp.where(run, d0 + 1, 0)
    return inc, wl, reach


def _route_step(occ_count: Array, hubs: Array, tgts: Array, tmask: Array,
                nmask: Array, *, capacity: int, use_kernel: bool | None):
    """Route one net slot across the whole batch.

    occ_count: (B, Gh, Gw) int32; hubs (B, 2); tgts (B, 2, 2);
    tmask (B, 2); nmask (B,).  Returns (occ_count', ok, wirelength).
    """
    _, gh, gw = occ_count.shape
    occ = occ_count >= capacity
    iy = jnp.arange(gh)[None, :, None]
    ix = jnp.arange(gw)[None, None, :]
    seed = ((iy == hubs[:, 0, None, None]) & (ix == hubs[:, 1, None, None])
            & nmask[:, None, None])
    # translate the legacy use_kernel knob here: internal code
    # never calls the deprecated ops spelling (pytest errors on it)
    impl = None if use_kernel is None else (
        "kernel" if use_kernel else "ref")
    dist = wavefront_distance(occ, seed, impl=impl)

    dirf = jax.vmap(_dir_field)(dist)
    trace = jax.vmap(jax.vmap(_trace_one, in_axes=(None, None, 0, 0)))
    inc, wl, reach = trace(dist, dirf, tgts, tmask & nmask[:, None])
    ok = nmask & jnp.all(reach | ~tmask, axis=1)
    occ_count = occ_count + (inc.astype(jnp.int32).sum(axis=1)
                             * ok[:, None, None])
    return occ_count, ok, wl.sum(axis=1) * ok


@functools.partial(jax.jit, static_argnames=("capacity", "use_kernel"))
def _route_program(occ0: Array, nets: NetBatch, *, capacity: int,
                   use_kernel: bool | None):
    """All net slots in one compiled program: `lax.scan` over the slot
    axis with the (occupancy, counters) carry — the sequential
    net-by-net data dependence stays, but there is a single dispatch for
    the whole batch instead of one per net."""

    def step(carry, slot):
        occ, routed, failed, wirelen = carry
        hubs, tgts, tmask, nmask = slot
        occ, ok, wl = _route_step(occ, hubs, tgts, tmask, nmask,
                                  capacity=capacity, use_kernel=use_kernel)
        return (occ, routed + ok, failed + (nmask & ~ok), wirelen + wl), None

    bsz = occ0.shape[0]
    zeros = jnp.zeros((bsz,), jnp.int32)
    slots = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), nets)
    (occ, routed, failed, wirelen), _ = jax.lax.scan(
        step, (occ0, zeros, zeros, zeros), slots)
    return occ, routed, failed, wirelen


# ----------------------------------------------------------------------
# Concurrent-net routing: conflict-aware scheduling over frontier buckets
# ----------------------------------------------------------------------
#
# The scan engine above pays one full-grid wavefront per net *slot* —
# O(nets) sweeps even though most nets never interact.  The concurrent
# engine routes many nets of one spec in the same dispatch and keeps the
# result bit-identical to the sequential router by separating *when a
# field is computed* from *when its route commits*:
#
#   * rounds are colors of the conflict graph: each round greedily picks
#     pending nets, in slot order, whose expanded bounding boxes are
#     pairwise disjoint within a spec (greedy coloring — a net conflicts
#     with an earlier pick, it waits for a later round);
#   * the picked lanes' distance fields are computed together — closed
#     form while the spec has no blocked cell (an obstacle-free
#     rectangle's BFS field is plain Manhattan distance), the bucketed
#     frontier engine (`kernels.maze_route.frontier`) with per-lane
#     early exit afterwards;
#   * routes commit strictly in slot order.  A commit that pushes cells
#     *across* the capacity threshold (newly blocked cells X) is the
#     only event that can perturb later fields, and a buffered field
#     stays exact iff every target distance d0 satisfies
#     d0 <= min over x in X of dist(x): blocking a cell at distance >=
#     d0 cannot change any cell at distance < d0 (its shortest paths
#     can't pass through x), cannot shrink the d0-1 match sets the
#     backtrace reads, and leaves unreachable targets unreachable.
#     Fields that fail the test are occupancy *collisions*: the loser is
#     dropped and recomputed (retried) in a later round against the
#     updated occupancy.
#
# The head of each spec's pending queue is always computed in the round
# (no earlier pick exists to conflict with) and always commits (its
# field is fresh), so every round makes progress and the loop terminates
# in <= nets rounds; in practice rounds ~ conflict depth of the net set.


@dataclasses.dataclass
class RouteSchedule:
    """Trace of the conflict-aware scheduler, for tests and the bench.

    dispatches[r] = (spec, slot) lanes whose wavefronts were computed
    together in round r; bboxes is every net's expanded bounding box
    (y0, x0, y1, x1 inclusive, grid cells) so tests can assert no round
    ever co-dispatched two overlapping nets of one spec."""

    dispatches: list
    bboxes: np.ndarray
    rounds: int = 0
    collisions: int = 0
    crossings: int = 0


@dataclasses.dataclass
class _Buffered:
    """A computed-but-not-yet-committed route of one (spec, slot) lane."""

    cells: np.ndarray            # occupancy increments, real-grid flat idx
    wl: int                      # wirelength contribution if committed
    ok: bool                     # every valid target reachable
    d0max: int                   # max finite target distance (-1: none)
    dist: np.ndarray | None      # (C,) canvas field (frontier lanes)
    hub: tuple | None            # (hy, hx): closed-form field (Manhattan)


def _still_valid(e: _Buffered, ys: np.ndarray, xs: np.ndarray,
                 stride: int) -> bool:
    """Does `e`'s route survive cells (ys, xs) becoming blocked?

    Exactness bound (see module comment): valid iff d0max <= min dist(x)
    over the newly blocked cells.  Failed-net entries are always valid —
    an unreachable target stays unreachable under more blocking, and
    nothing else of theirs is ever read."""
    if not e.ok or e.d0max < 0:
        return True
    if e.dist is not None:
        dmin = int(e.dist[canvas_index(ys, xs, stride)].min())
    else:
        hy, hx = e.hub
        dmin = int((np.abs(ys - hy) + np.abs(xs - hx)).min())
    return e.d0max <= dmin


def _ragged_arange(lengths: np.ndarray) -> np.ndarray:
    """Concatenated [0..l) ranges: [0,1,..,l0-1, 0,1,..,l1-1, ...]."""
    ends = np.cumsum(lengths)
    return np.arange(int(ends[-1]) if len(ends) else 0) \
        - np.repeat(ends - lengths, lengths)


def _manhattan_paths(lane, hy, hx, ty, tx):
    """Closed-form backtrace on an obstacle-free grid, all walkers at once.

    On a blocked-free rectangle the field is |dy|+|dx| and the shared
    tie-break (first `NEIGHBORS` entry at d-1: down, up, right, left)
    walks vertically to the hub row, then horizontally — so the full
    path (target included) is two ragged runs.  Returns concatenated
    (lane, y, x) path cells, d0+1 of them per walker."""
    sy = np.sign(hy - ty)
    lv = np.abs(hy - ty) + 1            # vertical run, target included
    sx = np.sign(hx - tx)
    lh = np.abs(hx - tx)                # horizontal run, pivot excluded
    ys_v = np.repeat(ty, lv) + np.repeat(sy, lv) * _ragged_arange(lv)
    xs_v = np.repeat(tx, lv)
    ys_h = np.repeat(hy, lh)
    xs_h = np.repeat(tx + sx, lh) + np.repeat(sx, lh) * _ragged_arange(lh)
    return (np.concatenate([np.repeat(lane, lv), np.repeat(lane, lh)]),
            np.concatenate([ys_v, ys_h]), np.concatenate([xs_v, xs_h]))


def _walk_paths(dist: np.ndarray, lanes, start, steps, stride: int):
    """Vectorized multi-walker backtrace over canvas distance fields.

    Every active walker takes its step simultaneously: 4 neighbour
    gathers, first `NEIGHBORS` match at d-1 (the shared tie-break),
    advance, emit.  Start cells are not emitted (callers emit target and
    blocked-entry cells themselves).  Returns concatenated (lane,
    canvas idx) of stepped-to cells."""
    offs = strides(stride)
    cur, d, who = start.copy(), steps.copy(), np.asarray(lanes).copy()
    out_l: list[np.ndarray] = []
    out_c: list[np.ndarray] = []
    act = d > 0
    cur, d, who = cur[act], d[act], who[act]
    while d.size:
        nbr = dist[who[:, None], cur[:, None] + offs[None, :]]
        sel = np.argmax(nbr == (d - 1)[:, None], axis=1)
        cur = cur + offs[sel]
        out_l.append(who.copy())
        out_c.append(cur.copy())
        d = d - 1
        act = d > 0
        if not act.all():
            cur, d, who = cur[act], d[act], who[act]
    if not out_l:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64))
    return np.concatenate(out_l), np.concatenate(out_c)


def _group_cells(lanes: np.ndarray, cells: np.ndarray, n_lanes: int):
    """Split concatenated (lane, cell) emissions into per-lane arrays."""
    order = np.argsort(lanes, kind="stable")
    lanes, cells = lanes[order], cells[order]
    bounds = np.searchsorted(lanes, np.arange(n_lanes + 1))
    return [cells[bounds[k]:bounds[k + 1]] for k in range(n_lanes)]


def _bbox_overlap(a, b) -> bool:
    return bool(a[0] <= b[2] and b[0] <= a[2]
                and a[1] <= b[3] and b[1] <= a[3])


def _concurrent_route(nets: NetBatch, grids: np.ndarray, occ0: np.ndarray,
                      *, capacity: int, record: bool = False):
    """Route every net of every spec, conflict-aware (see section comment).

    nets: numpy `NetBatch`; occ0: (B, Gh, Gw) int32 with out-of-grid
    cells pre-blocked at `capacity`.  Returns (occ, routed, failed,
    wirelength, rounds, collisions, schedule)."""
    hubs, tgts = np.asarray(nets.hubs), np.asarray(nets.tgts)
    tmask, nmask = np.asarray(nets.tmask), np.asarray(nets.nmask)
    bsz = nmask.shape[0]
    gh, gw = occ0.shape[1:]
    stride = gw + 2
    occ = occ0.copy()
    occ_flat = occ.reshape(bsz, -1)
    offs = strides(stride)

    # Expanded bounding boxes: hub + valid targets, one-cell margin for
    # the blocked-destination entry step.
    py = np.concatenate([hubs[:, :, None, 0],
                         np.where(tmask, tgts[..., 0], hubs[:, :, None, 0])],
                        axis=2)
    px = np.concatenate([hubs[:, :, None, 1],
                         np.where(tmask, tgts[..., 1], hubs[:, :, None, 1])],
                        axis=2)
    bbox = np.stack([py.min(2) - 1, px.min(2) - 1,
                     py.max(2) + 1, px.max(2) + 1], axis=-1)

    pend = [collections.deque(np.nonzero(nmask[b])[0].tolist())
            for b in range(bsz)]
    # In-grid blocked cells per spec (normally grows from empty as
    # commits cross capacity); Manhattan distance from a lane's hub to
    # this set decides closed-form vs frontier expansion per lane.
    blk_yx: list[list[np.ndarray]] = []
    for b in range(bsz):
        by, bx = np.nonzero(occ[b, :grids[b, 0], :grids[b, 1]] >= capacity)
        blk_yx.append([by.astype(np.int64), bx.astype(np.int64)])
    crossed = [bool(blk_yx[b][0].size) for b in range(bsz)]
    routed = np.zeros(bsz, np.int32)
    failed = np.zeros(bsz, np.int32)
    wirelen = np.zeros(bsz, np.int32)
    buffer: dict[tuple[int, int], _Buffered] = {}
    schedule = RouteSchedule([], bbox) if record else None
    rounds = collisions = crossings = 0

    while any(pend):
        rounds += 1
        # ---- color: greedy bbox-disjoint picks over pending, slot order
        man_lanes: list[tuple[int, int]] = []
        bfs_lanes: list[tuple[int, int]] = []
        for b in range(bsz):
            chosen: list[np.ndarray] = []
            picked: list[tuple[int, int]] = []
            for s in pend[b]:
                if (b, s) in buffer:
                    continue
                bb = bbox[b, s]
                if any(_bbox_overlap(bb, c) for c in chosen):
                    # Slots past a conflict cannot commit this round
                    # (commits are in slot order), so computing them now
                    # would be speculative work that the next crossing
                    # would likely throw away — stop the scan here.
                    break
                chosen.append(bb)
                picked.append((b, s))
            if not picked:
                continue
            if not crossed[b]:
                man_lanes.extend(picked)
                continue
            # Crossed spec: a lane whose farthest target (Manhattan) is
            # no farther than the nearest blocked cell never reads a
            # cell the obstacles can shadow (same bound as
            # `_still_valid`), so its field is still closed-form; only
            # the rest pay a frontier expansion.
            ps = np.array([s for _, s in picked])
            hy, hx = hubs[b, ps, 0], hubs[b, ps, 1]
            d0 = (np.abs(tgts[b, ps, :, 0] - hy[:, None])
                  + np.abs(tgts[b, ps, :, 1] - hx[:, None]))
            d0max = np.where(tmask[b, ps], d0, -1).max(1)
            by, bx = blk_yx[b]
            blkmin = (np.abs(by[None, :] - hy[:, None])
                      + np.abs(bx[None, :] - hx[:, None])).min(1)
            for k, lane in enumerate(picked):
                (man_lanes if d0max[k] <= blkmin[k]
                 else bfs_lanes).append(lane)
        if schedule is not None:
            schedule.dispatches.append(man_lanes + bfs_lanes)

        # ---- expand: closed-form fields for still-obstacle-free specs
        if man_lanes:
            lb = np.array([b for b, _ in man_lanes])
            ls = np.array([s for _, s in man_lanes])
            hy, hx = hubs[lb, ls, 0], hubs[lb, ls, 1]
            t_y, t_x = tgts[lb, ls, :, 0], tgts[lb, ls, :, 1]
            tm = tmask[lb, ls]
            d0 = np.abs(t_y - hy[:, None]) + np.abs(t_x - hx[:, None])
            wk, wj = np.nonzero(tm)
            wl_l, wys, wxs = _manhattan_paths(
                wk, hy[wk], hx[wk], t_y[wk, wj], t_x[wk, wj])
            per_lane = _group_cells(wl_l, wys * gw + wxs, len(man_lanes))
            for k, (b, s) in enumerate(man_lanes):
                dk = d0[k][tm[k]]
                buffer[(b, s)] = _Buffered(
                    cells=per_lane[k], wl=int((dk + 1).sum()), ok=True,
                    d0max=int(dk.max()) if dk.size else -1,
                    dist=None, hub=(int(hy[k]), int(hx[k])))

        # ---- expand: bucketed frontier wavefronts, early-exit on targets
        fresh: list[tuple[int, int]] = []
        if bfs_lanes:
            lb = np.array([b for b, _ in bfs_lanes])
            ls = np.array([s for _, s in bfs_lanes])
            nlan = len(bfs_lanes)
            karr = np.arange(nlan, dtype=np.int64)
            occ_l = occ[lb] >= capacity
            free = canvas_free(occ_l)
            dist = np.full((nlan, (gh + 2) * stride), INF, np.int32)
            hy, hx = hubs[lb, ls, 0], hubs[lb, ls, 1]
            sidx = canvas_index(hy, hx, stride)
            dist[karr, sidx] = 0
            t_y, t_x = tgts[lb, ls, :, 0], tgts[lb, ls, :, 1]
            tm = tmask[lb, ls]
            tciv = canvas_index(t_y, t_x, stride)
            tb = occ_l.reshape(nlan, -1)[karr[:, None], t_y * gw + t_x] & tm

            def resolved():
                res = dist[karr[:, None], tciv] < INF
                if tb.any():
                    ndv = dist[karr[:, None, None],
                               tciv[:, :, None] + offs[None, None, :]]
                    res = res | (tb & (ndv < INF).any(-1))
                return (res | ~tm).all(1)

            expand_buckets(free, dist, karr, sidx, stride, resolved)

            dv = dist[karr[:, None], tciv].astype(np.int64)
            ndv = dist[karr[:, None, None],
                       tciv[:, :, None] + offs[None, None, :]]
            nmin = ndv.min(-1).astype(np.int64)
            d0 = np.where(dv < INF, dv, np.minimum(nmin + 1, INF))
            run = tm & (d0 < INF)
            okl = (run | ~tm).all(1)
            blkt = run & (dv >= INF)
            esel = np.argmax(ndv == (d0 - 1)[:, :, None], axis=2)
            entry = tciv + offs[esel]
            start = np.where(blkt, entry, tciv)
            dstart = np.where(blkt, d0 - 1, d0)
            wk, wj = np.nonzero(run & okl[:, None])
            bw = blkt[wk, wj]
            sl, sc = _walk_paths(dist, wk, start[wk, wj], dstart[wk, wj],
                                 stride)
            lanes_all = np.concatenate([wk, wk[bw], sl])
            cidx_all = np.concatenate([tciv[wk, wj], entry[wk, wj][bw], sc])
            cells_all = ((cidx_all // stride - 1) * gw
                         + (cidx_all % stride - 1))
            per_lane = _group_cells(lanes_all, cells_all, nlan)
            for k, (b, s) in enumerate(bfs_lanes):
                dk = d0[k][run[k]]
                buffer[(b, s)] = _Buffered(
                    cells=per_lane[k],
                    wl=int((dk + 1).sum()) if okl[k] else 0,
                    ok=bool(okl[k]),
                    d0max=int(dk.max()) if (okl[k] and dk.size) else -1,
                    dist=dist[k], hub=None)
                fresh.append((b, s))

        # ---- commit: strictly in slot order, collision-test on crossings
        for b in range(bsz):
            while pend[b] and (b, pend[b][0]) in buffer:
                s = pend[b].popleft()
                e = buffer.pop((b, s))
                if not e.ok:
                    failed[b] += 1
                    continue
                routed[b] += 1
                wirelen[b] += e.wl
                uc, cnt = np.unique(e.cells, return_counts=True)
                pre = occ_flat[b, uc]
                occ_flat[b, uc] = pre + cnt
                newly = uc[(pre < capacity) & (pre + cnt >= capacity)]
                if newly.size:
                    crossings += 1
                    crossed[b] = True
                    ys, xs = newly // gw, newly % gw
                    blk_yx[b][0] = np.concatenate([blk_yx[b][0], ys])
                    blk_yx[b][1] = np.concatenate([blk_yx[b][1], xs])
                    for key in [k for k in buffer if k[0] == b]:
                        if not _still_valid(buffer[key], ys, xs, stride):
                            del buffer[key]
                            collisions += 1

        # Surviving frontier fields are views into this round's batch
        # array; copy them out so the batch can be freed.
        for key in fresh:
            if key in buffer and buffer[key].dist is not None:
                buffer[key].dist = buffer[key].dist.copy()

    if schedule is not None:
        schedule.rounds = rounds
        schedule.collisions = collisions
        schedule.crossings = crossings
    return occ, routed, failed, wirelen, rounds, collisions, schedule


class BatchedRouting(NamedTuple):
    routed: np.ndarray          # (B,) int32 — successfully routed nets
    failed: np.ndarray          # (B,) int32
    wirelength: np.ndarray      # (B,) int32 — total path points
    occ_count: np.ndarray       # (B, Gh, Gw) int32 congestion map
    grids: np.ndarray           # (B, 2) per-spec (gh, gw)
    engine: str = "scan"        # "scan" (lax.scan slots) | "concurrent"
    rounds: int = 0             # wavefront dispatch rounds taken
    collisions: int = 0         # buffered routes dropped by a crossing
    schedule: RouteSchedule | None = None

    @property
    def success_rate(self) -> np.ndarray:
        n = self.routed + self.failed
        return np.where(n > 0, self.routed / np.maximum(n, 1), 1.0)


def batched_route(nets: NetBatch, widths: np.ndarray, heights: np.ndarray,
                  *, coarse: int = 64, capacity: int = 4,
                  use_kernel: bool | None = None,
                  engine: str | None = None,
                  record_schedule: bool = False) -> BatchedRouting:
    """Drive the batched wavefront routing over all specs.

    engine: "concurrent" (conflict-aware host scheduler over frontier
    buckets — the default off-TPU), "scan" (one `lax.scan` wavefront per
    net slot; the default on TPU, where the Pallas kernel batches the
    grids, and whenever `use_kernel` forces a device impl), or None for
    that auto choice.  Both engines produce identical results — the
    concurrent engine is proven and tested against the scan engine and
    the sequential router, not an approximation of them.

    Cells beyond a spec's own routing grid are pre-blocked, so padding a
    small spec up to the batch-max grid cannot open new paths."""
    bsz = len(widths)
    grids = np.array([grid_shape(int(w), int(h), coarse)
                      for w, h in zip(widths, heights)], np.int64)
    gh_max, gw_max = int(grids[:, 0].max()), int(grids[:, 1].max())
    iy = np.arange(gh_max)[None, :, None]
    ix = np.arange(gw_max)[None, None, :]
    blocked = ((iy >= grids[:, 0, None, None])
               | (ix >= grids[:, 1, None, None]))
    occ0_np = np.where(blocked, capacity, 0).astype(np.int32)
    if engine is None:
        engine = ("scan" if use_kernel or jax.default_backend() == "tpu"
                  else "concurrent")
    if engine == "concurrent":
        nets_np = NetBatch(*(np.asarray(a) for a in nets))
        occ, routed, failed, wirelen, rounds, collisions, sched = \
            _concurrent_route(nets_np, grids, occ0_np, capacity=capacity,
                              record=record_schedule)
        occ_np = np.where(blocked, 0, occ).astype(np.int32)
        return BatchedRouting(routed, failed, wirelen, occ_np, grids,
                              "concurrent", rounds, collisions, sched)
    if engine != "scan":
        raise ValueError(f"engine must be 'scan' or 'concurrent', "
                         f"got {engine!r}")
    occ, routed, failed, wirelen = _route_program(
        jnp.asarray(occ0_np), nets, capacity=capacity, use_kernel=use_kernel)
    occ_np = np.asarray(occ)
    occ_np = np.where(blocked, 0, occ_np).astype(np.int32)
    return BatchedRouting(np.asarray(routed), np.asarray(failed),
                          np.asarray(wirelen), occ_np, grids,
                          "scan", int(nets.nmask.shape[1]), 0, None)


# ----------------------------------------------------------------------
# The end-to-end batched flow
# ----------------------------------------------------------------------
@dataclasses.dataclass
class BatchedLayoutResult:
    """Layouts for a whole spec batch, in padded tensor form.

    Mirrors `flow.LayoutResult` per spec (`metrics_rows` carries the same
    keys minus the wall-clock; `placements()` / `drc_reports()` unpack to
    the sequential dataclasses).  Wire point lists are not materialized —
    the routing stats and the congestion map (`routing.occ_count`) are;
    use the sequential `flow.generate_layout` when full wire geometry is
    needed (e.g. for GDS-like JSON export of a single chosen design
    point).  Timing is a caller concern: `repro.api.DesignSession`
    reports it in the artifact provenance, benchmarks time around the
    call — the library path itself stays clock-free.
    """

    specs: tuple[MacroSpec, ...]
    dims: BatchDims
    geom: PlacerGeometry
    ops: LayoutOperands
    tensors: dict
    routing: BatchedRouting
    drc_overlaps: np.ndarray
    drc_oob: np.ndarray
    netlist_stats: list[dict]

    def __len__(self) -> int:
        return len(self.specs)

    @property
    def widths(self) -> np.ndarray:
        return np.asarray(self.ops.width)

    @property
    def heights(self) -> np.ndarray:
        return np.asarray(self.ops.height)

    @property
    def drc_clean(self) -> np.ndarray:
        return (self.drc_overlaps == 0) & (self.drc_oob == 0)

    def drc_reports(self) -> list[DRCReport]:
        return [DRCReport(int(o), int(b))
                for o, b in zip(self.drc_overlaps, self.drc_oob)]

    def placements(self) -> list[Placement]:
        """Unpack per-spec named `Placement`s (host-side, for interop)."""
        out = []
        np_tensors = {c: (np.asarray(r), np.asarray(m))
                      for c, (r, m) in self.tensors.items()}
        for i, spec in enumerate(self.specs):
            exact = dims_for_spec(spec)
            rects: list[Placed] = []
            for cat in CATEGORIES:
                vals, mask = np_tensors[cat]
                vals = vals[i].reshape(-1, 4)[mask[i].reshape(-1)]
                cell = CATEGORY_CELL[cat]
                rects.extend(
                    Placed(name, cell, *map(int, xywh)) for name, xywh
                    in zip(category_names(cat, exact, spec), vals))
            out.append(Placement(spec, rects, int(self.widths[i]),
                                 int(self.heights[i])))
        return out

    def metrics_rows(self) -> list[dict]:
        """Per-spec metrics: the pure-content keys of
        `LayoutResult.metrics` (no `elapsed_s` — rows are identical for
        a spec regardless of what batch it rode in)."""
        h = np.array([s.h for s in self.specs], np.float32)
        l = np.array([s.l for s in self.specs], np.float32)
        b = np.array([s.b_adc for s in self.specs], np.float32)
        est = np.asarray(estimator.area_f2_per_bit(h, l, b))
        area = (self.widths.astype(np.float64) * self.heights
                / np.array([s.array_size for s in self.specs]))
        succ = self.routing.success_rate
        rows = []
        for i, s in enumerate(self.specs):
            rows.append({
                "h": s.h, "w": s.w, "l": s.l, "b_adc": s.b_adc,
                "layout_area_f2_per_bit": float(area[i]),
                "estimator_area_f2_per_bit": float(est[i]),
                "area_model_error": float(area[i] / est[i] - 1.0),
                "routed_nets": int(self.routing.routed[i]),
                "failed_nets": int(self.routing.failed[i]),
                "route_success": float(succ[i]),
                "wirelength": int(self.routing.wirelength[i]),
                "drc_clean": bool(self.drc_clean[i]),
            })
        return rows

    def to_json(self, path: str) -> None:
        import json

        with open(path, "w") as f:
            json.dump({"specs": [s.as_tuple() for s in self.specs],
                       "points": self.metrics_rows()}, f, indent=1)


def iter_layout_buckets(buckets, *, use_kernel: bool | None = None,
                        engine: str | None = None):
    """Stream a sequence of layout buckets through the batched flow.

    `buckets` is an iterable of `(specs, coarse, capacity)` triples —
    one routing-grid-shape bucket each (see the bucketing in
    `repro.api.session`).  Each bucket's `BatchedLayoutResult` is
    yielded as soon as its dispatch chain completes, so a consumer (the
    staged pipeline executor in `repro.serve.design_service`, or a
    plain `for` loop) can overlap downstream work — artifact
    finalization, the next batch's exploration — with the remaining
    buckets instead of blocking until the whole union is laid out.
    """
    for specs, coarse, capacity in buckets:
        yield generate_layouts(specs, coarse=coarse, capacity=capacity,
                               use_kernel=use_kernel, engine=engine)


def generate_layouts(specs, *, coarse: int = 64, capacity: int = 4,
                     use_kernel: bool | None = None,
                     engine: str | None = None) -> BatchedLayoutResult:
    """Lay out a whole (e.g. Pareto-distilled) spec batch at once.

    Equivalent per spec to calling `flow.generate_layout` B times, but
    placement/DRC/net derivation are single vmapped dispatches and
    routing expands all B wavefronts together.
    """
    specs = tuple(specs)
    if not specs:
        raise ValueError("generate_layouts needs at least one MacroSpec")
    geom = geometry()
    dims = BatchDims.for_specs(specs)
    ops = stack_layout_operands(specs, geom)
    tensors = _place_program(ops, dims=dims, geom=geom)
    overlaps, oob = _drc_program(tensors, ops, dims=dims, geom=geom)
    nets = _nets_program(tensors, ops, dims=dims, geom=geom, coarse=coarse)
    routing = batched_route(nets, np.asarray(ops.width),
                            np.asarray(ops.height), coarse=coarse,
                            capacity=capacity, use_kernel=use_kernel,
                            engine=engine)
    stats = [nl_mod.stats_for_spec(s) for s in specs]
    return BatchedLayoutResult(
        specs=specs, dims=dims, geom=geom, ops=ops, tensors=tensors,
        routing=routing, drc_overlaps=np.asarray(overlaps),
        drc_oob=np.asarray(oob), netlist_stats=stats)
