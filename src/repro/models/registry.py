"""Model registry: uniform (init / loss / decode) API over all families,
plus exact parameter accounting used by the roofline's MODEL_FLOPS terms.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm, paligemma, whisper

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable[[Array], Any]
    loss: Callable[[Any, dict], tuple[Array, dict]]
    init_decode_state: Callable[[int, int], Any]
    decode_step: Callable[[Any, Any, Array], tuple[Array, Any]]


def build_model(cfg: ArchConfig, *, remat: bool = False,
                mlstm_chunked: bool = False) -> ModelAPI:
    if cfg.family == "audio":
        return ModelAPI(
            cfg,
            init=lambda key: whisper.init_whisper(key, cfg),
            loss=lambda p, b: whisper.whisper_loss(p, b, cfg, remat=remat),
            init_decode_state=lambda bs, s: whisper.init_whisper_decode_state(cfg, bs, s),
            decode_step=lambda p, st, t: whisper.whisper_decode_step(p, st, t, cfg),
        )
    if cfg.family == "vlm":
        return ModelAPI(
            cfg,
            init=lambda key: paligemma.init_paligemma(key, cfg),
            loss=lambda p, b: paligemma.paligemma_loss(p, b, cfg, remat=remat),
            init_decode_state=lambda bs, s: paligemma.init_decode_state(cfg, bs, s),
            decode_step=lambda p, st, t: paligemma.decode_step(p, st, t, cfg),
        )
    return ModelAPI(
        cfg,
        init=lambda key: lm.init_lm(key, cfg),
        loss=lambda p, b: lm.lm_loss(p, b, cfg, remat=remat,
                                     mlstm_chunked=mlstm_chunked),
        init_decode_state=lambda bs, s: lm.init_decode_state(cfg, bs, s),
        decode_step=lambda p, st, t: lm.decode_step(p, st, t, cfg),
    )


# ---------------------------------------------------------------------------
# parameter accounting (for 6*N*D roofline terms)
# ---------------------------------------------------------------------------
def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    """Exact count by tracing init with ShapeDtypeStructs (no allocation).

    active_only: MoE experts counted at top_k (+shared) instead of all E —
    the 6*N_active*D convention from the brief.
    """
    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.key(0))
    total = 0

    def add(path, leaf):
        nonlocal total
        n = int(np.prod(leaf.shape))
        name = jax.tree_util.keystr(path)
        if active_only and cfg.moe is not None and (
                "'wi'" in name or "'wg'" in name or "'wo'" in name) and (
                "ffn" in name) and ("shared" not in name) and ("dense" not in name)\
                and len(leaf.shape) >= 3:
            # stacked expert tensors: (L, E, d, f) -> count top_k of E
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n

    jax.tree_util.tree_map_with_path(add, shapes)
    return total


def embedding_params(cfg: ArchConfig) -> int:
    n = cfg.vocab * cfg.d_model
    if not cfg.tie_embeddings and cfg.family != "audio":
        n *= 2
    if cfg.pos == "learned":
        n += 8192 * cfg.d_model
    return n
