"""PaliGemma-style VLM backbone (vlm family).

Per the assignment the SigLIP vision tower is a STUB: `input_specs()`
provides precomputed patch embeddings (B, 256, d_model).  The language
decoder is a Gemma-style transformer (MQA kv=1, GeGLU d_ff=16384, head_dim
256, RoPE) that attends with a *prefix-LM* mask: bidirectional across the
image patches, causal over text — per arXiv:2407.07726.

Decode reuses the generic `lm.decode_step` (past the prefix everything is
ordinary causal decoding over the joint cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common, lm
from repro.models.common import prefix_lm_mask

Array = jax.Array


def init_paligemma(key: Array, cfg: ArchConfig):
    return lm.init_lm(key, cfg)


def paligemma_loss(params, batch: dict, cfg: ArchConfig, *, remat: bool = False):
    """batch: patches (B, P, D) float, inputs (B, S) int32, targets (B, S)."""
    patches = batch["patches"]
    p = patches.shape[1]
    s = batch["inputs"].shape[1]
    mask = prefix_lm_mask(p + s, p)
    hidden, aux = lm.lm_hidden(params, batch["inputs"], cfg, mask=mask,
                               prefix_embeds=patches, remat=remat)
    logits = lm.lm_logits(params, hidden[:, p:], cfg)
    loss, metrics = common.softmax_cross_entropy(logits, batch["targets"])
    metrics["aux_loss"] = aux
    return loss + aux, metrics


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int):
    return lm.init_decode_state(cfg, batch, max_seq)


def decode_step(params, state, tokens: Array, cfg: ArchConfig):
    return lm.decode_step(params, state, tokens, cfg)
