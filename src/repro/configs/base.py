"""Architecture configuration schema.

One `ArchConfig` instance fully determines a model: the generic decoder
(`repro.models.lm`) plus family-specific mixers (MoE, MLA, Mamba2, xLSTM,
encoder-decoder, VLM prefix) are all driven from here.  Each assigned
architecture lives in `repro/configs/<id>.py` with the exact published
numbers; `reduced()` derives the CPU-smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts
    capacity_factor: float = 1.25
    group_size: int = 512        # tokens per dispatch group (GSPMD-friendly)
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    dense_ff: int = 0            # parallel dense FFN width (Arctic residual)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512           # compressed KV latent dim
    rope_dim: int = 64           # decoupled rope head dim
    nope_dim: int = 128          # per-head non-rope q/k dim
    v_dim: int = 128             # per-head value dim


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 64              # Mamba2 SSM state per head
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256             # SSD chunk length
    n_groups: int = 1            # B/C groups


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0     # mLSTM up-projection factor
    conv_width: int = 4
    chunk: int = 64              # mLSTM chunkwise-parallel length
    slstm_every: int = 2         # every k-th block is sLSTM (1:1 -> 2)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    shared_attn_every: int = 6   # Zamba2: shared attn block period
    attn_heads: int = 32
    attn_kv_heads: int = 32
    shared_ff: int = 10240


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 32
    enc_frames: int = 1500       # whisper fixed encoder length (stub frontend)


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    n_patches: int = 256         # SigLIP stub: precomputed patch embeddings


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "silu"
    mlp_gated: bool = True
    attn_bias: bool = False
    mlp_bias: bool = False
    qk_norm: bool = False
    pos: str = "rope"            # rope | learned | sinusoidal (enc)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # source annotation from the assignment table
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Whether long_500k decode is admissible (SSM/hybrid/linear-attn)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # no encoder-only archs in this assignment

    def n_params(self) -> int:
        """Total parameter count (exact, mirrors the init functions)."""
        from repro.models import registry  # local import to avoid cycle

        return registry.count_params(self)

    def n_active_params(self) -> int:
        from repro.models import registry

        return registry.count_params(self, active_only=True)
