"""Telemetry & adaptive control plane for the design service.

Three layers, each usable alone:

  * tracing (`repro.telemetry.spans`) — `SpanRecorder` collects
    monotonic-clock stage spans; `TraceExport` serializes them as a
    schema-stamped, Chrome-trace-compatible event list and a per-batch
    stage Gantt;
  * metrics (`repro.telemetry.metrics` + `repro.telemetry.export`) —
    a typed `Counter`/`Gauge`/`Histogram` registry snapshotable as
    versioned JSON or prometheus text;
  * control (`repro.telemetry.control`) — `FeedbackController` turns
    windowed metrics (arrival-rate EMA, queue depth, pool occupancy)
    into adaptive-coalescing and pool-autoscaling decisions, each
    recorded as a span.

`Telemetry` is the bundle `repro.serve.design_service.DesignService`
accepts (`telemetry=Telemetry()` or `telemetry=True`): one recorder +
one registry wired through the admission pump, all four stage workers,
the layout pool, and the retry/shed/preemption paths.
"""
from repro.telemetry.control import (ControlDecision, ControllerConfig,
                                     FeedbackController)
from repro.telemetry.export import (atomic_write_json, load_snapshot,
                                    render_prometheus, write_metrics_json)
from repro.telemetry.metrics import (DEFAULT_LATENCY_BUCKETS,
                                     HISTOGRAM_SAMPLE_CAP, METRICS_SCHEMA,
                                     Counter, Gauge, Histogram,
                                     MetricsRegistry, percentile)
from repro.telemetry.spans import TRACE_SCHEMA, Span, SpanRecorder, TraceExport


class Telemetry:
    """One recorder + one registry: what the service threads through its
    pump, stages, pool, and fault paths.  Pass your own pieces to share
    a recorder between a session and several services, or rely on the
    defaults."""

    def __init__(self, *, recorder: SpanRecorder | None = None,
                 metrics: MetricsRegistry | None = None):
        self.recorder = recorder if recorder is not None else SpanRecorder()
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    def export(self) -> TraceExport:
        return self.recorder.export()


__all__ = [
    "ControlDecision", "ControllerConfig", "Counter",
    "DEFAULT_LATENCY_BUCKETS", "FeedbackController", "Gauge", "Histogram",
    "HISTOGRAM_SAMPLE_CAP", "METRICS_SCHEMA", "MetricsRegistry", "Span",
    "SpanRecorder", "TRACE_SCHEMA", "Telemetry", "TraceExport",
    "atomic_write_json", "load_snapshot", "percentile", "render_prometheus",
    "write_metrics_json",
]
