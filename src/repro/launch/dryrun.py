import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this records (JSON cache in runs/dryrun/):
  * compile success, wall time;
  * memory_analysis(): per-device argument/output/temp bytes (proves fit);
  * cost_analysis(): per-device HLO FLOPs and bytes accessed;
  * collective bytes by op kind, parsed from compiled.as_text()
    (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute, including async -start forms);
  * the roofline terms (compute / memory / collective, seconds) per the
    brief's TPU v5e constants.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import registry as creg
from repro.launch import shapes as shp
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.core.constants import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_BF16_FLOPS

RUNS = pathlib.Path(__file__).resolve().parents[3] / "runs" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?(?:\.\d+)?\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\])")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"\"known_trip_count\":{\"n\":\"(\d+)\"}")
_CALLEE_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> tuple[dict[str, list[str]], str]:
    comps: dict[str, list[str]] = {}
    name, buf, entry = None, [], None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if name is None and stripped.endswith("{") and ") -> " in stripped:
            head = stripped.split("(", 1)[0].strip()
            is_entry = head.startswith("ENTRY")
            head = head.removeprefix("ENTRY").strip().lstrip("%")
            name = head
            if is_entry:
                entry = name
            buf = []
            comps[name] = buf
        elif name is not None:
            if stripped == "}":
                name = None
            else:
                buf.append(line)
    return comps, entry or ""


def collective_bytes(hlo_text: str) -> dict:
    """Collective bytes with while-loop trip-count multipliers.

    XLA reports each while body once; per-layer collectives (TP psums, EP
    all-to-alls) live inside the scan-over-layers body and must be scaled
    by the trip count.  Trip counts come from the `known_trip_count`
    backend_config XLA attaches to each while; the effective multiplier is
    the product along the while-nesting path from ENTRY.  Async -start
    lines are skipped (the -done carries the result shape).
    """
    comps, entry = _split_computations(hlo_text)

    bytes_by_kind: dict[str, float] = {}
    count: dict[str, int] = {}
    visited: set[tuple[str, float]] = set()

    def walk(name: str, mult: float) -> None:
        if name not in comps or (name, mult) in visited:
            return
        visited.add((name, mult))
        for line in comps[name]:
            m = _COLL_RE.search(line)
            if m and "-start" not in line.split("=")[0]:
                kind, nbytes = m.group(1), _shape_bytes(m.group(2))
                bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + nbytes * mult
                count[kind] = count.get(kind, 0) + 1
            if "while(" in line:
                bm = _WHILE_BODY_RE.search(line)
                tm = _TRIP_RE.search(line)
                if bm:
                    walk(bm.group(1), mult * (int(tm.group(1)) if tm else 1))
            else:
                for callee in _CALLEE_RE.findall(line):
                    if callee in comps and callee != name:
                        walk(callee, mult)

    walk(entry, 1.0)
    return {"bytes": bytes_by_kind, "count": count,
            "total_bytes": sum(bytes_by_kind.values())}


def model_flops(cfg, shape: shp.ShapeSpec) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) per the brief; decode: D = batch
    tokens per step."""
    from repro.models.registry import count_params

    n = count_params(cfg, active_only=cfg.moe is not None)
    if shape.kind == "train":
        d = shape.batch * shape.seq
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.batch * shape.seq
        return 2.0 * n * d
    return 2.0 * n * shape.batch        # decode: one token per sequence


def analytic_terms(cfg, shape: shp.ShapeSpec, chips: int) -> dict:
    from repro.launch.roofline_model import cell_cost

    cost = cell_cost(cfg, shape)
    return {
        "flops_global": cost.flops,
        "hbm_bytes_global": cost.hbm_bytes,
        "compute_s": cost.flops / (chips * TPU_PEAK_BF16_FLOPS),
        "memory_s": cost.hbm_bytes / (chips * TPU_HBM_BW),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             force: bool = False, variant: str = "") -> dict:
    cfg = creg.get(arch)
    shape = shp.SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    RUNS.mkdir(parents=True, exist_ok=True)
    suffix = f"__{variant}" if variant else ""
    out_path = RUNS / (f"{creg.canonical(arch)}__{shape_name}__{mesh_name}"
                       f"{suffix}.json")
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    ok, why = shp.applicable(cfg, shape)
    rec = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind}
    if not ok:
        rec.update(status="skip", reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        if shape.kind == "train":
            kw = dict(microbatches=shp.microbatches_for(cfg, shape))
            if variant == "perf":
                kw.update(steps_mod.PERF_TRAIN_OVERRIDES.get(cfg.name, {}))
            ts = steps_mod.make_train_step(cfg, mesh, **kw)
            lowered = ts.fn.lower(ts.state_struct, ts.batch_struct)
        elif shape.kind == "prefill":
            ps = steps_mod.make_prefill_step(cfg, mesh, shape)
            lowered = ps.fn.lower(ps.params_struct, ps.batch_struct)
        else:
            ss = steps_mod.make_serve_step(cfg, mesh, shape)
            lowered = ss.fn.lower(ss.params_struct, ss.state_struct,
                                  ss.tokens_struct)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    except Exception as e:  # noqa: BLE001 — failures are data here
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    coll_dev = coll["total_bytes"]      # per-device program, trip-corrected
    ana = analytic_terms(cfg, shape, chips)
    terms = {
        "compute_s": ana["compute_s"],
        "memory_s": ana["memory_s"],
        "collective_s": coll_dev / TPU_ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    rec.update(
        status="ok", chips=chips, lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "total_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
            "fits_16gb": bool(ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes < 16e9),
        },
        # raw HLO cost analysis (NOTE: while bodies counted once — see
        # roofline_model.py docstring; analytic terms are authoritative)
        cost={"flops_per_device_raw": flops_dev,
              "bytes_per_device_raw": bytes_dev,
              "transcendentals": float(ca.get("transcendentals", 0.0))},
        analytic=ana,
        collectives=coll,
        roofline={**terms, "dominant": dominant,
                  "model_flops_global": mf,
                  "useful_flops_ratio": mf / max(ana["flops_global"], 1.0),
                  "roofline_fraction": mf / max(ana["flops_global"], 1.0)
                  * ana["compute_s"] / max(max(terms.values()), 1e-30)},
        hlo_bytes=len(hlo),
    )
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shp.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="",
                    help="'perf' applies PERF_TRAIN_OVERRIDES; results get a "
                         "__perf suffix")
    args = ap.parse_args()

    archs = creg.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(shp.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    rows = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, force=args.force,
                               variant=args.variant)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']:<13s} "
                             f"comp={r['compute_s']:.3e}s "
                             f"mem={r['memory_s']:.3e}s "
                             f"coll={r['collective_s']:.3e}s "
                             f"bytes/dev={rec['memory']['total_bytes']/1e9:.2f}GB "
                             f"compile={rec['compile_s']}s")
                elif status == "error":
                    extra = rec["error"][:140]
                else:
                    extra = rec.get("reason", "")
                print(f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:10s} "
                      f"{status:5s} {extra}", flush=True)
                rows.append(rec)
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_err = sum(r["status"] == "error" for r in rows)
    n_skip = sum(r["status"] == "skip" for r in rows)
    print(f"\n{n_ok} ok, {n_err} error, {n_skip} skip / {len(rows)} cells")


if __name__ == "__main__":
    main()
