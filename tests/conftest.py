import faulthandler
import os
import sys
import threading

import pytest

# Tests run on the single real CPU device; only subprocess-based tests use
# forced host device counts (never set globally — per the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    import pytest_timeout  # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False


def _abort(item, seconds: float) -> None:
    # A deadlocked thread cannot be killed from Python: dump every
    # thread's traceback for the post-mortem, then hard-exit so CI gets
    # a failure instead of a 30-minute hang.
    sys.stderr.write(f"\n\nTIMEOUT: {item.nodeid} exceeded {seconds:g}s "
                     f"(conftest watchdog — pytest-timeout not installed); "
                     f"dumping all thread stacks and aborting the run\n\n")
    faulthandler.dump_traceback(all_threads=True)
    sys.stderr.flush()
    os._exit(1)


@pytest.fixture(autouse=True, scope="session")
def _lock_order_sanitizer():
    """With REPRO_LOCK_SANITIZER=1 (the CI sanitizer shard), every
    lock the threaded stack created via `make_lock` reported its
    acquisition order; assert the whole suite produced no inversion.
    A no-op (empty graph) when the gate is off."""
    yield
    from repro.runtime.lock_sanitizer import GLOBAL_REGISTRY
    GLOBAL_REGISTRY.assert_clean()


if not _HAVE_PYTEST_TIMEOUT:
    # Minimal stand-in for pytest-timeout's thread method: the threaded
    # pipeline tests mark themselves `@pytest.mark.timeout(N)` because a
    # bug there deadlocks rather than fails, and a deadlocked suite is
    # useless in CI.  When the real plugin is installed (CI does), it
    # handles the marker and this hook stays inert.
    @pytest.hookimpl(wrapper=True)
    def pytest_runtest_call(item):
        marker = item.get_closest_marker("timeout")
        if marker is None or not marker.args:
            return (yield)
        seconds = float(marker.args[0])
        timer = threading.Timer(seconds, _abort, args=(item, seconds))
        timer.daemon = True
        timer.start()
        try:
            return (yield)
        finally:
            timer.cancel()
