"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240
ssm_state=64 — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]

54 Mamba2 layers in 9 groups of 6; one *weight-shared* (attention + FFN)
block runs at the start of every group (gradient accumulates across its 9
invocations).  Mamba2: expand 2 (d_inner 5120), head_dim 64 (80 heads),
state 64, conv 4, chunked SSD.  Runs long_500k (O(1)/token state).
"""
import dataclasses

from repro.configs.base import ArchConfig, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    norm="rmsnorm", act="silu", mlp_gated=True,
    ssm=SSMConfig(state=64, head_dim=64, expand=2, conv_width=4, chunk=256),
    hybrid=HybridConfig(shared_attn_every=6, attn_heads=32, attn_kv_heads=32,
                        shared_ff=10240),
    source="arXiv:2411.15242; hf",
)

REDUCED = dataclasses.replace(
    CONFIG, name="zamba2-reduced",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    ssm=SSMConfig(state=8, head_dim=16, expand=2, conv_width=4, chunk=16),
    hybrid=HybridConfig(shared_attn_every=2, attn_heads=4, attn_kv_heads=4,
                        shared_ff=128),
)
