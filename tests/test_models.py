"""Model zoo: per-arch reduced smoke tests + cross-implementation
consistency identities (the strongest correctness evidence in the suite)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as creg
from repro.models import attention as attn_mod
from repro.models import lm, mamba2, mlp, xlstm
from repro.models.registry import build_model, count_params


def _batch(cfg, b=2, s=32):
    batch = {"inputs": jnp.arange(b * s).reshape(b, s).astype(jnp.int32) % 17 + 3,
             "targets": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jnp.ones((b, cfg.encdec.enc_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = 0.1 * jnp.ones((b, cfg.vlm.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", creg.ARCH_IDS)
class TestSmokePerArch:
    def test_forward_train_step_no_nans(self, name):
        cfg = creg.reduced(name)
        api = build_model(cfg)
        p = api.init(jax.random.key(0))
        batch = _batch(cfg)
        loss, metrics = jax.jit(api.loss)(p, batch)
        assert jnp.isfinite(loss), name
        g = jax.grad(lambda p: api.loss(p, batch)[0])(p)
        gn = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
                 for x in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0, name

    def test_decode_steps_finite(self, name):
        cfg = creg.reduced(name)
        api = build_model(cfg)
        p = api.init(jax.random.key(0))
        st = api.init_decode_state(2, 64)
        if cfg.family == "audio":
            from repro.models import whisper as wmod

            frames = 0.1 * jnp.ones((2, cfg.encdec.enc_frames, cfg.d_model))
            ck, cv = wmod.precompute_cross(p, frames, cfg)
            st["cross_k"], st["cross_v"] = ck, cv
        toks = jnp.array([3, 5], jnp.int32)
        step = jax.jit(api.decode_step)
        for _ in range(3):
            logits, st = step(p, st, toks)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
        assert logits.shape == (2, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), name

    def test_full_config_param_count_scale(self, name):
        """FULL configs instantiate via eval_shape only (no allocation) and
        land in the right parameter-count ballpark."""
        cfg = creg.get(name)
        n = count_params(cfg)
        expected = {
            "arctic-480b": (4.3e11, 5.3e11),
            "deepseek-v2-lite-16b": (1.1e10, 1.9e10),
            "xlstm-125m": (0.8e8, 1.9e8),
            "qwen2.5-3b": (2.4e9, 3.8e9),
            "codeqwen1.5-7b": (6e9, 8.5e9),
            "granite-34b": (3.0e10, 3.9e10),
            "qwen3-8b": (6.8e9, 9.5e9),
            "whisper-large-v3": (1.2e9, 2.2e9),
            "zamba2-2.7b": (2.2e9, 3.4e9),
            "paligemma-3b": (2.2e9, 3.6e9),
        }[cfg.name]
        assert expected[0] <= n <= expected[1], (cfg.name, n)


class TestConsistencyIdentities:
    def test_mamba2_chunked_equals_recurrent(self):
        cfg = creg.reduced("zamba2_2_7b")
        p = mamba2.init_mamba2(jax.random.key(1), cfg)
        x = jax.random.normal(jax.random.key(2), (2, 32, cfg.d_model))
        y_par = mamba2.mamba2_fwd(p, x, cfg)
        st = mamba2.init_mamba2_state(cfg, 2)
        ys = []
        for t in range(32):
            yt, st = mamba2.mamba2_decode(p, x[:, t], st, cfg)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(y_par),
                                   np.asarray(jnp.stack(ys, 1)), atol=2e-5)

    def test_mlstm_scan_equals_chunked_equals_decode(self):
        cfg = creg.reduced("xlstm_125m")
        p = xlstm.init_mlstm(jax.random.key(3), cfg)
        x = jax.random.normal(jax.random.key(4), (2, 64, cfg.d_model))
        y_scan = xlstm.mlstm_fwd(p, x, cfg)
        y_chunk = xlstm.mlstm_fwd_chunked(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_chunk),
                                   atol=1e-5)
        st = xlstm.init_mlstm_state(cfg, 2)
        ys = []
        for t in range(64):
            yt, st = xlstm.mlstm_decode(p, x[:, t], st, cfg)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(y_scan),
                                   np.asarray(jnp.stack(ys, 1)), atol=1e-5)

    def test_blockwise_attention_equals_dense(self):
        cfg = creg.reduced("qwen3_8b")
        p = attn_mod.init_attention(jax.random.key(5), cfg)
        x = jax.random.normal(jax.random.key(6), (2, 64, cfg.d_model)
                              ).astype(jnp.float32)
        pos = jnp.arange(64)
        from repro.models.common import causal_mask

        dense = attn_mod.attention_fwd(p, x, cfg, mask=causal_mask(64),
                                       positions=pos)
        block = attn_mod.attention_fwd_blockwise(p, x, cfg, positions=pos,
                                                 kv_block=16)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(block),
                                   atol=2e-3)

    def test_mla_blockwise_equals_dense(self):
        cfg = creg.reduced("deepseek_v2_lite_16b")
        p = attn_mod.init_mla(jax.random.key(7), cfg)
        x = jax.random.normal(jax.random.key(8), (2, 32, cfg.d_model))
        pos = jnp.arange(32)
        from repro.models.common import causal_mask

        dense = attn_mod.mla_fwd(p, x, cfg, mask=causal_mask(32), positions=pos)
        block = attn_mod.mla_fwd_blockwise(p, x, cfg, positions=pos, kv_block=8)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(block),
                                   atol=2e-3)

    def test_decode_matches_teacher_forced_logits(self):
        """Strongest identity: step-by-step decode logits == full forward
        logits on the same token sequence (dense arch)."""
        cfg = creg.reduced("qwen2_5_3b")
        api = build_model(cfg)
        p = api.init(jax.random.key(9))
        toks = jax.random.randint(jax.random.key(10), (2, 16), 0, cfg.vocab)
        hidden, _ = lm.lm_hidden(p, toks, cfg)
        full_logits = lm.lm_logits(p, hidden, cfg)
        st = api.init_decode_state(2, 16)
        outs = []
        for t in range(16):
            lg, st = api.decode_step(p, st, toks[:, t])
            outs.append(lg)
        dec_logits = jnp.stack(outs, 1)
        np.testing.assert_allclose(np.asarray(dec_logits),
                                   np.asarray(full_logits.astype(jnp.float32)),
                                   atol=0.05, rtol=0.05)

    def test_moe_groupwise_close_to_dropfree(self):
        cfg = creg.reduced("arctic_480b")
        p = mlp.init_moe(jax.random.key(11), cfg.d_model, cfg)
        x = 0.5 * jax.random.normal(jax.random.key(12), (2, 64, cfg.d_model))
        y_g, aux = mlp.moe_fwd(p, x, cfg)
        y_d = mlp.moe_fwd_dense_eval(p, x, cfg)
        # capacity dropping may zero a few tokens; most must agree
        diff = jnp.linalg.norm((y_g - y_d).reshape(-1, cfg.d_model), axis=-1)
        base = jnp.linalg.norm(y_d.reshape(-1, cfg.d_model), axis=-1) + 1e-6
        frac_close = float(jnp.mean((diff / base) < 1e-3))
        assert frac_close > 0.85
        assert jnp.isfinite(aux)

    def test_moe_aux_loss_balanced_router_is_one(self):
        """With a uniform router, the Switch LB loss -> ~aux_weight."""
        cfg = creg.reduced("arctic_480b")
        m = cfg.moe
        p = mlp.init_moe(jax.random.key(13), cfg.d_model, cfg)
        p = dict(p, router=jnp.zeros_like(p["router"]))
        x = jax.random.normal(jax.random.key(14), (2, 64, cfg.d_model))
        _, aux = mlp.moe_fwd(p, x, cfg)
        assert float(aux) < 3 * m.router_aux_weight
