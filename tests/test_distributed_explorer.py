"""Island-model distributed NSGA-II (subprocess, 8 forced host devices)."""
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_islands_recover_true_front():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.parallel.distributed_explorer import explore_islands, pareto_front_of
        from repro.core import explorer, pareto

        mesh = jax.make_mesh((8,), ("i",))
        g, o = explore_islands(mesh, 16384, pop_size=48, generations=20,
                               migrate_every=10, seed=0)
        fg, fo = pareto_front_of(g, o)
        # compare against exhaustive ground truth
        genes_all, objs_all = explorer.full_design_space(16384)
        truth = np.asarray(pareto.non_dominated_mask(objs_all))
        true_front = {tuple(x) for x, m in zip(np.asarray(genes_all), truth) if m}
        found = {tuple(x) for x in fg}
        assert found <= true_front, "found dominated points"
        assert len(found) >= 0.5 * len(true_front), (len(found), len(true_front))
        print("OK", len(found), "/", len(true_front))
    """)
    import os

    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
