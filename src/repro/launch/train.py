"""Training launcher with auto-restart supervision.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_3b \
      --steps 200 --seq 256 --batch 8 [--supervise]

--supervise wraps the run in the in-process supervisor: preemption
(SIGTERM) or injected node failures checkpoint-and-restart until the step
budget completes.  On a real cluster the same entry point runs under the
cluster's restart policy (exit code 42 = retry).
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import registry as creg
from repro.runtime.fault_tolerance import PreemptionGuard, run_supervised
from repro.train.trainer import TrainerConfig, train


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (smoke) config of the family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="runs/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--mesh", default="1x1",
                    help="AxB -> (data, model) mesh over host devices")
    ap.add_argument("--supervise", action="store_true")
    args = ap.parse_args()

    cfg = creg.reduced(args.arch) if args.reduced else creg.get(args.arch)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))
    tcfg = TrainerConfig(seq=args.seq, global_batch=args.batch,
                         total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir,
                         microbatches=args.microbatches)

    guard = PreemptionGuard().install()

    def run_once() -> int:
        return train(cfg, mesh, tcfg, guard=guard).exit_code

    if args.supervise:
        return run_supervised(run_once)
    return run_once()


if __name__ == "__main__":
    sys.exit(main())
