"""Pure-jnp oracle for the maze_route kernels: multi-source Lee wavefront.

`wavefront_distance_ref` computes the full BFS distance field of the
Lee maze router (paper Sec. 2.3 / 3.3) by *fast sweeping*: a round runs
four directional min-plus propagations (+x, -x, +y, -y), each expressed
as a segmented associative scan that carries distance along free runs of
a row/column and is killed by blocked cells; rounds repeat until the
field stops changing.  The fixed point satisfies
``d = min(d0, 1 + min(4-neighbour d))`` on traversable cells — exactly
the BFS distance field of `repro.eda.router`'s former host-Python queue
implementation — but converges in O(bends-in-shortest-paths) rounds
instead of O(path-length) Jacobi steps, which is what makes the batched
layout flow faster than per-spec host BFS on every backend.

Semantics shared with the Pallas kernel (which keeps the simple
step-per-iteration relaxation — same unique fixed point):

  * seeds have distance 0, even when they sit on an occupied cell (a
    router hub is always enterable, matching the old BFS which started
    its queue at ``src`` unconditionally);
  * occupied cells are never traversed (distance stays `INF`) — the
    router handles the "dst occupied but still reachable" exception
    outside the wavefront (see `repro.eda.router.target_distance`).

The field is exact shortest-path distance, so any tie-break used to
backtrace a path from it yields a path of the same length as BFS.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Unreachable marker.  A plain Python int so Pallas kernels can close
# over it as a literal; small enough that INF + INF fits in int32 (the
# sweep composition adds two INF-capped terms before re-capping).
INF = 2 ** 29


def relax_once(dist: jax.Array, free: jax.Array) -> jax.Array:
    """One Jacobi wavefront step: (..., H, W) int32 -> same.

    The building block of the Pallas kernel; exported so tests can
    cross-check the sweeping fixed point against the plain relaxation.
    """
    pad = [(0, 0)] * (dist.ndim - 2)
    padded = jnp.pad(dist, pad + [(1, 1), (1, 1)], constant_values=INF)
    up = padded[..., :-2, 1:-1]
    down = padded[..., 2:, 1:-1]
    left = padded[..., 1:-1, :-2]
    right = padded[..., 1:-1, 2:]
    best = jnp.minimum(jnp.minimum(up, down), jnp.minimum(left, right)) + 1
    return jnp.where(free, jnp.minimum(dist, best), dist)


def _sweep(dist: jax.Array, transit: jax.Array, axis: int,
           reverse: bool) -> jax.Array:
    """Directional propagation: min-plus affine segmented scan.

    Each cell is the map f(x) = min(u, x + w) with u its current
    distance and w its traversal cost (1, or INF on non-transit cells so
    the carry dies at obstacles); composition of such maps is
    associative, so the whole run is one `associative_scan`.
    """
    u = jnp.where(transit, dist, INF)
    w = jnp.where(transit, 1, INF).astype(jnp.int32)

    def op(left, right):
        ul, wl = left
        ur, wr = right
        return (jnp.minimum(ur, jnp.minimum(ul + wr, INF)),
                jnp.minimum(wl + wr, INF))

    uu, _ = jax.lax.associative_scan(op, (u, w), axis=axis, reverse=reverse)
    return jnp.where(transit, jnp.minimum(dist, uu), dist)


def wavefront_distance_ref(occ: jax.Array, seed: jax.Array) -> jax.Array:
    """BFS distance field on a routing grid.

    occ:  (..., H, W) bool — blocked cells (track capacity exhausted).
    seed: (..., H, W) bool — wavefront sources (distance 0).
    Returns (..., H, W) int32 distances; `INF` where unreachable or
    blocked.  Seeds are distance 0 even on blocked cells.
    """
    occ = jnp.asarray(occ, jnp.bool_)
    seed = jnp.asarray(seed, jnp.bool_)
    # Seeds are traversable even when occupied (hub exception); paths
    # re-entering a source can never be shorter, so this is harmless.
    transit = ~occ | seed
    dist0 = jnp.where(seed, 0, INF).astype(jnp.int32)
    ndim = dist0.ndim

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        dist, _ = state
        nxt = dist
        for axis, reverse in ((ndim - 1, False), (ndim - 1, True),
                              (ndim - 2, False), (ndim - 2, True)):
            nxt = _sweep(nxt, transit, axis, reverse)
        return nxt, jnp.any(nxt < dist)

    dist, _ = jax.lax.while_loop(cond, body, (dist0, jnp.bool_(True)))
    return dist
