"""Production mesh construction.

`make_production_mesh` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.

Single pod: 16 x 16 = 256 chips, axes ("data", "model") — "model" maps onto
the high-bandwidth ICI torus dimension (TP/EP/SP collectives stay intra-pod),
"data" carries DP/FSDP.  Multi-pod: 2 x 16 x 16 = 512 chips with an outer
"pod" axis that only sees the per-step gradient all-reduce (DCN-friendly).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / elastic re-mesh)."""
    return jax.make_mesh(shape, axes)


def dp_size(mesh) -> int:
    import numpy as np

    return int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.axis_names]))
