"""Grid router (paper Sec. 2.3 / 3.3): Lee-style BFS wavefront on a coarse
routing grid, hierarchical per the paper — template internals use
predefined tracks (constant-time), only inter-template nets are maze-routed.

Two routing layers (H on layer 1, V on layer 2) with an occupancy grid per
layer; nets are routed sequentially, longest-first, marking used tracks.
Power and SAR control nets go on reserved tracks first (the paper's
"pre-defined routing tracks for critical nets").
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque

import numpy as np

from repro.eda.placer import Placement


@dataclasses.dataclass(frozen=True)
class Wire:
    net: str
    points: tuple[tuple[int, int], ...]     # grid path (coarse units)
    layer_pattern: str = "HV"


@dataclasses.dataclass
class RoutingResult:
    wires: list[Wire]
    grid_shape: tuple[int, int]
    coarse: int
    failed: list[str]
    total_wirelength: int

    @property
    def success_rate(self) -> float:
        n = len(self.wires) + len(self.failed)
        return len(self.wires) / n if n else 1.0


def _bfs(occ: np.ndarray, src: tuple[int, int], dst: tuple[int, int]):
    """Lee wavefront from src to dst avoiding occupied cells (dst always
    allowed).  Returns path or None."""
    h, w = occ.shape
    prev = -np.ones((h, w, 2), np.int32)
    q = deque([src])
    seen = np.zeros((h, w), bool)
    seen[src] = True
    while q:
        y, x = q.popleft()
        if (y, x) == dst:
            path = [(y, x)]
            while (y, x) != src:
                y, x = prev[y, x]
                path.append((int(y), int(x)))
            return path[::-1]
        for dy, dx in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            ny, nx = y + dy, x + dx
            if 0 <= ny < h and 0 <= nx < w and not seen[ny, nx] and (
                    not occ[ny, nx] or (ny, nx) == dst):
                seen[ny, nx] = True
                prev[ny, nx] = (y, x)
                q.append((ny, nx))
    return None


def route(placement: Placement, nets: list[tuple[str, list[tuple[int, int]]]],
          *, coarse: int = 64, capacity: int = 4) -> RoutingResult:
    """Route multi-pin nets (star topology around the first pin) on a
    coarse grid.  nets: (name, [(x, y) pin coords in F units])."""
    gw = max(2, placement.width // coarse + 2)
    gh = max(2, placement.height // coarse + 3)
    occ_count = np.zeros((gh, gw), np.int16)
    wires: list[Wire] = []
    failed: list[str] = []
    total = 0

    def cell(p):
        x, y = p
        return (min(gh - 1, max(0, int(y) // coarse)),
                min(gw - 1, max(0, int(x) // coarse)))

    # longest (bounding box) first
    def span(pins):
        xs = [p[0] for p in pins]
        ys = [p[1] for p in pins]
        return (max(xs) - min(xs)) + (max(ys) - min(ys))

    for name, pins in sorted(nets, key=lambda n: -span(n[1])):
        if len(pins) < 2:
            continue
        hub = cell(pins[0])
        pts: list[tuple[int, int]] = []
        ok = True
        occ = occ_count >= capacity
        for p in pins[1:]:
            path = _bfs(occ, hub, cell(p))
            if path is None:
                ok = False
                break
            pts.extend(path)
        if ok:
            for y, x in pts:
                occ_count[y, x] += 1
            total += len(pts)
            wires.append(Wire(name, tuple(pts)))
        else:
            failed.append(name)
    return RoutingResult(wires, (gh, gw), coarse, failed, total)
