"""Pallas TPU kernel: bit-serial QR ACIM matmul with in-loop SAR ADC.

Hardware adaptation (paper -> TPU): one macro conversion digitizes the
charge-redistributed average of N = H/L 1b products per column.  On TPU we
map each conversion group to an (bm x N) @ (N x bn) MXU matmul followed by
the ADC transfer function (round/clip — VPU ops) and digital accumulation in
a VMEM f32 scratch accumulator, exactly mirroring the macro's
chunked-analog / exact-digital split:

    HBM  x:(M,K) w:(K,C)  --BlockSpec-->  VMEM tiles (bm, bk), (bk, bn)
    for each of bk/N sub-chunks:  s = x_c @ w_c   (MXU)
                                  acc += adc(s)   (VPU round+clip)
    last k-step: out tile (bm, bn) <- acc

Block shapes are multiples of the 128-lane MXU dims; N itself is a power of
two (64..2048 for real macros), so sub-chunk matmuls stay MXU-aligned.
Capacitor mismatch (Eq. 5, static) enters as a multiplicative weight
perturbation and is folded into `w` by the ops layer — the kernel itself is
deterministic and bit-exact against `ref.acim_matmul_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _adc(s: jax.Array, n: int, b_adc: int) -> jax.Array:
    """B-bit mid-tread SAR quantization of a sum in [-N, N] (dequantized)."""
    delta = 2.0 * n / (2.0 ** b_adc)
    code = jnp.round(s * (1.0 / delta))
    code = jnp.clip(code, -(2.0 ** (b_adc - 1)), 2.0 ** (b_adc - 1) - 1.0)
    return code * delta


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n: int, b_adc: int, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    for c in range(bk // n):
        xs = x[:, c * n:(c + 1) * n]
        ws = w[c * n:(c + 1) * n, :]
        s = jnp.dot(xs, ws, preferred_element_type=jnp.float32)
        acc_ref[...] += _adc(s, n, b_adc)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n", "b_adc", "block_m", "block_n", "block_k", "interpret"))
def acim_matmul_kernel(x: jax.Array, w: jax.Array, *, n: int, b_adc: int,
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 512, interpret: bool = False) -> jax.Array:
    """y[i,j] = sum over K-chunks of ADC(sum_{k in chunk} x[i,k] w[k,j]).

    Preconditions (enforced by ops.acim_matmul, which pads):
      M % block_m == 0, C % block_n == 0, K % block_k == 0, block_k % n == 0.
    """
    m, k = x.shape
    k2, c = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and c % block_n == 0, (m, c, block_m, block_n)
    assert k % block_k == 0 and block_k % n == 0, (k, block_k, n)

    grid = (m // block_m, c // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, n=n, b_adc=b_adc, bk=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, l: (i, l)),
            pl.BlockSpec((block_k, block_n), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), w.astype(jnp.float32))
