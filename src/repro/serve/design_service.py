"""Multi-tenant design service: queue-backed, coalescing front door.

The design-flow counterpart of `repro.serve.engine.ServeEngine`'s slot
model: concurrent users `submit()` `DesignRequest`s and collect
ticketed `DesignArtifact`s, while the service amortizes the heavy work
across tenants.  Each `step()` drains up to `max_coalesce` queued
requests and hands them to `DesignSession.run_many`, which

  * coalesces every request in the same explore group (equal MOGA
    budget / calibration / backend knobs) into ONE `explore_cells`
    dispatch — concurrent tenants share the compiled sweep program and
    a single padded population stack instead of dispatching per user;
  * buckets the union of surviving specs by routing-grid shape before
    `generate_layouts`, so a mixed tenant population (tall-narrow next
    to wide-shallow macros) does not pay padded-batch waste for the
    biggest member (the ROADMAP "bucketing" item);
  * demuxes per-request artifacts whose content is equal to what the
    sequential legacy path (`explore` -> `filter` -> a whole-batch
    `generate_layouts`) produces for each request alone — asserted in
    `tests/test_design_api.py`.

Dispatch accounting lives in `service.stats` (a view of the session's
counter): `explorer_dispatches`, `layout_dispatches`,
`run_cell_traces`, cache hit/miss counts.
"""
from __future__ import annotations

import collections

from repro.api.request import DesignRequest
from repro.api.session import DesignArtifact, DesignSession


class DesignService:
    """Queue-backed multi-tenant layer over a `DesignSession`."""

    def __init__(self, session: DesignSession | None = None, *,
                 max_coalesce: int = 16):
        if max_coalesce <= 0:
            raise ValueError("max_coalesce must be positive")
        self.session = session or DesignSession()
        self.max_coalesce = max_coalesce
        self._queue: list[tuple[int, DesignRequest]] = []
        self._next_ticket = 0
        self.done: dict[int, DesignArtifact] = {}

    @property
    def stats(self) -> collections.Counter:
        return self.session.stats

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, request: DesignRequest) -> int:
        """Enqueue a request; returns the ticket to collect its artifact."""
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, request))
        return ticket

    def step(self) -> dict[int, DesignArtifact]:
        """Drain one coalesced batch (up to `max_coalesce` requests) and
        return its per-ticket artifacts.

        A request whose requirements remove every Pareto point cannot
        poison the batch: it completes with `artifact.error` set (the
        session's non-strict mode) while the other tenants are served.
        On an unexpected exception the batch is restored to the queue
        so no tenant's submission is lost."""
        batch, self._queue = (self._queue[:self.max_coalesce],
                              self._queue[self.max_coalesce:])
        if not batch:
            return {}
        try:
            artifacts = self.session.run_many([r for _, r in batch],
                                              bucket_layouts=True,
                                              strict=False)
        except Exception:
            self._queue = batch + self._queue
            raise
        out = {ticket: artifacts[r] for ticket, r in batch}
        self.done.update(out)
        return out

    def run(self) -> dict[int, DesignArtifact]:
        """Drain the whole queue; returns every completed ticket."""
        while self._queue:
            self.step()
        return self.done

    def collect(self, ticket: int) -> DesignArtifact:
        return self.done[ticket]
