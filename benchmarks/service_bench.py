"""Design-service benchmark: N coalesced requests vs N sequential sessions.

The service-level counterpart of `benchmarks/explorer_bench.py` (which
measures the raw sweep program) and `benchmarks/layout_bench.py` (the
raw layout batch): this measures the multi-tenant front door end to end.
The sequential baseline runs each `DesignRequest` in its own fresh
`DesignSession` (one explorer dispatch per request, one whole-batch
layout per request — the legacy `explore` -> `filter` ->
`generate_layouts` shape); the coalesced side submits all N requests to
one `DesignService`, which folds them into a single explorer dispatch
and lays the union of surviving specs out in routing-grid-shape buckets.

Two views per side:

  * cold — fresh process caches (`jax.clear_caches()` first): what a
    fresh fleet pays, including compilation;
  * warm — the same requests resubmitted to the same service / sessions:
    front-cache hits, steady-state relayout only.

A third scenario measures the **async** front door: N tenant threads
submit with jittered arrivals against a running `serve()` pump
(latency-bounded coalescing windows) and block in
`collect(timeout=...)`.  Recorded per run: the realized coalescing
factor (requests per dispatched batch — > 1 means the window actually
merged concurrent tenants) and the per-ticket p50/p95 latency from
submit to artifact-in-hand.  Artifact content is asserted equal to the
sequential baseline, same as the synchronous drain.

A fourth scenario measures the **staged pipeline** executor against
the serial pump on a deliberately multi-batch workload
(`max_coalesce=1`: every request is its own batch, all submitted up
front).  The serial pump runs each batch start-to-finish before the
next; the pipeline overlaps batch N+1's exploration with batch N's
layout and streams layout buckets.  Recorded: wall-clock, per-ticket
p50/p95, per-stage busy seconds, and the explore/layout **overlap
fraction** (simultaneously-busy wall-clock over the smaller stage's
busy time — > 0 means the pipeline actually overlapped; the serial
pump is structurally 0).  Artifacts are asserted ticket-for-ticket
equal to the sequential baseline on both sides.

A fifth scenario measures the **layout worker pool** on the same
multi-batch workload: K=1 vs K=`POOL_WORKERS` layout workers over the
streamed bucket queue, each fault-free and fault-injected (one `node`
fault on a layout bucket — retried in place — and one `slow` fault —
the straggler path: a pool sheds it to a peer via the watchdog, a
single worker has to sit it out).  Recorded per column: wall, ticket
p50/p95, bucket retries/failures, shed count.  `cpu_count` is recorded
at the top level because worker *threads* only buy wall-clock on a
multi-core host — on a 1-core container the fault-free K speedup is
structurally ~1.0x and should be read as environment, not regression.

A sixth **chaos** scenario drives the full fault-tolerance contract:
a guarded service takes an injected layout-bucket kill plus a simulated
preemption mid-run, drains what was admitted, journals the rest to the
WAL beside the artifact cache; a fresh service over the same cache root
replays the journal.  Every ticket must resolve across the two phases
with artifacts equal to the fault-free sequential baseline.

A seventh **bursty** scenario compares fixed coalescing windows with
the controller-driven adaptive window (`repro.telemetry.control`) on
bursty arrivals: tenants arrive in `BURST_COUNT` bursts separated by a
gap, so a narrow fixed window fragments each burst into per-request
dispatches while a wide one taxes every ticket with held-open latency.
Recorded per column: wall, ticket p50/p95, dispatched batch count, and
artifact equality across columns.  The same scenario measures the
telemetry overhead warn-only (one fixed-window run re-executed with a
recorder attached) and — with `--telemetry-dir` — dumps the adaptive
run's span trace (Chrome-trace JSON + per-batch Gantt) and metrics
snapshot for CI to upload as workflow artifacts.

An eighth **fleet** scenario measures the sharded design fleet end to
end: N worker *processes* (subprocess sessions over
`tests/cache_roundtrip_helper.py`), each with a private L1 artifact
cache and one shared `FileRemoteStore` L2, exploring an island-model
request (`DesignRequest.islands > 1`) on a device mesh forced to 8
host devices (`XLA_FLAGS=--xla_force_host_platform_device_count`).
The cold worker dispatches the ring-migration mesh engine and writes
the shared tier; every warm worker serves the same artifact with zero
explorer dispatches (`served_from="artifact_cache_l2"`, promoted into
its own L1).  Recorded: mesh device count, migration topology/rounds,
per-tier hit/write counters, per-worker wall, and `artifacts_equal`
against a single-process in-process baseline — the island engine is
bit-identical across device counts, so the 8-device fleet front must
equal the 1-device baseline front.

Compile counts come from the `nsga2.TRACE_COUNTS["run_cell"]` probe and
the session dispatch counters.  Per-ticket percentiles use
`repro.telemetry.metrics.percentile` — the same quantile math the
service's latency-histogram summaries report.  Results land in
`BENCH_service.json` at the repo root so future PRs have a perf
trajectory.

  PYTHONPATH=src python -m benchmarks.service_bench [--smoke] [--out PATH]
      [--telemetry-dir DIR]

`--smoke` shrinks the request set and MOGA budget for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import platform
import random
import subprocess
import sys
import tempfile
import threading
import time

import jax

from repro.api import DesignRequest, DesignSession, Requirements
from repro.core import nsga2
from repro.telemetry import (ControllerConfig, Telemetry, atomic_write_json,
                             percentile, write_metrics_json)
from repro.runtime.fault_tolerance import (FailureInjector, PreemptionGuard,
                                           StragglerMonitor)
from repro.serve.design_service import DesignService, PendingTicket

# Async-scenario knobs: arrivals are jittered uniformly inside the
# jitter span, the pump's admit-until-deadline window is the window
# span; jitter well under window so concurrent tenants coalesce.  CI's
# smoke mode widens both — a descheduled tenant thread on a loaded
# runner must not slip past the deadline and flake the
# coalescing_factor assertion.
ASYNC_WINDOW_S, ASYNC_JITTER_S = 0.25, 0.15
ASYNC_WINDOW_SMOKE_S, ASYNC_JITTER_SMOKE_S = 1.5, 0.3

# Layout-pool scenario knobs: pool width, the shed bar (threshold x EMA
# of a bucket's wall time), and the injected slow fault's sleep — long
# enough to clear the bar once an EMA exists, short enough not to
# dominate the single-worker column's wall.
POOL_WORKERS = 4
POOL_SHED_THRESHOLD = 4.0   # loose: CPU contention on few-core hosts
#   stretches healthy concurrent buckets too; sheds of those are benign
#   (first completion wins, duplicates cancel at pickup) but a
#   hair-trigger bar would shed every bucket on a 1-core runner
POOL_SLOW_S, POOL_SLOW_SMOKE_S = 30.0, 6.0   # must clear threshold x EMA
#   by a margin: full-mode buckets run seconds each

# Bursty-scenario knobs: BURST_COUNT bursts, BURST_GAP_S apart, tenants
# inside a burst jittered within BURST_JITTER_S.  The fixed columns
# bracket the design space — a narrow window (fragments bursts) vs a
# wide one (holds every ticket open); the adaptive column starts at the
# narrow window and lets the controller ease it from the arrival-rate
# EMA.  Burst size is the controller's target batch.
BURST_COUNT, BURST_GAP_S, BURST_JITTER_S = 3, 1.5, 0.1
BURSTY_NARROW_S, BURSTY_WIDE_S = 0.02, 1.0
BURSTY_SEEDS = 6

# Fleet-scenario knobs: worker process count, islands per request, and
# the forced host device count the workers' meshes see.  The island
# engine uses the largest divisor of `islands` that fits the mesh, so
# FLEET_ISLANDS devices carry the islands on the 8-device workers while
# the in-process baseline runs the identical request on 1 device.
FLEET_WORKERS = 2
FLEET_ISLANDS = 4
FLEET_DEVICES = 8

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

REQUIREMENTS = Requirements(min_tops=0.5, min_snr_db=10.0)
REQUIREMENTS_FULL = Requirements(min_tops=0.5, min_snr_db=15.0)


def _requests(smoke: bool) -> list[DesignRequest]:
    sizes, seeds = ((4096,), (0, 1)) if smoke else \
        ((4096, 8192), (0, 1, 2))
    pop, gens = (48, 8) if smoke else (192, 60)
    reqs = REQUIREMENTS if smoke else REQUIREMENTS_FULL
    return [DesignRequest(array_size=s, seed=sd, pop_size=pop,
                          generations=gens, requirements=reqs, layout=True)
            for s in sizes for sd in seeds]


def _sequential(requests, sessions=None):
    """One fresh session per request: the pre-coalescing baseline."""
    sessions = sessions or [DesignSession() for _ in requests]
    arts = [ses.run(req) for ses, req in zip(sessions, requests)]
    return arts, sessions


def _coalesced(requests, service=None):
    service = service or DesignService(max_coalesce=len(requests))
    tickets = [service.submit(r) for r in requests]
    done = service.run()
    return [done[t] for t in tickets], service


def _async_serve(requests, *, window_s: float, jitter_s: float,
                 timeout_s: float = 600.0):
    """N tenant threads, jittered arrivals, one serve() pump."""
    offsets = [random.Random(i).uniform(0.0, jitter_s)
               for i in range(len(requests))]
    service = DesignService(max_coalesce=len(requests),
                            coalesce_window_s=window_s)
    artifacts = [None] * len(requests)
    latencies = [0.0] * len(requests)
    errors: list[Exception] = []
    gate = threading.Barrier(len(requests) + 1)

    def tenant(i: int, req: DesignRequest) -> None:
        try:
            gate.wait()
            time.sleep(offsets[i])
            t0 = time.perf_counter()
            ticket = service.submit(req)
            artifacts[i] = service.collect(ticket, timeout=timeout_s)
            latencies[i] = time.perf_counter() - t0
        except Exception as e:   # surfaced to the caller below
            errors.append(e)

    threads = [threading.Thread(target=tenant, args=(i, r))
               for i, r in enumerate(requests)]
    for t in threads:
        t.start()
    with service.serve():
        t0 = time.perf_counter()
        gate.wait()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return artifacts, service, wall, latencies


def _burst_requests(smoke: bool) -> list[DesignRequest]:
    pop, gens = (48, 8) if smoke else (96, 24)
    return [DesignRequest(array_size=4096, seed=sd, pop_size=pop,
                          generations=gens, requirements=REQUIREMENTS,
                          layout=True)
            for sd in range(BURSTY_SEEDS)]


def _bursty_serve(requests, *, window_s: float, controller=None,
                  telemetry=None, timeout_s: float = 600.0):
    """Tenant threads arriving in bursts against one serve() pump.
    Burst k's tenants arrive at ~`k * BURST_GAP_S`; `max_coalesce` is
    the burst size, so a perfectly-adapted window coalesces each burst
    into exactly one dispatch without holding it open into the gap."""
    per_burst = (len(requests) + BURST_COUNT - 1) // BURST_COUNT
    offsets = [(i // per_burst) * BURST_GAP_S
               + random.Random(1000 + i).uniform(0.0, BURST_JITTER_S)
               for i in range(len(requests))]
    service = DesignService(max_coalesce=per_burst,
                            coalesce_window_s=window_s,
                            telemetry=telemetry, controller=controller)
    artifacts = [None] * len(requests)
    latencies = [0.0] * len(requests)
    errors: list[Exception] = []
    gate = threading.Barrier(len(requests) + 1)

    def tenant(i: int, req: DesignRequest) -> None:
        try:
            gate.wait()
            time.sleep(offsets[i])
            t0 = time.perf_counter()
            ticket = service.submit(req)
            artifacts[i] = service.collect(ticket, timeout=timeout_s)
            latencies[i] = time.perf_counter() - t0
        except Exception as e:   # surfaced to the caller below
            errors.append(e)

    threads = [threading.Thread(target=tenant, args=(i, r))
               for i, r in enumerate(requests)]
    for t in threads:
        t.start()
    with service.serve():
        t0 = time.perf_counter()
        gate.wait()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return artifacts, service, wall, latencies


def _bursty_column(arts, service, wall, lat, ref) -> dict:
    stats = service.stats()
    return {
        "wall_s": wall,
        "ticket_p50_s": float(percentile(lat, 50)),
        "ticket_p95_s": float(percentile(lat, 95)),
        "batches": int(stats["service_batches"]),
        "explorer_dispatches": int(stats["explorer_dispatches"]),
        "artifacts_equal": (True if ref is None else
                            all(a.summary() == b.summary()
                                for a, b in zip(ref, arts))),
    }


def _bursty(smoke: bool, telemetry_dir=None) -> dict:
    """Adaptive-vs-fixed coalescing on bursty arrivals (plus the
    telemetry-overhead measurement and the CI trace/metrics dump)."""
    requests = _burst_requests(smoke)
    per_burst = (len(requests) + BURST_COUNT - 1) // BURST_COUNT
    # warm the shapes once so no column pays compilation alone
    _bursty_serve(requests, window_s=BURSTY_WIDE_S)

    # -- fixed columns (narrow doubles as the artifact reference) ------
    ref, narrow_svc, narrow_wall, narrow_lat = _bursty_serve(
        requests, window_s=BURSTY_NARROW_S)
    wide_arts, wide_svc, wide_wall, wide_lat = _bursty_serve(
        requests, window_s=BURSTY_WIDE_S)
    # -- telemetry overhead: same wide config, recorder attached -------
    _, _, tel_wall, _ = _bursty_serve(
        requests, window_s=BURSTY_WIDE_S, telemetry=Telemetry())
    # -- adaptive column -----------------------------------------------
    atel = Telemetry()
    cfg = ControllerConfig(min_window_s=BURSTY_NARROW_S,
                           max_window_s=BURSTY_WIDE_S,
                           target_batch=per_burst,
                           min_workers=1, max_workers=1,
                           hysteresis_ticks=3, tick_interval_s=0.05)
    ada_arts, ada_svc, ada_wall, ada_lat = _bursty_serve(
        requests, window_s=BURSTY_NARROW_S, controller=cfg, telemetry=atel)

    if telemetry_dir is not None:
        d = pathlib.Path(telemetry_dir)
        d.mkdir(parents=True, exist_ok=True)
        trace = ada_svc.trace()
        trace.to_json(d / "service_trace.json")
        atomic_write_json(trace.gantt(), d / "service_gantt.json")
        write_metrics_json(ada_svc.metrics(), d / "service_metrics.json")

    return {
        "n_requests": len(requests),
        "bursts": BURST_COUNT,
        "burst_gap_s": BURST_GAP_S,
        "fixed_narrow": dict(
            _bursty_column(ref, narrow_svc, narrow_wall, narrow_lat,
                           None) | {"window_s": BURSTY_NARROW_S}),
        "fixed_wide": dict(
            _bursty_column(wide_arts, wide_svc, wide_wall, wide_lat, ref)
            | {"window_s": BURSTY_WIDE_S}),
        "adaptive": dict(
            _bursty_column(ada_arts, ada_svc, ada_wall, ada_lat, ref)
            | {"window_start_s": BURSTY_NARROW_S,
               "window_final_s": float(ada_svc.coalesce_window_s),
               "control_decisions": len(ada_svc.controller.decisions),
               "window_updates":
                   int(ada_svc.stats()["control_window_updates"])}),
        # warn-only: wall-clock cost of an attached recorder on the
        # identical fixed-wide run (noisy on loaded hosts — a regression
        # signal, not a gate)
        "telemetry_overhead_frac":
            float((tel_wall - wide_wall) / wide_wall),
        "telemetry_spans": len(atel.recorder),
    }


def _staged(requests, *, pipelined: bool, workers: int = 1,
            injector=None, straggler=None, timeout_s: float = 600.0):
    """The multi-batch pipeline workload: every request is its own batch
    (`max_coalesce=1`), all submitted up front.  Under the staged
    executor, batch N+1's exploration overlaps batch N's layout; under
    the serial pump each batch runs start-to-finish before the next.
    `workers`/`injector`/`straggler` parameterize the layout-pool and
    fault-injected columns."""
    service = DesignService(max_coalesce=1, layout_workers=workers,
                            injector=injector, straggler=straggler)
    with service.serve(pipelined=pipelined):
        t0 = time.perf_counter()
        tickets = [service.submit(r) for r in requests]
        artifacts, latencies = [], []
        for t in tickets:   # finalize is FIFO: completion order == order
            artifacts.append(service.collect(t, timeout=timeout_s))
            latencies.append(time.perf_counter() - t0)
        wall = time.perf_counter() - t0
        stats = service.stats()
    return artifacts, stats, wall, latencies


def _pool_injector(smoke: bool) -> FailureInjector:
    # one node fault on the second layout bucket dispatch (retried in
    # place) and one slow fault on the fourth (shed to a peer when the
    # pool is wider than one); indices that never dispatch simply don't
    # fire, so the schedule is safe for any bucket count
    return FailureInjector(
        slow_seconds=POOL_SLOW_SMOKE_S if smoke else POOL_SLOW_S,
        fail_at={"layout": [1, (3, "slow")]})


def _pool_column(arts, stats, wall, lat, seq) -> dict:
    return {
        "wall_s": wall,
        "ticket_p50_s": float(percentile(lat, 50)),
        "ticket_p95_s": float(percentile(lat, 95)),
        "layout_dispatches": int(stats["layout_dispatches"]),
        "bucket_retries": int(stats["bucket_retries"]),
        "bucket_failures": int(stats["bucket_failures"]),
        "shed_buckets": int(stats["shed_buckets"]),
        "shed_losses": int(stats["shed_losses"]),
        "artifacts_equal": all(a.summary() == b.summary()
                               for a, b in zip(seq, arts)),
    }


def _chaos(requests, baseline, *, timeout_s: float = 900.0) -> dict:
    """Kill one layout bucket and preempt the service mid-run, then
    restart.  Phase 1: a guarded service with an injected node fault on
    the first layout bucket and a preemption request at the second
    admission — it drains the already-admitted batches and journals
    every unfinished ticket to the WAL beside the artifact cache.
    Phase 2: a fresh service over the same cache root (the "restarted
    process") replays the journal; drained work is served from disk.
    Every ticket must resolve across the two phases with artifacts
    equal to the fault-free sequential baseline."""
    cache_dir = tempfile.mkdtemp(prefix="acim-chaos-cache-")
    guard = PreemptionGuard()
    injector = FailureInjector(
        guard=guard, fail_at={"layout": [0], "admit": [(1, "preempt")]})
    svc1 = DesignService(DesignSession(artifact_cache=cache_dir),
                         max_coalesce=1, layout_workers=2,
                         guard=guard, injector=injector)
    drained = {}
    t0 = time.perf_counter()
    with svc1.serve():
        tickets = [svc1.submit(r) for r in requests]
        for r, t in zip(requests, tickets):
            try:
                drained[r] = svc1.collect(t, timeout=timeout_s)
            except PendingTicket:
                pass            # journaled: the replaying service owns it
    drain_wall = time.perf_counter() - t0
    s1 = svc1.stats()

    svc2 = DesignService(DesignSession(artifact_cache=cache_dir),
                         max_coalesce=1, layout_workers=2)
    pending = svc2.journal.replay()    # peek; replay() does not clear
    replayed = {}
    t0 = time.perf_counter()
    tickets2 = svc2.replay_journal()
    with svc2.serve():
        for r, t in zip(pending, tickets2):
            replayed[r] = svc2.collect(t, timeout=timeout_s)
    replay_wall = time.perf_counter() - t0
    s2 = svc2.stats()

    # in-flight tickets are journaled too (at-least-once WAL), so a
    # request can resolve in both phases; the drained copy is canonical
    arts = {**replayed, **drained}
    resolved = [arts.get(r) for r in requests]
    return {
        "n_requests": len(requests),
        "drain_wall_s": drain_wall,
        "replay_wall_s": replay_wall,
        "n_drained": len(drained),
        "n_journaled": int(s1["journaled_tickets"]),
        "n_replayed": len(tickets2),
        "preemptions": int(s1["preemptions"]),
        "bucket_retries": int(s1["bucket_retries"]),
        # drained work that reached the cache before the "old process
        # died" is served from disk on replay — convergence, not recompute
        "replay_artifact_cache_hits": int(s2["artifact_cache_hits"]),
        "replay_explorer_dispatches": int(s2["explorer_dispatches"]),
        "all_resolved": all(a is not None and a.ok for a in resolved),
        "artifacts_equal": all(a is not None and a.summary() == b.summary()
                               for a, b in zip(resolved, baseline)),
    }


def _fleet(smoke: bool) -> dict:
    """Sharded-fleet scenario: FLEET_WORKERS subprocess sessions, each a
    private L1 over one shared L2, exploring an island request on a
    mesh of FLEET_DEVICES forced host devices.  Worker 0 runs cold
    (mesh explorer dispatch + L2 write); the rest are warm fleet
    members (zero dispatches, served from the shared tier).  The
    in-process baseline runs the identical request single-process —
    the island engine is device-count independent, so every front must
    be equal."""
    pop, gens = (48, 8) if smoke else (96, 40)
    req = DesignRequest(array_size=4096, seed=0, pop_size=pop,
                        generations=gens, requirements=REQUIREMENTS,
                        layout=True, islands=FLEET_ISLANDS, migrate_every=5)
    t0 = time.perf_counter()
    baseline = DesignSession().run(req)
    base_wall = time.perf_counter() - t0
    base_summary = json.loads(json.dumps(baseline.summary()))

    tmp = pathlib.Path(tempfile.mkdtemp(prefix="acim-fleet-"))
    remote = f"file://{tmp / 'shared-l2'}"
    reports, walls = [], []
    for w in range(FLEET_WORKERS):
        t0 = time.perf_counter()
        r = subprocess.run(
            [sys.executable,
             str(REPO_ROOT / "tests" / "cache_roundtrip_helper.py"),
             str(tmp / f"worker{w}-l1"), req.to_json(), "--remote", remote],
            capture_output=True, text=True, timeout=900,
            env={**os.environ, "PYTHONPATH": str(REPO_ROOT / "src"),
                 "JAX_PLATFORMS": "cpu",
                 "XLA_FLAGS":
                     f"--xla_force_host_platform_device_count={FLEET_DEVICES}"})
        walls.append(time.perf_counter() - t0)
        if r.returncode != 0:
            raise RuntimeError(f"fleet worker {w} failed: {r.stderr[-3000:]}")
        reports.append(json.loads(r.stdout))

    cold, warm = reports[0], reports[1:]
    tiers = {k: sum(rep["tier_stats"][f"artifact_cache_{k}"]
                    for rep in reports)
             for k in ("l1_hits", "l2_hits", "promotions", "l2_writes")}
    return {
        "n_workers": FLEET_WORKERS,
        "islands": FLEET_ISLANDS,
        "migrate_every": req.migrate_every,
        "forced_host_devices": cold["mesh"]["n_devices"],
        "mesh_devices": cold["mesh"]["mesh_devices"],
        "migration_topology": cold["mesh"]["migration_topology"],
        "migration_rounds": cold["mesh"]["migration_rounds"],
        "baseline_wall_s": base_wall,
        "baseline_mesh_devices": baseline.provenance.mesh_devices,
        "worker_wall_s": walls,
        "cold_worker": {
            "served_from": cold["served_from"],
            "explorer_dispatches": cold["explorer_dispatches"],
            "l2_writes": cold["tier_stats"]["artifact_cache_l2_writes"]},
        "warm_workers": [{
            "served_from": rep["served_from"],
            "explorer_dispatches": rep["explorer_dispatches"],
            "layout_dispatches": rep["layout_dispatches"],
            "l2_hits": rep["tier_stats"]["artifact_cache_l2_hits"],
            "promotions": rep["tier_stats"]["artifact_cache_promotions"]}
            for rep in warm],
        "tier_hits": tiers,
        "artifacts_equal": all(rep["summary"] == base_summary
                               for rep in reports),
    }


def _timed(fn, *args):
    n0 = nsga2.TRACE_COUNTS["run_cell"]
    t0 = time.perf_counter()
    out, state = fn(*args)
    return out, state, time.perf_counter() - t0, \
        nsga2.TRACE_COUNTS["run_cell"] - n0


def run(smoke: bool = False, telemetry_dir=None) -> dict:
    requests = _requests(smoke)

    jax.clear_caches()
    seq, sessions, seq_cold, seq_traces = _timed(_sequential, requests)
    _, _, seq_warm, _ = _timed(_sequential, requests, sessions)
    seq_dispatches = sum(s.stats["explorer_dispatches"] for s in sessions)

    jax.clear_caches()
    bat, service, bat_cold, bat_traces = _timed(_coalesced, requests)
    _, _, bat_warm, _ = _timed(_coalesced, requests, service)

    artifacts_equal = all(a.summary() == b.summary()
                          for a, b in zip(seq, bat))

    window_s = ASYNC_WINDOW_SMOKE_S if smoke else ASYNC_WINDOW_S
    jitter_s = ASYNC_JITTER_SMOKE_S if smoke else ASYNC_JITTER_S
    asy, asvc, asy_wall, asy_lat = _async_serve(requests, window_s=window_s,
                                                jitter_s=jitter_s)
    astats = asvc.stats()
    async_equal = all(a.summary() == b.summary() for a, b in zip(seq, asy))
    batches = int(astats["service_batches"])

    # warm the per-request layout programs first: the multi-batch workload
    # compiles different batch shapes than the coalesced scenarios, and
    # whichever side ran first would otherwise pay them alone
    _staged(requests, pipelined=False)
    srl, srl_stats, srl_wall, srl_lat = _staged(requests, pipelined=False)
    pipe, pipe_stats, pipe_wall, pipe_lat = _staged(requests, pipelined=True)
    busy = pipe_stats["stage_busy_s"]

    # layout-pool scenario: K=1 fault-free is the pipelined run above
    p4, p4_stats, p4_wall, p4_lat = _staged(
        requests, pipelined=True, workers=POOL_WORKERS)
    f1, f1_stats, f1_wall, f1_lat = _staged(
        requests, pipelined=True, workers=1, injector=_pool_injector(smoke),
        straggler=StragglerMonitor(threshold=POOL_SHED_THRESHOLD))
    f4, f4_stats, f4_wall, f4_lat = _staged(
        requests, pipelined=True, workers=POOL_WORKERS,
        injector=_pool_injector(smoke),
        straggler=StragglerMonitor(threshold=POOL_SHED_THRESHOLD))

    chaos = _chaos(requests, seq)
    bursty = _bursty(smoke, telemetry_dir=telemetry_dir)
    fleet = _fleet(smoke)
    return {
        "n_requests": len(requests),
        "requests": [r.to_dict() for r in requests],
        "smoke": smoke,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
        "sequential": {"cold_s": seq_cold, "warm_s": seq_warm,
                       "run_cell_traces": seq_traces,
                       "explorer_dispatches": seq_dispatches},
        "coalesced": {"cold_s": bat_cold, "warm_s": bat_warm,
                      "run_cell_traces": bat_traces,
                      "explorer_dispatches":
                          int(service.stats()["explorer_dispatches"]),
                      "layout_bucket_dispatches":
                          int(service.stats()["layout_dispatches"])},
        "coalesced_speedup_cold": seq_cold / bat_cold,
        "coalesced_speedup_warm": seq_warm / bat_warm,
        "artifacts_equal": artifacts_equal,
        "async": {
            "window_s": window_s,
            "jitter_s": jitter_s,
            "wall_s": asy_wall,
            "ticket_p50_s": float(percentile(asy_lat, 50)),
            "ticket_p95_s": float(percentile(asy_lat, 95)),
            "batches": batches,
            "coalescing_factor":
                int(astats["service_batch_requests"]) / max(batches, 1),
            "explorer_dispatches": int(astats["explorer_dispatches"]),
            "artifacts_equal": async_equal,
        },
        "pipelined": {
            "batches": int(pipe_stats["service_batches"]),
            "wall_s": pipe_wall,
            "ticket_p50_s": float(percentile(pipe_lat, 50)),
            "ticket_p95_s": float(percentile(pipe_lat, 95)),
            "stage_busy_s": {k: float(v) for k, v in busy.items()},
            "overlap_s": float(pipe_stats["pipeline_overlap_s"]),
            "overlap_fraction":
                float(pipe_stats["pipeline_overlap_fraction"]),
            "artifacts_equal": all(a.summary() == b.summary()
                                   for a, b in zip(seq, pipe)),
            "serial": {
                "batches": int(srl_stats["service_batches"]),
                "wall_s": srl_wall,
                "ticket_p50_s": float(percentile(srl_lat, 50)),
                "ticket_p95_s": float(percentile(srl_lat, 95)),
                "artifacts_equal": all(a.summary() == b.summary()
                                       for a, b in zip(seq, srl)),
            },
            "wall_speedup_vs_serial": srl_wall / pipe_wall,
            "p50_ratio_vs_serial":
                float(percentile(pipe_lat, 50)
                      / percentile(srl_lat, 50)),
            "p95_ratio_vs_serial":
                float(percentile(pipe_lat, 95)
                      / percentile(srl_lat, 95)),
        },
        "layout_pool": {
            "workers": POOL_WORKERS,
            "shed_threshold": POOL_SHED_THRESHOLD,
            "slow_fault_s": POOL_SLOW_SMOKE_S if smoke else POOL_SLOW_S,
            "fault_free": {
                "k1": _pool_column(pipe, pipe_stats, pipe_wall,
                                   pipe_lat, seq),
                "k4": _pool_column(p4, p4_stats, p4_wall, p4_lat, seq),
            },
            "fault_injected": {
                "k1": _pool_column(f1, f1_stats, f1_wall, f1_lat, seq),
                "k4": _pool_column(f4, f4_stats, f4_wall, f4_lat, seq),
            },
            # thread-pool parallelism needs cores: read these against
            # the top-level cpu_count (1-core hosts pin fault-free ~1.0x)
            "wall_speedup_k4_vs_k1": pipe_wall / p4_wall,
            "faulty_wall_speedup_k4_vs_k1": f1_wall / f4_wall,
        },
        "chaos": chaos,
        "bursty": bursty,
        "fleet": fleet,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small request set / MOGA budget for CI")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_service.json"))
    ap.add_argument("--telemetry-dir", default=None,
                    help="dump the adaptive run's span trace, Gantt, and "
                         "metrics snapshot here (CI uploads these)")
    args = ap.parse_args()
    result = run(smoke=args.smoke, telemetry_dir=args.telemetry_dir)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    for side in ("sequential", "coalesced"):
        r = result[side]
        print(f"{side}: cold={r['cold_s']:.3f}s warm={r['warm_s']:.3f}s "
              f"traces={r['run_cell_traces']} "
              f"dispatches={r['explorer_dispatches']}")
    a = result["async"]
    print(f"async: wall={a['wall_s']:.3f}s p50={a['ticket_p50_s']:.3f}s "
          f"p95={a['ticket_p95_s']:.3f}s batches={a['batches']} "
          f"coalescing_factor={a['coalescing_factor']:.2f} "
          f"artifacts_equal={a['artifacts_equal']}")
    p = result["pipelined"]
    print(f"pipelined: wall={p['wall_s']:.3f}s (serial pump "
          f"{p['serial']['wall_s']:.3f}s, {p['wall_speedup_vs_serial']:.2f}x) "
          f"p50={p['ticket_p50_s']:.3f}s p95={p['ticket_p95_s']:.3f}s "
          f"(serial p50={p['serial']['ticket_p50_s']:.3f}s "
          f"p95={p['serial']['ticket_p95_s']:.3f}s) "
          f"overlap_fraction={p['overlap_fraction']:.2f} "
          f"artifacts_equal={p['artifacts_equal']}")
    lp = result["layout_pool"]
    ff, fi = lp["fault_free"], lp["fault_injected"]
    print(f"layout pool (K={lp['workers']}, cpu_count="
          f"{result['cpu_count']}): fault-free wall "
          f"K1={ff['k1']['wall_s']:.3f}s K4={ff['k4']['wall_s']:.3f}s "
          f"({lp['wall_speedup_k4_vs_k1']:.2f}x); fault-injected wall "
          f"K1={fi['k1']['wall_s']:.3f}s K4={fi['k4']['wall_s']:.3f}s "
          f"({lp['faulty_wall_speedup_k4_vs_k1']:.2f}x) "
          f"retries={fi['k4']['bucket_retries']} "
          f"shed={fi['k4']['shed_buckets']}")
    # artifact equality is load-bearing on every host; the K-speedup is
    # only meaningful with >= K cores (thread-pool parallelism)
    for side in ("fault_free", "fault_injected"):
        for k in ("k1", "k4"):
            assert lp[side][k]["artifacts_equal"], (side, k)
    cores = result["cpu_count"] or 1
    if cores < lp["workers"]:
        print(f"CAVEAT: cpu_count=={cores} < K={lp['workers']} — layout-pool "
              f"wall speedups are structurally ~1.0x on this host; "
              f"skipping the K-speedup assertion")
    else:
        assert lp["wall_speedup_k4_vs_k1"] > 1.0, lp
    b = result["bursty"]
    print(f"bursty: narrow p95={b['fixed_narrow']['ticket_p95_s']:.3f}s "
          f"({b['fixed_narrow']['batches']} batches) wide "
          f"p95={b['fixed_wide']['ticket_p95_s']:.3f}s "
          f"({b['fixed_wide']['batches']} batches) adaptive "
          f"p95={b['adaptive']['ticket_p95_s']:.3f}s "
          f"({b['adaptive']['batches']} batches, window "
          f"{b['adaptive']['window_start_s']:.3f}->"
          f"{b['adaptive']['window_final_s']:.3f}s) "
          f"overhead={b['telemetry_overhead_frac']:+.1%} "
          f"artifacts_equal={b['adaptive']['artifacts_equal']}")
    fl = result["fleet"]
    print(f"fleet: {fl['n_workers']} workers x {fl['islands']} islands on "
          f"{fl['mesh_devices']}/{fl['forced_host_devices']} devices "
          f"({fl['migration_topology']}, {fl['migration_rounds']} rounds): "
          f"cold={fl['worker_wall_s'][0]:.3f}s "
          f"({fl['cold_worker']['served_from']}) warm="
          f"{[f'{w:.3f}s' for w in fl['worker_wall_s'][1:]]} "
          f"(served {[w['served_from'] for w in fl['warm_workers']]}) "
          f"tier_hits={fl['tier_hits']} "
          f"artifacts_equal={fl['artifacts_equal']}")
    c = result["chaos"]
    print(f"chaos: drained {c['n_drained']}/{c['n_requests']} then "
          f"journaled {c['n_journaled']}, replayed {c['n_replayed']} "
          f"(cache hits {c['replay_artifact_cache_hits']}) "
          f"retries={c['bucket_retries']} "
          f"all_resolved={c['all_resolved']} "
          f"artifacts_equal={c['artifacts_equal']}")
    print(f"speedup cold={result['coalesced_speedup_cold']:.2f}x "
          f"warm={result['coalesced_speedup_warm']:.2f}x "
          f"artifacts_equal={result['artifacts_equal']} -> {args.out}")


if __name__ == "__main__":
    main()
