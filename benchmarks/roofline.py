"""Roofline aggregation: reads runs/dryrun/*.json into the EXPERIMENTS.md
tables (per arch x shape x mesh: three terms, dominant bottleneck,
MODEL_FLOPS ratio, fit)."""
from __future__ import annotations

import json
import pathlib

RUNS = pathlib.Path(__file__).resolve().parents[1] / "runs" / "dryrun"


def rows(mesh: str | None = "pod16x16", variant: str = "") -> list[dict]:
    out = []
    for f in sorted(RUNS.glob("*.json")):
        is_perf = "__perf" in f.name
        if bool(variant) != is_perf:
            continue
        r = json.loads(f.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        out.append(r)
    return out


def table(mesh: str = "pod16x16", variant: str = "") -> str:
    lines = ["arch,shape,status,compute_s,memory_s,collective_s,dominant,"
             "bytes_per_dev_GB,fits_16gb,useful_ratio,roofline_frac,"
             "model_gflops"]
    for r in rows(mesh, variant):
        if r["status"] != "ok":
            lines.append(f"{r['arch']},{r['shape']},{r['status']},,,,,,,,")
            continue
        ro, m = r["roofline"], r["memory"]
        lines.append(
            f"{r['arch']},{r['shape']},ok,"
            f"{ro['compute_s']:.3e},{ro['memory_s']:.3e},"
            f"{ro['collective_s']:.3e},{ro['dominant'].replace('_s','')},"
            f"{m['total_bytes']/1e9:.2f},{m['fits_16gb']},"
            f"{ro['useful_flops_ratio']:.3f},{ro['roofline_fraction']:.3f},"
            f"{ro['model_flops_global']/1e9:.0f}")
    return "\n".join(lines)


def main() -> None:
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"== mesh {mesh} ==")
        print(table(mesh))
    perf = table("pod16x16", variant="perf").splitlines()
    if len(perf) > 1:
        print("== §Perf hillclimb variants (pod16x16) ==")
        print("\n".join(perf))


if __name__ == "__main__":
    main()
