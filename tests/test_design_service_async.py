"""Async `DesignService` (thread-pumped serve() loop, deadline
coalescing, ticket lifecycle, failure/restore) and the persistent
`ArtifactCache` (atomic writes, schema stamp, cross-process round
trip)."""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import threading
import time

import pytest

from repro.api import (ArtifactCache, DesignArtifact, DesignRequest,
                       DesignSession, Requirements)
from repro.api.session import ARTIFACT_SCHEMA, _grid_sig
from repro.serve.design_service import (DesignService, PendingTicket,
                                        UnknownTicket)

REPO = pathlib.Path(__file__).resolve().parents[1]

# threaded serve()-loop tests deadlock rather than fail when broken;
# bound each test (pytest-timeout in CI, the conftest watchdog otherwise)
pytestmark = pytest.mark.timeout(900)

# Same small budget as tests/test_design_api.py: the compiled sweep and
# layout programs are shared process-wide, so these tests ride its jit
# cache (and vice versa) instead of paying a fresh compile each.
POP, GENS = 48, 10
REQS = Requirements(min_tops=0.5, min_snr_db=10.0)


def _request(array_size=4096, seed=0, **kw):
    kw.setdefault("pop_size", POP)
    kw.setdefault("generations", GENS)
    return DesignRequest(array_size=array_size, seed=seed, **kw)


@pytest.fixture(scope="module")
def laid_artifact():
    """One real, laid-out artifact (built once per module)."""
    art = DesignSession().run(_request(requirements=REQS, layout=True))
    assert art.ok and art.layout_rows
    return art


# -- async serve loop ----------------------------------------------------

class TestServeLoop:
    def test_async_artifacts_equal_sync_drain(self):
        reqs = [_request(seed=sd, requirements=REQS, layout=True)
                for sd in (0, 1)]
        sync = DesignService()
        tickets = [sync.submit(r) for r in reqs]
        done = sync.run()
        sync_arts = {r: done[t] for r, t in zip(reqs, tickets)}

        svc = DesignService(coalesce_window_s=0.25)
        with svc.serve():
            tickets = [svc.submit(r) for r in reqs]
            arts = [svc.collect(t, timeout=600) for t in tickets]
        for r, a in zip(reqs, arts):
            assert a.summary() == sync_arts[r].summary()
        # the window actually merged the concurrent submissions
        stats = svc.stats()
        assert stats["service_batches"] == 1
        assert stats["service_batch_requests"] == 2
        assert arts[0].provenance.coalesced == 2

    def test_window_deadline_dispatches_partial_batch(self):
        # max_coalesce is far above the submission count, so only the
        # deadline of the oldest queued request can trigger the dispatch
        svc = DesignService(max_coalesce=64, coalesce_window_s=0.2)
        with svc.serve():
            t = svc.submit(_request(layout=False))
            art = svc.collect(t, timeout=600)
        assert art.ok
        assert svc.stats()["service_batches"] == 1

    def test_full_batch_dispatches_before_window(self):
        # window is huge; hitting max_coalesce must dispatch immediately
        svc = DesignService(max_coalesce=2, coalesce_window_s=3600.0)
        with svc.serve():
            tickets = [svc.submit(_request(seed=sd, layout=False))
                       for sd in (0, 1)]
            t0 = time.monotonic()
            arts = [svc.collect(t, timeout=600) for t in tickets]
            assert time.monotonic() - t0 < 600
        assert all(a.ok for a in arts)
        assert svc.stats()["service_batches"] == 1

    def test_concurrent_submit_during_active_pump(self):
        svc = DesignService(max_coalesce=8, coalesce_window_s=0.1)
        seeds = list(range(6))
        results: dict[int, DesignArtifact] = {}
        errors: list[Exception] = []

        def tenant(sd):
            try:
                t = svc.submit(_request(seed=sd, layout=False))
                results[sd] = svc.collect(t, timeout=600)
            except Exception as e:
                errors.append(e)

        with svc.serve():
            threads = [threading.Thread(target=tenant, args=(sd,))
                       for sd in seeds]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        assert not errors
        assert sorted(results) == seeds
        assert all(results[sd].ok for sd in seeds)
        # every tenant's artifact demuxed to its own request
        assert {results[sd].request.seed for sd in seeds} == set(seeds)
        assert len(svc) == 0 and not svc.done   # collected == popped

    def test_serve_idempotent_and_close_reusable(self):
        svc = DesignService(coalesce_window_s=0.05)
        assert svc.serve() is svc.serve()
        svc.close()
        svc.close()   # idempotent
        # service still usable synchronously after close
        t = svc.submit(_request(layout=False))
        assert svc.run()[t].ok
        # and serve() can be restarted
        with svc.serve():
            t2 = svc.submit(_request(seed=1, layout=False))
            assert svc.collect(t2, timeout=600).ok

    def test_run_and_step_refused_while_pump_active(self):
        # only one dispatcher may drive the (non-thread-safe) session
        svc = DesignService()
        with svc.serve():
            with pytest.raises(RuntimeError, match="serve\\(\\) pump"):
                svc.run()
            with pytest.raises(RuntimeError, match="serve\\(\\) pump"):
                svc.step()

    def test_submit_and_serve_refused_while_closing(self):
        svc = DesignService()
        svc._closing = True   # simulate the mid-close window
        with pytest.raises(RuntimeError, match="closing"):
            svc.submit(_request(layout=False))
        with pytest.raises(RuntimeError, match="close\\(\\) is in progress"):
            svc.serve()


# -- failure / restore ---------------------------------------------------

class TestFailureRestore:
    def test_step_restores_batch_in_order(self, monkeypatch):
        svc = DesignService(max_coalesce=2)
        tickets = [svc.submit(_request(seed=sd, layout=False))
                   for sd in range(3)]

        def boom(*a, **kw):
            raise RuntimeError("injected dispatch failure")

        monkeypatch.setattr(svc.session, "run_many", boom)
        with pytest.raises(RuntimeError, match="injected"):
            svc.step()
        # nothing lost, nothing reordered, nothing marked done
        assert [t for t, _, _ in svc._queue] == tickets
        assert svc.poll(tickets[0]) is None
        monkeypatch.undo()
        done = svc.run()
        assert [done[t].request.seed for t in tickets] == [0, 1, 2]

    def test_pump_failure_surfaces_and_tickets_survive(self, monkeypatch):
        svc = DesignService(coalesce_window_s=0.02)
        real_explore = svc.session.explore_stage

        def boom(*a, **kw):
            raise RuntimeError("injected pump failure")

        # the SERIAL pump dispatches through run_many, where a stage
        # exception is a whole-pump failure (the pipelined executor
        # instead isolates it into error artifacts — see
        # tests/test_service_faults.py); explore_stage is shared by
        # run_many and the pipeline, so the later recovery drain works
        monkeypatch.setattr(svc.session, "explore_stage", boom)
        svc.serve(pipelined=False)
        ticket = svc.submit(_request(layout=False))
        with pytest.raises(RuntimeError, match="pump failed"):
            svc.collect(ticket, timeout=600)
        with pytest.raises(RuntimeError, match="pump failed"):
            svc.poll(ticket)   # a poll-only consumer must not spin forever
        with pytest.raises(RuntimeError, match="pump failed"):
            svc.submit(_request(seed=9, layout=False))   # dead-pump refusal
        with pytest.raises(RuntimeError, match="restored"):
            svc.close()
        # the ticket is back in the queue, pending — not lost
        assert svc.poll(ticket) is None
        monkeypatch.setattr(svc.session, "explore_stage", real_explore)
        assert svc.run()[ticket].ok


# -- ticket lifecycle ----------------------------------------------------

class TestTicketLifecycle:
    def test_unknown_vs_pending_vs_collected(self):
        svc = DesignService()
        with pytest.raises(UnknownTicket, match="never issued"):
            svc.poll(0)
        ticket = svc.submit(_request(layout=False))
        assert svc.poll(ticket) is None   # pending, not an error
        with pytest.raises(PendingTicket, match="still pending"):
            svc.collect(ticket)           # no pump, no timeout: clear error
        svc.run()
        art = svc.collect(ticket)
        assert art.ok
        with pytest.raises(UnknownTicket, match="already collected"):
            svc.collect(ticket)
        with pytest.raises(UnknownTicket, match="never issued"):
            svc.collect(ticket + 1)

    def test_collect_timeout_raises_pending(self):
        svc = DesignService()
        ticket = svc.submit(_request(layout=False))
        t0 = time.monotonic()
        with pytest.raises(PendingTicket, match="after 0.2"):
            svc.collect(ticket, timeout=0.2)
        assert 0.1 < time.monotonic() - t0 < 10.0

    def test_done_bounded_by_pop_on_collect(self):
        svc = DesignService()
        tickets = [svc.submit(_request(seed=sd, layout=False))
                   for sd in (0, 1)]
        svc.run()
        assert len(svc.done) == 2
        kept = svc.collect(tickets[0], keep_done=True)
        assert svc.collect(tickets[0]) is kept   # escape hatch kept it
        svc.collect(tickets[1])
        assert not svc.done


# -- persistent artifact cache -------------------------------------------

class TestArtifactCache:
    def test_put_get_roundtrip(self, tmp_path, laid_artifact):
        cache = ArtifactCache(tmp_path / "cache")
        req = laid_artifact.request
        assert cache.get(req) is None and cache.stats["misses"] == 1
        path = cache.put(laid_artifact)
        assert path.name == f"{req.sha()}.json"
        assert req in cache and len(cache) == 1
        back = cache.get(req)
        assert back.summary() == laid_artifact.summary()
        assert cache.stats["hits"] == 1
        assert cache.clear() == 1 and len(cache) == 0

    def test_corrupt_entry_is_counted_miss(self, tmp_path, laid_artifact):
        cache = ArtifactCache(tmp_path)
        path = cache.put(laid_artifact)
        path.write_text(path.read_text()[: len(path.read_text()) // 2])
        assert cache.get(laid_artifact.request) is None
        assert cache.stats["rejects"] == 1

    def test_schema_skew_is_counted_miss(self, tmp_path, laid_artifact):
        cache = ArtifactCache(tmp_path)
        path = cache.put(laid_artifact)
        d = json.loads(path.read_text())
        assert d["schema"] == ARTIFACT_SCHEMA
        d["schema"] = 999
        path.write_text(json.dumps(d))
        assert cache.get(laid_artifact.request) is None
        assert cache.stats["rejects"] == 1
        with pytest.raises(ValueError, match="schema 999"):
            DesignArtifact.from_dict(d)

    def test_key_collision_guard(self, tmp_path, laid_artifact):
        # an entry parked under another request's sha must not be served
        cache = ArtifactCache(tmp_path)
        other = dataclasses.replace(laid_artifact.request, seed=123)
        cache.put(laid_artifact)
        os.replace(cache.path_for(laid_artifact.request),
                   cache.path_for(other))
        assert cache.get(other) is None
        assert cache.stats["rejects"] == 1

    def test_newer_request_schema_rejected_clearly(self, laid_artifact):
        d = laid_artifact.request.to_dict()
        d["hyperdrive"] = True
        with pytest.raises(ValueError, match="unknown DesignRequest field"):
            DesignRequest.from_dict(d)

    def test_atomic_write_preserves_previous_file(self, tmp_path,
                                                  laid_artifact):
        path = tmp_path / "artifact.json"
        laid_artifact.to_json(path)
        good = path.read_text()
        bad = dataclasses.replace(laid_artifact,
                                  layout_rows=(object(),))
        with pytest.raises(TypeError):
            bad.to_json(path)
        assert path.read_text() == good            # target never truncated
        assert list(tmp_path.iterdir()) == [path]  # no temp litter
        assert DesignArtifact.from_json(path).summary() \
            == laid_artifact.summary()

    def test_session_serves_repeat_from_disk(self, tmp_path):
        req = _request(requirements=REQS, layout=True)
        s1 = DesignSession(artifact_cache=tmp_path)
        a1 = s1.run(req)
        assert a1.provenance.served_from in ("explorer", "front_cache")
        assert s1.stats["artifact_cache_writes"] == 1
        # a FRESH session (fresh in-memory caches) hits the disk tier
        s2 = DesignSession(artifact_cache=ArtifactCache(tmp_path))
        a2 = s2.run(req)
        assert a2.provenance.served_from == "artifact_cache"
        assert a2.provenance.explorer_dispatches == 0
        assert s2.stats["explorer_dispatches"] == 0
        assert s2.stats["layout_dispatches"] == 0
        assert a2.summary() == a1.summary()
        # the service path uses the same tier
        svc = DesignService(session=DesignSession(artifact_cache=tmp_path))
        t = svc.submit(req)
        assert svc.run()[t].provenance.served_from == "artifact_cache"

    def test_error_artifacts_are_not_cached(self, tmp_path):
        ses = DesignSession(artifact_cache=tmp_path)
        bad = _request(requirements=Requirements(min_tops=1e9), layout=True)
        art = ses.run_many([bad], strict=False)[bad]
        assert not art.ok
        assert ses.stats["artifact_cache_writes"] == 0
        assert len(ses.artifact_cache) == 0


# -- cache eviction (long-lived fleets) ------------------------------------

def _variants(artifact, n):
    """Distinct cache entries: same content under fresh request keys."""
    return [dataclasses.replace(
        artifact, request=dataclasses.replace(artifact.request,
                                              seed=1000 + k))
            for k in range(n)]


class TestArtifactCacheEviction:
    def test_max_entries_prunes_lru_on_put(self, tmp_path, laid_artifact):
        cache = ArtifactCache(tmp_path, max_entries=2)
        v = _variants(laid_artifact, 3)
        for art in v:
            cache.put(art)
            time.sleep(0.02)   # distinct mtimes
        assert len(cache) == 2
        assert cache.stats["lru_evictions"] == 1
        assert cache.stats["prunes"] == 3
        # the oldest entry went; the newer two survive
        assert cache.get(v[0].request) is None
        assert cache.get(v[1].request) is not None
        assert cache.get(v[2].request) is not None

    def test_get_refreshes_lru_recency(self, tmp_path, laid_artifact):
        cache = ArtifactCache(tmp_path, max_entries=2)
        v = _variants(laid_artifact, 3)
        cache.put(v[0])
        time.sleep(0.02)
        cache.put(v[1])
        time.sleep(0.02)
        assert cache.get(v[0].request) is not None   # touch: v[1] is now LRU
        time.sleep(0.02)
        cache.put(v[2])                              # prune drops v[1]
        assert cache.get(v[1].request) is None
        assert cache.get(v[0].request) is not None

    def test_ttl_expires_old_entries(self, tmp_path, laid_artifact):
        cache = ArtifactCache(tmp_path, ttl_s=60.0)
        v = _variants(laid_artifact, 2)
        path = cache.put(v[0])
        stale = time.time() - 120.0
        os.utime(path, (stale, stale))
        cache.put(v[1])
        assert cache.stats["ttl_evictions"] == 1
        assert cache.get(v[0].request) is None
        assert cache.get(v[1].request) is not None
        assert len(cache) == 1

    def test_fresh_put_never_self_evicts(self, tmp_path, laid_artifact):
        cache = ArtifactCache(tmp_path, max_entries=1, ttl_s=3600.0)
        v = _variants(laid_artifact, 2)
        cache.put(v[0])
        time.sleep(0.02)
        cache.put(v[1])
        assert cache.get(v[1].request) is not None
        assert len(cache) == 1

    def test_entry_aged_exactly_ttl_survives(self, tmp_path, laid_artifact,
                                             monkeypatch):
        # the TTL bound is strict (`age > ttl_s` evicts): an entry aged
        # EXACTLY ttl_s is still valid.  Pin the prune's clock so the
        # boundary is exact, not within-jitter.
        from repro.api import artifact_cache as ac_mod
        cache = ArtifactCache(tmp_path, ttl_s=60.0)
        v = _variants(laid_artifact, 2)
        at_bound = cache.put(v[0])
        beyond = cache.put(v[1])
        t0 = time.time() + 1000.0

        class _FrozenTime:
            @staticmethod
            def time():
                return t0
        os.utime(at_bound, (t0 - 60.0, t0 - 60.0))       # age == ttl
        os.utime(beyond, (t0 - 60.0 - 1e-3, t0 - 60.0 - 1e-3))
        monkeypatch.setattr(ac_mod, "time", _FrozenTime)
        cache._prune()
        assert cache.get(v[0].request) is not None        # survives
        assert cache.get(v[1].request) is None            # past the bound
        assert cache.stats["ttl_evictions"] == 1

    def test_max_entries_bound_holds_under_concurrent_puts(
            self, tmp_path, laid_artifact):
        # max_entries=3 -> every put prunes (cadence max(1, 3//8) == 1);
        # concurrent putters race their prunes against each other's
        # unlinks, which must neither raise nor leave the bound broken
        cache = ArtifactCache(tmp_path, max_entries=3)
        v = _variants(laid_artifact, 12)
        errors = []

        def putter(arts):
            try:
                for a in arts:
                    cache.put(a)
            except Exception as e:     # pragma: no cover - the failure
                errors.append(e)
        threads = [threading.Thread(target=putter, args=(v[i::4],))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        cache._prune()
        assert len(cache) <= 3
        assert cache.stats["lru_evictions"] >= len(v) - 3

    def test_get_refreshed_mtime_survives_ttl_prune(self, tmp_path,
                                                    laid_artifact):
        # a hit refreshes the entry's mtime (os.utime in get), so a
        # recently-read entry must survive a TTL prune that evicts its
        # untouched sibling of the same age
        cache = ArtifactCache(tmp_path, ttl_s=50.0)
        v = _variants(laid_artifact, 2)
        touched = cache.put(v[0])
        untouched = cache.put(v[1])
        stale = time.time() - 100.0                       # both long expired
        os.utime(touched, (stale, stale))
        os.utime(untouched, (stale, stale))
        assert cache.get(v[0].request) is not None        # refresh mtime
        cache._prune()
        assert cache.get(v[0].request) is not None        # read kept it hot
        assert cache.get(v[1].request) is None
        assert cache.stats["ttl_evictions"] == 1

    def test_knob_validation(self, tmp_path):
        with pytest.raises(ValueError, match="max_entries"):
            ArtifactCache(tmp_path, max_entries=0)
        with pytest.raises(ValueError, match="ttl_s"):
            ArtifactCache(tmp_path, ttl_s=0)

    def test_unbounded_cache_never_prunes(self, tmp_path, laid_artifact):
        cache = ArtifactCache(tmp_path)
        for art in _variants(laid_artifact, 3):
            cache.put(art)
        assert len(cache) == 3
        assert cache.stats["prunes"] == 0


# -- bounded grid-sig cache ----------------------------------------------

class TestGridSigCache:
    def test_stat_counters_attributed_to_the_calling_session(
            self, laid_artifact):
        ses = DesignSession()
        req = laid_artifact.request
        ses.run_many([req])   # bucketed layout path exercises _grid_sig
        assert ses.stats["grid_sig_hits"] + ses.stats["grid_sig_misses"] \
            >= len(laid_artifact.pareto)
        # a second session's lookups land on ITS counter, not the first's
        before = dict(ses.stats)
        other = DesignSession()
        other.run_many([req])
        assert other.stats["grid_sig_hits"] >= len(laid_artifact.pareto)
        assert ses.stats["grid_sig_hits"] == before["grid_sig_hits"]

    def test_memo_bounded_by_lru_eviction(self, laid_artifact, monkeypatch):
        from repro.api import session as session_mod

        spec = laid_artifact.pareto.specs[0]
        monkeypatch.setattr(session_mod, "GRID_SIG_CACHE_SIZE", 2)
        for coarse in (61, 62, 63, 64):   # 4 distinct keys, bound of 2
            _grid_sig(spec, coarse)
        assert len(session_mod._GRID_SIG_MEMO) <= 2


# -- cross-process persistence -------------------------------------------

@pytest.mark.slow
def test_cross_process_cache_roundtrip(tmp_path):
    """A warm second *process* serves the repeat request entirely from
    the disk cache: zero explorer dispatches, provenance marks the
    cache tier, content equal to the first process's artifact."""
    cache_dir = tmp_path / "cache"
    req = _request(requirements=REQS, layout=True)
    parent = DesignSession(artifact_cache=cache_dir)
    art = parent.run(req)
    assert art.ok and parent.stats["artifact_cache_writes"] == 1

    r = subprocess.run(
        [sys.executable, str(REPO / "tests" / "cache_roundtrip_helper.py"),
         str(cache_dir), req.to_json()],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": str(REPO / "src"),
             "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    report = json.loads(r.stdout)
    assert report["ok"]
    assert report["explorer_dispatches"] == 0
    assert report["layout_dispatches"] == 0
    assert report["artifact_cache_hits"] == 1
    assert report["served_from"] == "artifact_cache"
    # tuples became JSON lists on the wire; compare in JSON space
    assert report["summary"] == json.loads(json.dumps(art.summary()))


@pytest.mark.slow
def test_cross_process_l2_sharing(tmp_path):
    """Two fleet workers (separate processes) with private L1s and one
    shared remote tier: the second worker serves the first worker's
    artifact with zero explorer dispatches, `served_from ==
    "artifact_cache_l2"`, and promotes it into its own L1."""
    remote = f"file://{tmp_path}/shared-l2"
    req = _request(requirements=REQS, layout=True, islands=2,
                   migrate_every=5)

    def worker(name):
        r = subprocess.run(
            [sys.executable,
             str(REPO / "tests" / "cache_roundtrip_helper.py"),
             str(tmp_path / name), req.to_json(), "--remote", remote],
            capture_output=True, text=True, timeout=600,
            env={**os.environ, "PYTHONPATH": str(REPO / "src"),
                 "JAX_PLATFORMS": "cpu"})
        assert r.returncode == 0, r.stderr[-3000:]
        return json.loads(r.stdout)

    first = worker("w1")
    assert first["ok"] and first["explorer_dispatches"] == 1
    assert first["served_from"] == "explorer"
    assert first["tier_stats"]["artifact_cache_l2_writes"] == 1
    assert first["mesh"]["islands"] == 2
    assert first["mesh"]["migration_topology"] == "ring"

    second = worker("w2")
    assert second["ok"]
    assert second["explorer_dispatches"] == 0
    assert second["layout_dispatches"] == 0
    assert second["served_from"] == "artifact_cache_l2"
    assert second["tier_stats"]["artifact_cache_l2_hits"] == 1
    assert second["tier_stats"]["artifact_cache_promotions"] == 1
    assert second["summary"] == first["summary"]
    # the promoted copy lives in w2's L1 now
    assert any((tmp_path / "w2").glob("*.json"))
