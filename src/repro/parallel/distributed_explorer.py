"""Device-mesh design exploration: the session's sharded explore engine.

Two mesh execution modes behind one entry point (`explore_cells_mesh`),
both first-class engines of `repro.api.session.DesignSession.explore_
stage` (a request opts in with `DesignRequest.islands > 1`; a session
opts in with `DesignSession(mesh=...)`):

  * **sharded cells** (`islands == 1`) — the coalesced (array_size x
    seed) cell list is sharded over the mesh's device axis and each
    device vmaps the very same operand-traced `nsga2.run_cell` the
    batched explorer uses, with the *identical* per-cell key and
    operands.  Per-cell fronts are therefore bit-equal to the
    single-device engine (`repro.core.batched_explorer.explore_cells`)
    — asserted by `tests/test_distributed_explorer.py` — so a fleet
    can turn the mesh on and off without invalidating any cache tier.

  * **island model** (`islands > 1`) — every island evolves an
    independent NSGA-II population per cell (island i's stream is
    `fold_in(key(seed), i)`), with periodic **ring migration** of
    Pareto elites: island i's top-k elites replace island i+1's worst-k
    (mod I).  The ring is realized as a local shift of the per-device
    island block plus ONE `jax.lax.ppermute` of the boundary elite
    block, so per-round comms are O(elites), not the O(islands x pop)
    of the all-gather scheme this engine replaced.  Migration is fully
    deterministic (rank/crowding-ordered, no random partner choice)
    and the key schedule is a function of *global* island ids only, so
    the merged result is bit-identical for ANY device count dividing
    the island count — an 8-device pod and a 1-device laptop produce
    the same front (also asserted by the tests).

The per-device program composes the same `run_cell` / `evolve_from`
building blocks as the single-device explorers, so the one-compile
sweep contract carries over: one jit-compiled program per (mesh,
statics, schedule) — rounds are unrolled inside it — and `run_cell` is
traced once per program build (`nsga2.TRACE_COUNTS` probe).

The merged front of an island run is the deduplicated Pareto front of
the union of the island populations (`explorer.pareto_result_from_
population` over the flattened island axis) — it can only gain points
over a lone island, never lose dominance, and the session records the
migration provenance (device count, topology, rounds) in the artifact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import nsga2, pareto
from repro.core.constants import CAL28
from repro.parallel.axes import shard_map
from repro.runtime.lock_sanitizer import make_lock

DEFAULT_MIGRATE_EVERY = 20
MESH_AXIS = "islands"

# Compiled mesh programs, keyed by everything that shapes them.  Session
# explore stages on several service threads may race the first build;
# the lock makes the cache insert atomic (compilation itself is
# jax-level cached by function identity, so a lost race costs nothing).
_PROGRAM_LOCK = make_lock("parallel.distributed_explorer._PROGRAM_LOCK")
_PROGRAMS: dict = {}


def default_mesh(max_devices: int | None = None) -> Mesh:
    """A 1-D mesh over the local devices (optionally capped), the shape
    both mesh modes consume.  One flat axis: island/cell sharding is
    1-D by construction (redco-style `mesh_utils` flattening)."""
    devices = jax.devices()
    n = len(devices)
    if max_devices is not None:
        if max_devices <= 0:
            raise ValueError("max_devices must be positive")
        n = min(n, max_devices)
    return Mesh(np.asarray(devices[:n]), (MESH_AXIS,))


def mesh_size(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def devices_for_islands(mesh: Mesh, islands: int) -> int:
    """Devices the island engine will actually use: the largest divisor
    of `islands` that fits the mesh.  Using a divisor (instead of
    padding) keeps the island->device block map exact, which is what
    makes the result independent of the device count."""
    n_dev = mesh_size(mesh)
    return max(d for d in range(1, min(islands, n_dev) + 1)
               if islands % d == 0)


def _submesh(mesh: Mesh, n: int) -> Mesh:
    if n == mesh_size(mesh):
        return mesh
    axis = mesh.axis_names[0]
    return Mesh(np.asarray(mesh.devices).reshape(-1)[:n], (axis,))


def _round_schedule(generations: int, migrate_every: int) -> tuple[int, ...]:
    """Per-round generation counts: migration fires between rounds, so
    `len(schedule) - 1` migrations happen in total."""
    if migrate_every <= 0:
        raise ValueError("migrate_every must be positive")
    full, rem = divmod(generations, migrate_every)
    gens = [migrate_every] * full + ([rem] if rem else [])
    return tuple(gens) or (generations,)


def _elite_count(pop_size: int) -> int:
    return min(max(2, pop_size // 8), pop_size // 2)


# ----------------------------------------------------------------------
# Compiled mesh programs
# ----------------------------------------------------------------------
def _sharded_cells_program(mesh: Mesh, statics: nsga2.EvolveStatics,
                           n_gens: int):
    """jit(shard_map(vmap(run_cell))) over the cell axis: each device
    runs its block of cells with the exact single-engine key/operands."""
    key = ("cells", mesh, statics, n_gens)
    with _PROGRAM_LOCK:
        prog = _PROGRAMS.get(key)
    if prog is not None:
        return prog
    axis = mesh.axis_names[0]
    cell = functools.partial(nsga2.run_cell, statics=statics, n_gens=n_gens)

    def body(keys, spaces):
        return jax.vmap(cell)(keys, spaces)

    prog = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                             out_specs=(P(axis), P(axis))))
    with _PROGRAM_LOCK:
        _PROGRAMS[key] = prog
    return prog


def _island_program(mesh: Mesh, statics: nsga2.EvolveStatics,
                    schedule: tuple[int, ...], n_elite: int):
    """The island engine's one compiled program: per-device island
    blocks, cells vmapped inside, migration rounds unrolled, ring
    links via a single boundary `ppermute` per round."""
    key = ("islands", mesh, statics, schedule, n_elite)
    with _PROGRAM_LOCK:
        prog = _PROGRAMS.get(key)
    if prog is not None:
        return prog
    axis = mesh.axis_names[0]
    n_dev = mesh_size(mesh)
    perm = [(d, (d + 1) % n_dev) for d in range(n_dev)]
    rc = functools.partial(nsga2.rank_and_crowd, statics=statics)

    def migrate(genes, objs):
        """Ring-migrate elites across the island axis.

        Shapes: genes (k, C, P, 3), objs (k, C, P, 4) — k islands on
        this device, C cells.  Each (island, cell) population is sorted
        by (rank, -crowding); the top `n_elite` rows are this island's
        emigrants and the bottom `n_elite` rows are replaced by the
        previous island's.  The ring crosses the device boundary once:
        the local block shifts down by one island and the last island's
        elites `ppermute` to the next device — O(n_elite) bytes per
        link instead of an O(islands x pop) all-gather.  The sorted
        order (and hence the returned layout) depends only on island-
        local data, so the result is identical for every device count."""
        ranks, crowd = jax.vmap(jax.vmap(lambda o: rc(o)))(objs)
        order = jnp.lexsort((-crowd, ranks), axis=-1)
        sorted_g = jnp.take_along_axis(genes, order[..., None], axis=2)
        sorted_o = jnp.take_along_axis(objs, order[..., None], axis=2)
        elite_g, elite_o = sorted_g[:, :, :n_elite], sorted_o[:, :, :n_elite]
        recv_g = jnp.concatenate(
            [jax.lax.ppermute(elite_g[-1:], axis, perm), elite_g[:-1]], 0)
        recv_o = jnp.concatenate(
            [jax.lax.ppermute(elite_o[-1:], axis, perm), elite_o[:-1]], 0)
        return (sorted_g.at[:, :, -n_elite:].set(recv_g),
                sorted_o.at[:, :, -n_elite:].set(recv_o))

    def body(init_keys, evolve_keys, spaces):
        # init_keys (k, C); evolve_keys (max(R-1,1), k, C); spaces
        # replicated (C, ...).  Rounds are unrolled: ONE device program
        # regardless of the migration cadence.
        cell = functools.partial(nsga2.run_cell, statics=statics,
                                 n_gens=schedule[0])
        genes, objs = jax.vmap(
            lambda krow: jax.vmap(cell)(krow, spaces))(init_keys)
        for r, g in enumerate(schedule[1:]):
            genes, objs = migrate(genes, objs)

            def step(k, ge, ob, sp, g=g):
                return nsga2.evolve_from(k, ge, ob, sp, statics, g)

            genes, objs = jax.vmap(
                lambda kr, gr, orow: jax.vmap(step)(kr, gr, orow, spaces)
            )(evolve_keys[r], genes, objs)
        return genes, objs

    prog = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(None, axis), P()),
        out_specs=(P(axis), P(axis))))
    with _PROGRAM_LOCK:
        _PROGRAMS[key] = prog
    return prog


# ----------------------------------------------------------------------
# Entry point
# ----------------------------------------------------------------------
def explore_cells_mesh(cells, *, mesh: Mesh | None = None, islands: int = 1,
                       migrate_every: int = DEFAULT_MIGRATE_EVERY,
                       pop_size: int = 256, generations: int = 80,
                       crossover_prob: float = nsga2.DEFAULT_CROSSOVER_PROB,
                       mutation_prob: float = nsga2.DEFAULT_MUTATION_PROB,
                       cal=CAL28, use_pallas_dominance: bool = False,
                       use_pallas_rank: bool = False):
    """Explore an (array_size, seed) cell list over a device mesh.

    Returns `({(array_size, seed): ParetoResult}, facts)` — the same
    front mapping as `batched_explorer.explore_cells` plus a facts dict
    (`mesh_devices`, `islands`, `migration_topology`,
    `migration_rounds`) the session stamps into artifact provenance.

    `islands == 1` shards the cell list (bit-equal per-cell fronts to
    the single-device engine); `islands > 1` runs ring-migrating island
    evolution per cell and merges the union front.  Either way the
    result is independent of the mesh's device count.
    """
    from repro.core import explorer  # deferred: explorer wraps core flows

    if islands < 1:
        raise ValueError("islands must be >= 1")
    cells = list(dict.fromkeys((int(s), int(sd)) for s, sd in cells))
    if not cells:
        raise ValueError("explore_cells_mesh needs at least one cell")
    if mesh is None:
        mesh = default_mesh()
    statics = nsga2.EvolveStatics(
        pop_size=pop_size, crossover_prob=crossover_prob,
        mutation_prob=mutation_prob,
        use_pallas_dominance=use_pallas_dominance,
        use_pallas_rank=use_pallas_rank)
    spaces = [nsga2.space_operands(nsga2.NSGA2Config(array_size=s, cal=cal))
              for s, _ in cells]

    if islands == 1:
        n_dev = mesh_size(mesh)
        pad = (-len(cells)) % n_dev
        padded = cells + cells[:1] * pad
        spaces_b = jax.tree.map(
            lambda *xs: jnp.stack(xs), *(spaces + spaces[:1] * pad))
        keys = jnp.stack([jax.random.key(sd) for _, sd in padded])
        prog = _sharded_cells_program(mesh, statics, generations)
        genes_b, objs_b = prog(keys, spaces_b)
        genes_b = np.asarray(genes_b)[:len(cells)]
        objs_b = np.asarray(objs_b)[:len(cells)]
        pops = {cell: (genes_b[i], objs_b[i])
                for i, cell in enumerate(cells)}
        facts = {"mesh_devices": n_dev, "islands": 1,
                 "migration_topology": "sharded", "migration_rounds": 0}
    else:
        n_dev = devices_for_islands(mesh, islands)
        sub = _submesh(mesh, n_dev)
        schedule = _round_schedule(generations, migrate_every)
        n_elite = _elite_count(pop_size)
        base = jnp.stack([jax.random.key(sd) for _, sd in cells])   # (C,)
        fold = jax.vmap(jax.random.fold_in, in_axes=(0, None))
        init_keys = jax.vmap(lambda i: fold(base, i),
                             out_axes=0)(jnp.arange(islands))       # (I, C)
        n_rounds = max(len(schedule) - 1, 1)
        evolve_keys = jax.vmap(
            lambda r: jax.vmap(jax.vmap(
                lambda k: jax.random.fold_in(k, 0x5EED0000 + r)))(init_keys)
        )(jnp.arange(n_rounds))                                     # (R,I,C)
        spaces_b = jax.tree.map(lambda *xs: jnp.stack(xs), *spaces)
        prog = _island_program(sub, statics, schedule, n_elite)
        genes_b, objs_b = prog(init_keys, evolve_keys, spaces_b)
        genes_b = np.asarray(genes_b)   # (I, C, P, 3)
        objs_b = np.asarray(objs_b)
        pops = {cell: (genes_b[:, i].reshape(-1, genes_b.shape[-1]),
                       objs_b[:, i].reshape(-1, objs_b.shape[-1]))
                for i, cell in enumerate(cells)}
        facts = {"mesh_devices": n_dev, "islands": islands,
                 "migration_topology": "ring",
                 "migration_rounds": len(schedule) - 1}

    fronts = {(s, sd): explorer.pareto_result_from_population(
                  s, genes, objs, cal=cal)
              for (s, sd), (genes, objs) in pops.items()}
    return fronts, facts


def pareto_front_of(genes: np.ndarray, objs: np.ndarray):
    """Deduplicated non-dominated subset of a raw (genes, objs) union —
    the test-side distillation of a merged island population."""
    uniq, idx = np.unique(genes, axis=0, return_index=True)
    ou = objs[idx]
    mask = np.asarray(pareto.non_dominated_mask(jnp.asarray(ou)))
    return uniq[mask], ou[mask]
