"""Batched one-compile explorer: equivalence with the sequential path,
single-trace contract, ground-truth front recovery, fused rank oracles."""
import jax
import numpy as np
import pytest

from repro.core import explorer, nsga2, pareto
from repro.core.batched_explorer import explore_batch

SIZES = (4096, 16384, 65536)


def _front_set(res: explorer.ParetoResult):
    return {(s.h, s.w, s.l, s.b_adc) for s in res.specs}


def _true_front(array_size: int):
    genes, objs = explorer.full_design_space(array_size)
    mask = np.asarray(pareto.non_dominated_mask(objs))
    return {tuple(g) for g, m in zip(np.asarray(genes), mask) if m}


class TestExploreBatch:
    def test_single_trace_and_sequential_equivalence(self):
        """3 sizes x 2 seeds: exactly one trace of the generation program,
        and per-cell fronts identical to the sequential `nsga2.run` path."""
        seeds = (0, 1)
        pop, gens = 56, 10
        jax.clear_caches()   # order-independent: force a fresh compile
        before = nsga2.TRACE_COUNTS["run_cell"]
        out = explore_batch(SIZES, seeds, pop_size=pop, generations=gens)
        assert nsga2.TRACE_COUNTS["run_cell"] - before == 1
        assert set(out) == {(s, sd) for s in SIZES for sd in seeds}
        # warm re-dispatch: no new trace
        explore_batch(SIZES, seeds, pop_size=pop, generations=gens)
        assert nsga2.TRACE_COUNTS["run_cell"] - before == 1
        for s in SIZES:
            for sd in seeds:
                cfg = nsga2.NSGA2Config(array_size=s, pop_size=pop,
                                        generations=gens, seed=sd)
                popu = nsga2.run(cfg)
                ref = explorer.pareto_result_from_population(
                    s, popu.genes, popu.objs)
                assert _front_set(out[(s, sd)]) == _front_set(ref), (s, sd)

    def test_recovers_ground_truth_front_all_sizes(self):
        """At the default exploration budget the batched sweep recovers the
        exhaustive-enumeration Pareto set exactly, per size."""
        out = explore_batch(SIZES, (0,), pop_size=256, generations=80)
        for s in SIZES:
            found = {(int(np.log2(sp.h)), int(np.log2(sp.l)), sp.b_adc)
                     for sp in out[(s, 0)].specs}
            assert found == _true_front(s), s

    def test_explore_sizes_wrapper_matches_batch(self):
        by_size = explorer.explore_sizes(SIZES[:2], seed=4, pop_size=48,
                                         generations=6)
        out = explore_batch(SIZES[:2], (4,), pop_size=48, generations=6)
        for s in SIZES[:2]:
            assert _front_set(by_size[s]) == _front_set(out[(s, 4)])

    def test_operand_traced_sequential_path_single_trace(self):
        """Sweeping array sizes sequentially also compiles once: the size
        is an operand, not a static."""
        pop, gens = 40, 5
        jax.clear_caches()   # order-independent: force a fresh compile
        before = nsga2.TRACE_COUNTS["run_cell"]
        for s in SIZES:
            nsga2.run(nsga2.NSGA2Config(array_size=s, pop_size=pop,
                                        generations=gens))
        assert nsga2.TRACE_COUNTS["run_cell"] - before == 1


class TestFusedRankPath:
    """The Pallas rank path (interpret mode off-TPU) against jnp oracles."""

    @pytest.mark.parametrize("p,m,seed", [(64, 4, 0), (200, 4, 1),
                                          (256, 3, 2), (400, 2, 3)])
    def test_rank_and_crowd_agree_with_oracles(self, p, m, seed):
        from repro.kernels.pareto_dom import ops as dom_ops

        f = jax.random.normal(jax.random.key(seed), (p, m))
        ranks, crowd = dom_ops.rank_and_crowd(f)
        ranks_ref = pareto.non_dominated_rank(f)
        np.testing.assert_array_equal(np.asarray(ranks), np.asarray(ranks_ref))
        np.testing.assert_allclose(
            np.asarray(crowd),
            np.asarray(pareto.crowding_distance(f, ranks_ref)))

    def test_explore_with_pallas_rank_matches_default(self):
        a = explorer.explore(16384, pop_size=64, generations=8, seed=2)
        b = explorer.explore(16384, pop_size=64, generations=8, seed=2,
                             use_pallas_rank=True)
        assert _front_set(a) == _front_set(b)
