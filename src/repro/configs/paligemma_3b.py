"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP (STUB) + gemma decoder.  [arXiv:2407.07726; hf]

Gemma-style decoder: head_dim 256, GeGLU (gated gelu), RMSNorm, RoPE, tied
embeddings.  Vision tower stubbed per the assignment: `input_specs()`
provides precomputed patch embeddings (B, 256, 2048); attention uses a
prefix-LM mask (bidirectional over patches, causal over text).
"""
import dataclasses

from repro.configs.base import ArchConfig, VLMConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=257216, head_dim=256,
    norm="rmsnorm", act="gelu_tanh", mlp_gated=True, tie_embeddings=True,
    vlm=VLMConfig(n_patches=256),
    source="arXiv:2407.07726; hf",
)

REDUCED = dataclasses.replace(
    CONFIG, name="paligemma-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab=512,
    head_dim=16,
    vlm=VLMConfig(n_patches=16),
)
