"""Fixture: the disciplined version of `locks_bad.py` — every shared
access holds ``_lock``, and ``_a``/``_b`` nest in one global order.
The lock-discipline pass must produce zero findings.
"""
import threading


class GoodService:
    def __init__(self):
        self._lock = threading.Lock()
        self._a = threading.Lock()
        self._b = threading.Lock()
        self.count = 0
        self._worker = None

    def start(self):
        self._worker = threading.Thread(target=self._loop)
        self._worker.start()

    def _loop(self):
        for _ in range(8):
            with self._lock:
                self.count += 1

    def read(self):
        with self._lock:
            return self.count

    def ab(self):
        with self._a:
            with self._b:
                return id(self)

    def also_ab(self):
        with self._a:
            with self._b:
                return -id(self)
