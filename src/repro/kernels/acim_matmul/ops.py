"""jit'd public wrapper for the ACIM matmul kernel.

Handles shape padding (zero rows = caps held at V_CM, contributing no
charge), static capacitor-mismatch folding (Eq. 5) as a multiplicative
weight perturbation, backend selection (interpret mode off-TPU), and a
straight-through-estimator custom VJP so the simulated macro can sit inside
a training graph (`repro.quant.cim_linear`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acim_numerics import NoiseParams
from repro.core.acim_spec import MacroSpec
from repro.kernels.acim_matmul.kernel import acim_matmul_kernel


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def mismatch_weights(w: jax.Array, spec: MacroSpec, instance_key: jax.Array,
                     noise: NoiseParams) -> jax.Array:
    """Fold the static per-cap mismatch into the weights: the QR error
    sum_k q_k eps_k is exactly a matmul with w * (1 + sqrt(pref) * eps)."""
    eps = jax.random.normal(instance_key, w.shape, jnp.float32)
    return w * (1.0 + float(np.sqrt(noise.prefactor)) * noise.mismatch_rel * eps)


def acim_matmul(x: jax.Array, w: jax.Array, spec: MacroSpec, *,
                block_m: int = 128, block_n: int = 128, block_k: int | None = None,
                interpret: bool | None = None) -> jax.Array:
    """Simulated y = x @ w on the macro; x (..., K), w (K, C) in [-1, 1].

    Bit-exact against `ref.acim_matmul_ref` for any shape (tests sweep
    shapes/dtypes).  Leading x dims are flattened into M.
    """
    if interpret is None:
        interpret = _should_interpret()
    n, b_adc = spec.n_caps, spec.b_adc
    lead = x.shape[:-1]
    k = x.shape[-1]
    c = w.shape[-1]
    xm = x.reshape((-1, k)).astype(jnp.float32)
    m = xm.shape[0]

    if block_k is None:
        block_k = max(n, min(512, 2 ** int(np.ceil(np.log2(max(k, 1))))))
        block_k = max(n, (block_k // n) * n)
    block_m_eff = min(block_m, max(8, 2 ** int(np.ceil(np.log2(max(m, 1))))))

    xm = _pad_to(_pad_to(xm, 0, block_m_eff), 1, block_k)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, block_k), 1, block_n)
    y = acim_matmul_kernel(xm, wp, n=n, b_adc=b_adc, block_m=block_m_eff,
                           block_n=min(block_n, wp.shape[1]),
                           block_k=block_k, interpret=interpret)
    return y[:m, :c].reshape(lead + (c,))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def acim_matmul_ste(x: jax.Array, w: jax.Array, spec: MacroSpec,
                    interpret: bool | None = None) -> jax.Array:
    """ACIM matmul with a straight-through gradient (d y / d(x,w) of the
    ideal matmul), the standard estimator for quantization-in-the-loop
    training."""
    return acim_matmul(x, w, spec, interpret=interpret)


def _ste_fwd(x, w, spec, interpret):
    return acim_matmul(x, w, spec, interpret=interpret), (x, w)


def _ste_bwd(spec, interpret, res, g):
    x, w = res
    gx = jnp.einsum("...c,kc->...k", g, w)
    gw = jnp.einsum("...k,...c->kc", x, g)
    return gx, gw


acim_matmul_ste.defvjp(_ste_fwd, _ste_bwd)
