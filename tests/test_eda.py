"""EDA stack: netlist structure, placement legality, routing, area model."""
import pytest

from repro.core.acim_spec import MacroSpec
from repro.eda import netlist as nl
from repro.eda.cells import library
from repro.eda.flow import drc_lite, generate_layout
from repro.eda.placer import place


SMALL = MacroSpec(64, 16, 2, 3)
MED = MacroSpec(128, 32, 4, 3)


class TestNetlist:
    def test_instance_counts(self):
        n = nl.generate(SMALL)
        st = n.stats()
        assert st["by_cell"]["SRAM8T"] == SMALL.array_size
        assert st["by_cell"]["CAPLC"] == SMALL.n_caps * SMALL.w
        assert st["by_cell"]["COMP"] == SMALL.w
        assert st["by_cell"]["DFF"] == SMALL.w * SMALL.b_adc

    def test_rbl_net_spans_column(self):
        n = nl.generate(SMALL)
        rbl = [net for net in n.nets if net.name == "c0_rbl"][0]
        # caps + switches + comparator
        assert len(rbl.pins) >= SMALL.n_caps + 1

    @pytest.mark.parametrize("spec", [SMALL, MED, MacroSpec(128, 128, 2, 3),
                                      MacroSpec(512, 32, 8, 3)])
    def test_closed_form_stats_match_generate(self, spec):
        assert nl.stats_for_spec(spec) == nl.generate(spec).stats()


class TestPlacer:
    @pytest.mark.parametrize("spec", [SMALL, MED, MacroSpec(128, 128, 2, 3)])
    def test_drc_clean(self, spec):
        p = place(spec)
        rep = drc_lite(p)
        assert rep.clean, (spec, rep)

    def test_area_within_model_envelope(self):
        from repro.core import estimator

        p = place(MED)
        est = float(estimator.area_f2_per_bit(MED.h, MED.l, MED.b_adc))
        ratio = p.area_f2_per_bit() / est
        assert 0.9 < ratio < 1.6   # layout = model + routing/driver overhead

    def test_cells_within_bounds(self):
        p = place(SMALL)
        for r in p.rects:
            assert r.x >= 0 and r.y >= 0
            assert r.x + r.w <= p.width and r.y + r.h <= p.height


class TestFlow:
    def test_end_to_end_routes_everything(self):
        lr = generate_layout(SMALL)
        m = lr.metrics()
        assert m["route_success"] == 1.0
        assert m["drc_clean"]
        assert m["failed_nets"] == 0
        assert m["elapsed_s"] < 120

    def test_pareto_to_layout_pipeline(self):
        from repro.core import explorer

        res = explorer.explore(4096, pop_size=64, generations=15, seed=1)
        spec = res.filter(min_tops=0.05).specs[0] if len(
            res.filter(min_tops=0.05)) else res.specs[0]
        lr = generate_layout(spec)
        assert lr.metrics()["drc_clean"]


class TestCellLibrary:
    def test_footprints_match_calibrated_areas(self):
        from repro.core.constants import CAL28

        lib = library()
        assert lib["SRAM8T"].area == pytest.approx(CAL28.a_sram, rel=0.1)
        assert lib["DFF"].area == pytest.approx(CAL28.a_dff, rel=0.1)
