"""Typed service metrics: counters, gauges, histograms, one registry.

This absorbs the ad-hoc `collections.Counter` accounting that
`DesignService.stats()` grew over PRs 3-6 into a typed, snapshotable
registry.  Three metric kinds:

  * `Counter` — monotonically increasing totals (dispatches, retries,
    cache hits).  Backed either by its own atomic int or by a `fn`
    callback sampled at snapshot time — the service proxies its
    existing `session.stats` keys through callbacks so there is ONE
    source of truth and `stats()` stays a thin compatibility view
    instead of a second bookkeeping system;
  * `Gauge` — point-in-time levels (queue depth, stage occupancy,
    live worker count), also callback-backed for the same reason;
  * `Histogram` — fixed log-spaced buckets (`DEFAULT_LATENCY_BUCKETS`:
    powers of two from 1 ms to ~73 min) plus a bounded reservoir of
    raw samples, so `summary()` reports exact p50/p95/p99 through the
    *same* `percentile()` the benchmarks use (identical quantile math
    by construction, not by convention) while the bucket counts stay
    prometheus-renderable.

Metrics are identified by name + optional label set (e.g.
`tickets_served_total{tier="artifact_cache"}`); asking the registry
for the same (name, labels) twice returns the same object.
`MetricsRegistry.snapshot()` is the versioned JSON form
(`METRICS_SCHEMA`); `repro.telemetry.export.render_prometheus` turns a
snapshot into prometheus text exposition format.

`percentile()` reimplements numpy's default linear-interpolation
quantile in pure Python: `benchmarks/service_bench.py` previously
computed its ticket p50/p95 with `np.percentile` in five separate
scenarios — both now call this one helper, so bench columns and
histogram summaries can never disagree on quantile math.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time

from repro.runtime.lock_sanitizer import make_lock

# Bump on any change to the snapshot shape.
METRICS_SCHEMA = 1

# Log-spaced (powers of two) latency bucket upper bounds, seconds:
# 1 ms .. ~4369 s.  Fixed so histograms from different processes /
# bench runs are mergeable bucket-for-bucket.
DEFAULT_LATENCY_BUCKETS = tuple(0.001 * 2.0 ** i for i in range(23))

# Bounded sample reservoir per histogram: enough to keep service-bench
# scale exact (hundreds of tickets) without letting a long-lived fleet
# grow memory without bound.  Beyond the cap the reservoir keeps the
# most recent samples (sliding window), which is the right bias for an
# operator asking "what is latency like *now*".
HISTOGRAM_SAMPLE_CAP = 8192


def percentile(values, q: float) -> float:
    """The q-th percentile (0..100) of `values` with linear
    interpolation between closest ranks — bit-identical to
    `numpy.percentile(values, q)` at default settings for finite
    inputs.  Raises on an empty sequence, same as numpy."""
    xs = sorted(float(v) for v in values)
    if not xs:
        raise ValueError("percentile() of an empty sequence")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q!r} outside [0, 100]")
    rank = (q / 100.0) * (len(xs) - 1)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return xs[int(rank)]
    return xs[lo] * (hi - rank) + xs[hi] * (rank - lo)


def _label_key(labels: dict | None) -> tuple:
    return tuple(sorted((labels or {}).items()))


@dataclasses.dataclass
class Counter:
    """Monotonic total; `fn` (if set) is sampled at snapshot time and
    wins over the internal count — proxy mode for pre-existing stats."""

    name: str
    help: str = ""
    labels: dict = dataclasses.field(default_factory=dict)
    fn: object = None
    _value: float = 0.0
    _lock: threading.Lock = dataclasses.field(default_factory=threading.Lock,
                                              repr=False)

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("Counter.inc() must be non-negative")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        if self.fn is not None:
            return float(self.fn())
        with self._lock:
            return self._value

    def to_dict(self) -> dict:
        return {"type": "counter", "help": self.help,
                "labels": dict(self.labels), "value": self.value}


@dataclasses.dataclass
class Gauge:
    """Point-in-time level; callback-backed (`fn`) or `set()`-driven."""

    name: str
    help: str = ""
    labels: dict = dataclasses.field(default_factory=dict)
    fn: object = None
    _value: float = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return float(self.fn()) if self.fn is not None else self._value

    def to_dict(self) -> dict:
        return {"type": "gauge", "help": self.help,
                "labels": dict(self.labels), "value": self.value}


class Histogram:
    """Fixed-bucket histogram with a bounded exact-sample reservoir.

    `buckets` are the inclusive upper bounds (`le`), ascending; an
    implicit +inf bucket catches the tail.  Thread-safe: layout pool
    workers and the admission pump observe concurrently."""

    def __init__(self, name: str, help: str = "", *,  # noqa: A002
                 labels: dict | None = None,
                 buckets=DEFAULT_LATENCY_BUCKETS,
                 sample_cap: int = HISTOGRAM_SAMPLE_CAP):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError("histogram buckets must be ascending and "
                             "non-empty")
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)   # + the +inf tail
        self._sum = 0.0
        self._count = 0
        self._samples: collections.deque = collections.deque(
            maxlen=sample_cap)
        self._lock = make_lock("Histogram._lock")

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        while i < len(self.bounds) and v > self.bounds[i]:
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1
            self._samples.append(v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def summary(self) -> dict:
        """count/sum/min/max plus exact p50/p95/p99 over the retained
        reservoir — the same `percentile()` the benchmarks call."""
        with self._lock:
            xs = list(self._samples)
            count, total = self._count, self._sum
        out = {"count": count, "sum": total}
        if xs:
            out.update(min=min(xs), max=max(xs),
                       p50=percentile(xs, 50), p95=percentile(xs, 95),
                       p99=percentile(xs, 99))
        return out

    def to_dict(self) -> dict:
        with self._lock:
            counts = list(self._counts)
        return {"type": "histogram", "help": self.help,
                "labels": dict(self.labels),
                "buckets": [[b, c] for b, c in zip(self.bounds, counts)],
                "inf_count": counts[-1],
                "count": self._count, "sum": self._sum,
                "summary": self.summary()}


class MetricsRegistry:
    """Name + label keyed store of the three metric kinds.

    Re-registering the same (name, labels) returns the existing
    object (callbacks may be refreshed); registering the same name as
    a *different* kind raises — a scrape endpoint with one name
    meaning two things is a lying endpoint."""

    def __init__(self):
        self._lock = make_lock("MetricsRegistry._lock")
        self._metrics: dict[tuple, object] = {}

    def _register(self, cls, name, help_, labels, **kw):
        key = (name, _label_key(labels))
        with self._lock:
            existing = self._metrics.get(key)
            if existing is not None:
                if not isinstance(existing, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{type(existing).__name__}, not {cls.__name__}")
                fn = kw.get("fn")
                if fn is not None and hasattr(existing, "fn"):
                    existing.fn = fn
                return existing
            metric = cls(name, help_, labels=dict(labels or {}), **kw)
            self._metrics[key] = metric
            return metric

    def counter(self, name: str, help_: str = "", *,
                labels: dict | None = None, fn=None) -> Counter:
        return self._register(Counter, name, help_, labels, fn=fn)

    def gauge(self, name: str, help_: str = "", *,
              labels: dict | None = None, fn=None) -> Gauge:
        return self._register(Gauge, name, help_, labels, fn=fn)

    def histogram(self, name: str, help_: str = "", *,
                  labels: dict | None = None,
                  buckets=DEFAULT_LATENCY_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help_, labels,
                              buckets=buckets)

    def __len__(self) -> int:
        with self._lock:
            return len(self._metrics)

    def snapshot(self) -> dict:
        """The versioned, JSON-serializable scrape: every metric's
        `to_dict()` (callbacks sampled NOW), grouped as a list per name
        so label families stay together."""
        with self._lock:
            metrics = list(self._metrics.values())
        series: dict[str, list] = {}
        for m in metrics:
            series.setdefault(m.name, []).append(m.to_dict())
        return {"schema": METRICS_SCHEMA,
                "time_unix_s": time.time(),
                "metrics": series}
