"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab=151936, head_dim=128,
    norm="rmsnorm", act="silu", mlp_gated=True, attn_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen2.5-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16,
)
