"""Design-service benchmark: N coalesced requests vs N sequential sessions.

The service-level counterpart of `benchmarks/explorer_bench.py` (which
measures the raw sweep program) and `benchmarks/layout_bench.py` (the
raw layout batch): this measures the multi-tenant front door end to end.
The sequential baseline runs each `DesignRequest` in its own fresh
`DesignSession` (one explorer dispatch per request, one whole-batch
layout per request — the legacy `explore` -> `filter` ->
`generate_layouts` shape); the coalesced side submits all N requests to
one `DesignService`, which folds them into a single explorer dispatch
and lays the union of surviving specs out in routing-grid-shape buckets.

Two views per side:

  * cold — fresh process caches (`jax.clear_caches()` first): what a
    fresh fleet pays, including compilation;
  * warm — the same requests resubmitted to the same service / sessions:
    front-cache hits, steady-state relayout only.

Compile counts come from the `nsga2.TRACE_COUNTS["run_cell"]` probe and
the session dispatch counters.  Results land in `BENCH_service.json` at
the repo root so future PRs have a perf trajectory.

  PYTHONPATH=src python -m benchmarks.service_bench [--smoke] [--out PATH]

`--smoke` shrinks the request set and MOGA budget for CI.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import time

import jax

from repro.api import DesignRequest, DesignSession, Requirements
from repro.core import nsga2
from repro.serve.design_service import DesignService

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

REQUIREMENTS = Requirements(min_tops=0.5, min_snr_db=10.0)
REQUIREMENTS_FULL = Requirements(min_tops=0.5, min_snr_db=15.0)


def _requests(smoke: bool) -> list[DesignRequest]:
    sizes, seeds = ((4096,), (0, 1)) if smoke else \
        ((4096, 8192), (0, 1, 2))
    pop, gens = (48, 8) if smoke else (192, 60)
    reqs = REQUIREMENTS if smoke else REQUIREMENTS_FULL
    return [DesignRequest(array_size=s, seed=sd, pop_size=pop,
                          generations=gens, requirements=reqs, layout=True)
            for s in sizes for sd in seeds]


def _sequential(requests, sessions=None):
    """One fresh session per request: the pre-coalescing baseline."""
    sessions = sessions or [DesignSession() for _ in requests]
    arts = [ses.run(req) for ses, req in zip(sessions, requests)]
    return arts, sessions


def _coalesced(requests, service=None):
    service = service or DesignService(max_coalesce=len(requests))
    tickets = [service.submit(r) for r in requests]
    done = service.run()
    return [done[t] for t in tickets], service


def _timed(fn, *args):
    n0 = nsga2.TRACE_COUNTS["run_cell"]
    t0 = time.perf_counter()
    out, state = fn(*args)
    return out, state, time.perf_counter() - t0, \
        nsga2.TRACE_COUNTS["run_cell"] - n0


def run(smoke: bool = False) -> dict:
    requests = _requests(smoke)

    jax.clear_caches()
    seq, sessions, seq_cold, seq_traces = _timed(_sequential, requests)
    _, _, seq_warm, _ = _timed(_sequential, requests, sessions)
    seq_dispatches = sum(s.stats["explorer_dispatches"] for s in sessions)

    jax.clear_caches()
    bat, service, bat_cold, bat_traces = _timed(_coalesced, requests)
    _, _, bat_warm, _ = _timed(_coalesced, requests, service)

    artifacts_equal = all(a.summary() == b.summary()
                          for a, b in zip(seq, bat))
    return {
        "n_requests": len(requests),
        "requests": [r.to_dict() for r in requests],
        "smoke": smoke,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "sequential": {"cold_s": seq_cold, "warm_s": seq_warm,
                       "run_cell_traces": seq_traces,
                       "explorer_dispatches": seq_dispatches},
        "coalesced": {"cold_s": bat_cold, "warm_s": bat_warm,
                      "run_cell_traces": bat_traces,
                      "explorer_dispatches":
                          int(service.stats["explorer_dispatches"]),
                      "layout_bucket_dispatches":
                          int(service.stats["layout_dispatches"])},
        "coalesced_speedup_cold": seq_cold / bat_cold,
        "coalesced_speedup_warm": seq_warm / bat_warm,
        "artifacts_equal": artifacts_equal,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small request set / MOGA budget for CI")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_service.json"))
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    for side in ("sequential", "coalesced"):
        r = result[side]
        print(f"{side}: cold={r['cold_s']:.3f}s warm={r['warm_s']:.3f}s "
              f"traces={r['run_cell_traces']} "
              f"dispatches={r['explorer_dispatches']}")
    print(f"speedup cold={result['coalesced_speedup_cold']:.2f}x "
          f"warm={result['coalesced_speedup_warm']:.2f}x "
          f"artifacts_equal={result['artifacts_equal']} -> {args.out}")


if __name__ == "__main__":
    main()
