"""Fig. 8 reproduction: 16 kb ACIM layouts at three design specifications.

Paper values: (a) H=128, L=2, B=3 -> 3.277 TOPS, 4504 F^2/bit;
(b) balanced -> 0.813 TOPS, 2610 F^2/bit; (c) same throughput, +3 dB SNR,
2977 F^2/bit.  The exact (H, W, L) of (b)/(c) are not published; the
estimator pins them to (512,32,8,3) and (256,64,8,3) (see
core/constants.py [T1]), which reproduce throughput to <1% and area to
-19%/-5%.
"""
from __future__ import annotations

from repro.core import estimator
from repro.core.acim_spec import MacroSpec
from repro.eda.flow import generate_layout

PAPER = {
    "a": (MacroSpec(128, 128, 2, 3), 3.277, 4504.0),
    "b": (MacroSpec(512, 32, 8, 3), 0.813, 2610.0),
    "c": (MacroSpec(256, 64, 8, 3), 0.813, 2977.0),
}


def run() -> list[dict]:
    rows = []
    for tag, (spec, paper_tops, paper_area) in PAPER.items():
        lr = generate_layout(spec)
        m = lr.metrics()
        tops = float(estimator.throughput_ops(spec.h, spec.w, spec.l,
                                              spec.b_adc)) / 1e12
        snr = float(estimator.snr_total_db(spec.h, spec.l, spec.b_adc))
        rows.append({
            "point": tag, "h": spec.h, "w": spec.w, "l": spec.l,
            "b_adc": spec.b_adc,
            "tops": tops, "paper_tops": paper_tops,
            "tops_err": tops / paper_tops - 1.0,
            "est_area": m["estimator_area_f2_per_bit"],
            "layout_area": m["layout_area_f2_per_bit"],
            "paper_area": paper_area,
            "area_err_est": m["estimator_area_f2_per_bit"] / paper_area - 1.0,
            "snr_db": snr,
            "drc_clean": m["drc_clean"],
            "route_success": m["route_success"],
            "layout_seconds": m["elapsed_s"],
        })
    return rows


def main() -> None:
    for r in run():
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in r.items()))


if __name__ == "__main__":
    main()
