"""ACIM macro design-point spec (the paper's decision vector).

A design point is (H, W, L, B_ADC) under the Eq. 12 constraints:
    H * W == array_size          (user-given array size)
    H >= L                       (local array fits in a column)
    H / L >= 2**B_ADC            (CDAC needs 1:1:2:...:2^(B-1) cap groups)
All four quantities are powers of two in the synthesizable architecture
(SAR cap groups are binary-ratioed), which is how the explorer encodes
genes; the spec itself stores plain integers.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class MacroSpec:
    """One synthesizable ACIM macro instance."""

    h: int          # array height (cells per column)
    w: int          # array width (columns == parallel dot products)
    l: int          # local-array size (cells sharing one compute cap)
    b_adc: int      # SAR ADC precision in bits

    def __post_init__(self) -> None:
        if self.h * self.w <= 0:
            raise ValueError(f"bad array dims {self.h}x{self.w}")
        if self.l > self.h:
            raise ValueError(f"L={self.l} > H={self.h}")
        if self.h % self.l != 0:
            raise ValueError(f"L={self.l} must divide H={self.h}")
        if self.n_caps < (1 << self.b_adc):
            raise ValueError(
                f"H/L={self.n_caps} < 2^B_ADC={1 << self.b_adc}: "
                "not enough caps to form the binary CDAC groups")

    @property
    def array_size(self) -> int:
        return self.h * self.w

    @property
    def n_caps(self) -> int:
        """Compute caps per column == accumulation (dot-product) length N."""
        return self.h // self.l

    @property
    def n(self) -> int:
        return self.n_caps

    def sar_groups(self) -> list[int]:
        """CDAC grouping of the N compute caps: 1:1:2:...:2^(B-1), the
        remainder staying as plain compute caps behind the RBL switch
        (opened after redistribution to save conversion energy)."""
        groups = [1] + [1 << i for i in range(self.b_adc)]
        rest = self.n_caps - sum(groups)
        assert rest >= 0
        return groups + ([rest] if rest else [])

    def as_tuple(self) -> tuple[int, int, int, int]:
        return (self.h, self.w, self.l, self.b_adc)

    def name(self) -> str:
        return f"acim_h{self.h}_w{self.w}_l{self.l}_b{self.b_adc}"


def valid_spec(h: int, w: int, l: int, b_adc: int) -> bool:
    try:
        MacroSpec(h, w, l, b_adc)
        return True
    except ValueError:
        return False
