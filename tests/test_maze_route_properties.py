"""Shared property suite for every maze_route wavefront implementation.

The dispatch contract of `repro.kernels.maze_route.ops` promises four
bit-identical engines behind `wavefront_distance`:

  impl="bfs"       pure-Python deque BFS (the readable oracle)
  impl="ref"       jitted jnp fast-sweeping reference
  impl="kernel"    grid-batched Pallas Jacobi kernel (interpret off-TPU)
  impl="frontier"  host numpy frontier-bucketed engine

This file pins all four to each other on randomized grids (varied
shapes, obstacle density, multiple seeds) and on the adversarial edges:
fully-blocked grids, seeds sitting on obstacles (hub exception), empty
seed masks, and — for the Pallas path — grids straddling the TPU tile
boundary, where `ops.pad_blocked` must keep the pad region out of the
sweep (a free pad would let wavefronts tunnel around the real grid's
edge; see the pad-boundary regression class below).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.maze_route import (INF, wavefront_distance,
                                      wavefront_distance_bfs)
from repro.kernels.maze_route.ops import HOST_IMPLS, IMPLS

# The kernel pads to (8, 128) tiles and relaxes the full padded grid per
# Jacobi sweep — fine at test sizes, but each extra case costs real time
# under interpret mode, so the random sweeps keep H, W modest.
ALL_IMPLS = IMPLS


def _field(occ, seed, impl):
    return np.asarray(wavefront_distance(occ, seed, impl=impl))


def _assert_all_impls_match(occ, seed):
    """Every impl must equal the deque-BFS oracle exactly."""
    oracle = wavefront_distance_bfs(occ, seed)
    for impl in ALL_IMPLS:
        np.testing.assert_array_equal(
            _field(occ, seed, impl), oracle,
            err_msg=f"impl={impl!r} diverges from the BFS oracle")


def _random_case(rng, h, w, density, n_seeds):
    occ = rng.random((h, w)) < density
    seed = np.zeros((h, w), bool)
    flat = rng.choice(h * w, size=min(n_seeds, h * w), replace=False)
    seed[flat // w, flat % w] = True
    return occ, seed


class TestFourWayEquality:
    @pytest.mark.parametrize("case", range(12))
    def test_randomized_grids(self, case):
        rng = np.random.default_rng(1000 + case)
        h = int(rng.integers(2, 20))
        w = int(rng.integers(2, 24))
        density = float(rng.uniform(0.0, 0.65))
        n_seeds = int(rng.integers(1, 4))
        occ, seed = _random_case(rng, h, w, density, n_seeds)
        _assert_all_impls_match(occ, seed)

    def test_batched_grids(self):
        rng = np.random.default_rng(7)
        occ = rng.random((3, 9, 13)) < 0.3
        seed = np.zeros((3, 9, 13), bool)
        for b in range(3):
            seed[b, rng.integers(0, 9), rng.integers(0, 13)] = True
        oracle = wavefront_distance_bfs(occ, seed)
        for impl in ALL_IMPLS:
            np.testing.assert_array_equal(_field(occ, seed, impl), oracle)

    def test_fully_blocked_grid(self):
        occ = np.ones((6, 11), bool)
        seed = np.zeros((6, 11), bool)
        seed[2, 3] = True
        oracle = wavefront_distance_bfs(occ, seed)
        # The hub exception: a seed is distance 0 even when occupied,
        # but nothing expands out of it into blocked cells.
        assert oracle[2, 3] == 0
        assert (oracle == INF).sum() == 6 * 11 - 1
        _assert_all_impls_match(occ, seed)

    def test_seed_on_obstacle_does_not_expand_neighbours_through_it(self):
        # Seed on a blocked cell in a corridor: the seed itself reads 0,
        # but its free neighbours are still reached *around* it only.
        occ = np.zeros((3, 7), bool)
        occ[1, 3] = True
        seed = np.zeros((3, 7), bool)
        seed[1, 3] = True
        oracle = wavefront_distance_bfs(occ, seed)
        assert oracle[1, 3] == 0
        assert oracle[1, 2] == 1 and oracle[1, 4] == 1
        _assert_all_impls_match(occ, seed)

    def test_empty_seed_mask_is_all_inf(self):
        occ = np.zeros((5, 9), bool)
        seed = np.zeros((5, 9), bool)
        for impl in ALL_IMPLS:
            assert (_field(occ, seed, impl) == INF).all()

    def test_disconnected_components(self):
        occ = np.zeros((7, 7), bool)
        occ[:, 3] = True                      # full wall
        seed = np.zeros((7, 7), bool)
        seed[3, 0] = True
        oracle = wavefront_distance_bfs(occ, seed)
        assert (oracle[:, 4:] == INF).all()   # far side unreachable
        _assert_all_impls_match(occ, seed)


class TestPadBoundaryRegression:
    """`ops.pad_blocked` pads to (8, 128) tiles with *blocked* cells.

    These shapes straddle the tile boundary in every direction; if the
    pad region were free (or merely left out of the masking), a seed on
    the real grid's edge would leak a wavefront into the pad and around
    obstacles, producing finite distances where the oracle says INF and
    short-circuiting distances along the boundary rows/columns.
    """
    SHAPES = [(8, 128), (7, 128), (9, 128), (8, 127), (8, 129), (9, 129)]

    @pytest.mark.parametrize("shape", SHAPES)
    def test_kernel_matches_oracle_at_tile_boundary(self, shape):
        h, w = shape
        rng = np.random.default_rng(h * 1000 + w)
        occ = rng.random((h, w)) < 0.25
        seed = np.zeros((h, w), bool)
        seed[h - 1, w - 1] = True             # seed on the pad boundary
        oracle = wavefront_distance_bfs(occ, seed)
        np.testing.assert_array_equal(_field(occ, seed, "kernel"), oracle)
        np.testing.assert_array_equal(_field(occ, seed, "frontier"), oracle)

    def test_wavefront_cannot_tunnel_through_pad(self):
        # A wall along the last real column, broken nowhere: cells past
        # it must be unreachable even though the pad region lies just
        # beyond the wall and would offer a bypass if traversable.
        h, w = 8, 126                         # pads to (8, 128): 2 pad cols
        occ = np.zeros((h, w), bool)
        occ[:, w - 2] = True
        seed = np.zeros((h, w), bool)
        seed[4, 0] = True
        for impl in ALL_IMPLS:
            out = _field(occ, seed, impl)
            assert (out[:, w - 1] == INF).all(), \
                f"impl={impl!r} tunnelled around the wall via the pad"

    def test_edge_seed_distances_exact_on_padded_rows(self):
        # Free grid, seed in a corner: distances along the padded edge
        # rows/cols are pure Manhattan — any pad participation would
        # only ever show up here first.
        h, w = 9, 127
        occ = np.zeros((h, w), bool)
        seed = np.zeros((h, w), bool)
        seed[0, 0] = True
        yy, xx = np.mgrid[:h, :w]
        manhattan = (yy + xx).astype(np.int64)
        for impl in ALL_IMPLS:
            np.testing.assert_array_equal(_field(occ, seed, impl), manhattan)


class TestDispatchContract:
    def test_unknown_impl_rejected(self):
        occ = np.zeros((4, 4), bool)
        seed = np.zeros((4, 4), bool)
        seed[0, 0] = True
        with pytest.raises(ValueError, match="impl must be one of"):
            wavefront_distance(occ, seed, impl="dijkstra")

    @pytest.mark.parametrize("impl", HOST_IMPLS)
    def test_host_impls_refuse_tracing(self, impl):
        @jax.jit
        def traced(occ, seed):
            return wavefront_distance(occ, seed, impl=impl)

        occ = jnp.zeros((4, 4), bool)
        seed = jnp.zeros((4, 4), bool).at[0, 0].set(True)
        with pytest.raises(TypeError, match="host engine"):
            traced(occ, seed)

    def test_host_default_is_frontier_and_returns_numpy(self):
        # Concrete arrays off-TPU dispatch to the frontier engine, which
        # returns numpy (callers read the field on host).
        if jax.default_backend() == "tpu":
            pytest.skip("host dispatch path is the off-TPU default")
        occ = np.zeros((5, 6), bool)
        seed = np.zeros((5, 6), bool)
        seed[2, 2] = True
        out = wavefront_distance(occ, seed)
        assert isinstance(out, np.ndarray)
        np.testing.assert_array_equal(out, wavefront_distance_bfs(occ, seed))

    def test_use_kernel_legacy_spelling(self):
        occ = np.zeros((6, 9), bool)
        seed = np.zeros((6, 9), bool)
        seed[3, 1] = True
        oracle = wavefront_distance_bfs(occ, seed)
        with pytest.warns(DeprecationWarning, match="use_kernel"):
            out_ref = wavefront_distance(occ, seed, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(out_ref), oracle)
        with pytest.warns(DeprecationWarning, match="use_kernel"):
            out_kernel = wavefront_distance(occ, seed, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(out_kernel), oracle)
