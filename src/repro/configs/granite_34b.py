"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — GPT-BigCode-style code model.  [arXiv:2405.04324; hf]

GPT-BigCode lineage: LayerNorm, learned absolute positions, *non-gated*
GELU MLP, MQA, biases on attention and MLP.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128,
    norm="layernorm", act="gelu_tanh", mlp_gated=False,
    attn_bias=True, mlp_bias=True, pos="learned",
    source="arXiv:2405.04324; hf",
)

REDUCED = dataclasses.replace(
    CONFIG, name="granite-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab=512,
    head_dim=16,
)
