"""Stage-span tracing: a lock-cheap recorder and a versioned trace export.

`SpanRecorder` collects monotonic-clock spans (name + category plus the
service's natural tags: batch sequence number, layout-bucket key,
worker id) from the admission pump, the four stage workers, the layout
pool, and the fault paths of `repro.serve.design_service` — and from
`repro.api.session`'s stage functions when a recorder is attached to
the session.  The recorder is deliberately dumb: `begin()`/`end()`
each take one short lock to append to a list, the clock is read
*outside* the lock (callers that already read `time.monotonic()` for
their busy clocks pass it in via `at=`, so span edges and occupancy
clocks agree exactly instead of within-jitter), and a recorder that is
simply not attached costs the service one `is None` branch per event.

`TraceExport` is the frozen read side: a schema-stamped snapshot of
every finished span (plus still-open spans flushed at export time —
a mid-batch export must show in-progress stage time, not zero).  It
serializes two ways:

  * `to_dict()`/`to_json()` — a Chrome-trace-compatible event list
    (`traceEvents`, `ph:"X"` complete events and `ph:"i"` instants,
    microsecond timestamps relative to the recorder epoch) that loads
    directly in `chrome://tracing` / Perfetto, under a top-level
    `schema` stamp (`TRACE_SCHEMA`) so CI and future readers can
    detect skew;
  * `gantt()` — the per-batch stage Gantt: batch sequence number ->
    ordered span rows, the replayable visual timeline of one serve run.

`stage_totals()` sums finished+flushed span durations per stage name,
which is what ties the trace back to the service's busy/overlap
clocks: with a single-occupant stage the two are computed from the
very same clock reads and agree to float precision
(`tests/test_telemetry.py`); a K-wide layout pool's busy *clock* is
the refcounted union while the span *sum* counts worker-seconds, so
sum >= clock there by construction.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import time

from repro.runtime.lock_sanitizer import make_lock

# Bump on any change to the exported span/event shape.
TRACE_SCHEMA = 1


@dataclasses.dataclass
class Span:
    """One unit of traced work.  `end_s` is None while the span is open;
    timestamps are raw `time.monotonic()` readings (the export
    re-bases them on the recorder epoch)."""

    __slots__ = ("name", "cat", "start_s", "end_s", "batch", "bucket",
                 "worker", "args")

    name: str
    cat: str
    start_s: float
    end_s: float | None
    batch: int | None
    bucket: str | None
    worker: str | None
    args: dict

    @property
    def duration_s(self) -> float:
        return 0.0 if self.end_s is None else self.end_s - self.start_s


class SpanRecorder:
    """Thread-safe, append-only span collector (see module docstring).

    `clock` is injectable for tests; every public entry point accepts
    `at=` so a caller can share one clock read between its own
    accounting and the span edge."""

    def __init__(self, *, clock=time.monotonic):
        self._clock = clock
        self._lock = make_lock("SpanRecorder._lock")
        self.epoch = clock()
        self._spans: list[Span] = []     # finished, in end order
        self._open: dict[int, Span] = {}  # id(span) -> span

    def begin(self, name: str, *, cat: str = "", batch: int | None = None,
              bucket=None, worker: str | None = None,
              at: float | None = None, **args) -> Span:
        span = Span(name=name, cat=cat,
                    start_s=self._clock() if at is None else at,
                    end_s=None, batch=batch,
                    bucket=None if bucket is None else str(bucket),
                    worker=worker, args=args)
        with self._lock:
            self._open[id(span)] = span
        return span

    def end(self, span: Span, *, at: float | None = None) -> Span:
        span.end_s = self._clock() if at is None else at
        with self._lock:
            self._open.pop(id(span), None)
            self._spans.append(span)
        return span

    @contextlib.contextmanager
    def span(self, name: str, **tags):
        s = self.begin(name, **tags)
        try:
            yield s
        finally:
            self.end(s)

    def instant(self, name: str, *, cat: str = "", batch: int | None = None,
                bucket=None, worker: str | None = None,
                at: float | None = None, **args) -> Span:
        """A zero-duration event (controller decisions, retries, sheds):
        recorded closed, exported as a Chrome `ph:"i"` instant."""
        t = self._clock() if at is None else at
        span = Span(name=name, cat=cat, start_s=t, end_s=t, batch=batch,
                    bucket=None if bucket is None else str(bucket),
                    worker=worker, args=args)
        with self._lock:
            self._spans.append(span)
        return span

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    def export(self, *, flush_open: bool = True) -> "TraceExport":
        """Snapshot every finished span; still-open spans are flushed at
        the current clock (tagged `open=True` in their args) so a
        mid-run export reports in-progress work instead of dropping it.
        The recorder keeps recording — exporting is read-only."""
        now = self._clock()
        with self._lock:
            spans = list(self._spans)
            if flush_open:
                for s in self._open.values():
                    spans.append(Span(name=s.name, cat=s.cat,
                                      start_s=s.start_s, end_s=now,
                                      batch=s.batch, bucket=s.bucket,
                                      worker=s.worker,
                                      args={**s.args, "open": True}))
        spans.sort(key=lambda s: s.start_s)
        return TraceExport(epoch=self.epoch, spans=spans)


@dataclasses.dataclass(frozen=True)
class TraceExport:
    """A frozen, schema-stamped snapshot of one recorder's spans."""

    epoch: float
    spans: list[Span]
    schema: int = TRACE_SCHEMA

    def to_events(self) -> list[dict]:
        """Chrome-trace event list: `ph:"X"` complete events (instants
        as `ph:"i"`), microseconds since the recorder epoch, `tid`
        rows by worker (or category) so Perfetto lays the pipeline out
        as a Gantt without any configuration."""
        events = []
        for s in self.spans:
            args = dict(s.args)
            if s.batch is not None:
                args["batch"] = s.batch
            if s.bucket is not None:
                args["bucket"] = s.bucket
            ev = {"name": s.name, "cat": s.cat or "trace",
                  "ts": (s.start_s - self.epoch) * 1e6,
                  "pid": 0, "tid": s.worker or s.cat or s.name,
                  "args": args}
            if s.end_s is not None and s.end_s > s.start_s:
                ev["ph"] = "X"
                ev["dur"] = (s.end_s - s.start_s) * 1e6
            else:
                ev["ph"] = "i"
                ev["s"] = "g"
            events.append(ev)
        return events

    def to_dict(self) -> dict:
        return {"schema": self.schema,
                "epoch_monotonic_s": self.epoch,
                "displayTimeUnit": "ms",
                "traceEvents": self.to_events()}

    def to_json(self, path=None) -> str:
        """The Chrome-trace JSON text; with `path`, also atomically
        written there (via `repro.telemetry.export.atomic_write_json`)."""
        d = self.to_dict()
        if path is not None:
            from repro.telemetry.export import atomic_write_json
            atomic_write_json(d, path)
        return json.dumps(d, indent=1)

    def gantt(self) -> dict:
        """The per-batch stage Gantt: batch seq -> ordered rows of
        `{name, cat, t0_s, t1_s, bucket, worker}` (epoch-relative
        seconds).  Spans with no batch tag (controller decisions, the
        admission pump's idle bookkeeping) land under batch `null` when
        serialized — `-1` here."""
        rows: dict[int, list[dict]] = {}
        for s in self.spans:
            rows.setdefault(-1 if s.batch is None else s.batch, []).append(
                {"name": s.name, "cat": s.cat,
                 "t0_s": s.start_s - self.epoch,
                 "t1_s": None if s.end_s is None else s.end_s - self.epoch,
                 "bucket": s.bucket, "worker": s.worker, "args": s.args})
        for batch in rows.values():
            batch.sort(key=lambda r: r["t0_s"])
        return {"schema": self.schema, "batches": rows}

    def stage_totals(self, cat: str = "stage") -> dict[str, float]:
        """Summed span duration per name within `cat` — the per-stage
        span sums the acceptance check compares with the service's
        busy clocks."""
        totals: dict[str, float] = {}
        for s in self.spans:
            if s.cat == cat:
                totals[s.name] = totals.get(s.name, 0.0) + s.duration_s
        return totals

    @classmethod
    def from_dict(cls, d: dict) -> "TraceExport":
        schema = d.get("schema")
        if schema != TRACE_SCHEMA:
            raise ValueError(f"trace schema {schema} != supported "
                             f"{TRACE_SCHEMA}; re-export the trace")
        epoch = d.get("epoch_monotonic_s", 0.0)
        spans = []
        for ev in d.get("traceEvents", ()):
            t0 = epoch + ev["ts"] / 1e6
            dur = ev.get("dur")
            args = dict(ev.get("args", {}))
            batch = args.pop("batch", None)
            bucket = args.pop("bucket", None)
            tid = ev.get("tid")
            spans.append(Span(
                name=ev["name"], cat=ev.get("cat", ""),
                start_s=t0, end_s=t0 if dur is None else t0 + dur / 1e6,
                batch=batch, bucket=bucket,
                worker=tid if isinstance(tid, str) else None, args=args))
        return cls(epoch=epoch, spans=spans)

    @classmethod
    def from_json(cls, path) -> "TraceExport":
        with open(path) as f:
            return cls.from_dict(json.load(f))
