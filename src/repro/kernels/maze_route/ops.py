"""Public maze_route entry point: shape handling, padding, impl selection.

`wavefront_distance` accepts a single (H, W) grid or a batched (B, H, W)
stack and returns int32 BFS distances (`INF` = unreachable).  Padding to
the TPU tile multiples (sublane 8, lane 128) uses *blocked* cells, so the
pad region is unreachable and distances inside the real grid are
untouched; different-sized grids in one batch are handled the same way by
the caller (`repro.eda.batched_flow` blocks every cell beyond a spec's
own grid bounds).

Implementation selection differs from `pareto_dom` on purpose: this op
sits on the *default* layout path (every `route()` call), so on
non-TPU backends it runs the jitted jnp reference — Pallas interpret
mode re-enters Python per while-loop step, which is fine for tests but
not for a hot path.  On TPU the grid-batched Pallas kernel is used.
Tests force the kernel with ``use_kernel=True`` (interpret mode off-TPU)
and assert it matches the reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.maze_route.kernel import wavefront_kernel
from repro.kernels.maze_route.ref import INF, wavefront_distance_ref

_ref_jit = jax.jit(wavefront_distance_ref)


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def wavefront_distance(occ: jax.Array, seed: jax.Array, *,
                       use_kernel: bool | None = None,
                       interpret: bool | None = None) -> jax.Array:
    """BFS distance field(s) for the Lee maze router.

    occ, seed: (H, W) or (B, H, W) bool.  Returns int32 distances of the
    same shape; seeds are 0 (even if occupied), blocked cells `INF`.
    """
    occ = jnp.asarray(occ)
    seed = jnp.asarray(seed)
    squeeze = occ.ndim == 2
    if squeeze:
        occ, seed = occ[None], seed[None]
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if not use_kernel:
        out = _ref_jit(occ, seed)
        return out[0] if squeeze else out
    if interpret is None:
        interpret = _should_interpret()
    _, h, w = occ.shape
    ph, pw = (-h) % 8, (-w) % 128
    pad = [(0, 0), (0, ph), (0, pw)]
    occ_p = jnp.pad(occ.astype(jnp.int8), pad, constant_values=1)
    seed_p = jnp.pad(seed.astype(jnp.int8), pad, constant_values=0)
    out = wavefront_kernel(occ_p, seed_p, interpret=interpret)[:, :h, :w]
    return out[0] if squeeze else out
