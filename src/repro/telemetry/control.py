"""Feedback control plane: adaptive coalescing + layout-pool autoscaling.

The service exposes two throughput/latency knobs that PRs 4-6 left
static: the admission pump's `coalesce_window_s` (how long to hold the
oldest queued request hoping more arrive to share its dispatch) and
the layout pool width `layout_workers`.  A fixed window is wrong in
both directions — too wide, and a lone request eats the whole window
as pure latency; too narrow, and a burst fragments into per-request
dispatches that each pay full exploration.  `FeedbackController`
closes the loop from *observed* windowed metrics:

  * **arrival-rate EMA** -> coalescing window.  The window that
    gathers one full batch is `target_batch / rate`; the controller
    tracks an EMA of the arrival rate (counted from the service's
    monotonic submission counter, so missed ticks lose nothing) and
    eases the live window toward that ideal between
    `[min_window_s, max_window_s]`.  Bursty traffic widens the window
    while the burst lasts; an idle or trickling queue narrows it to
    the latency floor.
  * **layout backlog + occupancy -> pool width.**  Sustained backlog
    above `scale_up_backlog` buckets per worker grows the pool by one
    (up to `max_workers`); a drained queue with idle workers shrinks
    it (down to `min_workers`).  Both directions require
    `hysteresis_ticks` *consecutive* agreeing observations, so a
    single bucket burst or momentary idle gap cannot flap the pool.

The controller is deliberately pure and clocked from outside
(`tick(now, ...)`): the service calls it from the admission pump loop
(bounded waits guarantee a tick at least every `tick_interval_s` even
on an idle queue), and tests drive it with synthetic clocks — no
sleeps, no threads of its own.  Every actuating decision is recorded
as a `cat="control"` instant span on the attached recorder AND kept in
`decisions`, so control behaviour is auditable after the fact: the
Gantt shows *why* the window moved next to the batches it affected.
"""
from __future__ import annotations

import dataclasses
import time

from repro.telemetry.spans import SpanRecorder


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Bounds and cadence of the feedback loop.  Defaults are sized for
    the design-service bench workloads; `target_batch` is filled from
    the service's `max_coalesce` when left `None`."""

    min_window_s: float = 0.01
    max_window_s: float = 0.5
    target_batch: int | None = None
    window_smoothing: float = 0.5     # EMA weight of the OLD window
    rate_decay: float = 0.5           # EMA weight of the old arrival rate
    min_workers: int = 1
    max_workers: int = 1              # == min: autoscaling disabled
    scale_up_backlog: float = 2.0     # queued buckets per worker to grow
    hysteresis_ticks: int = 3
    tick_interval_s: float = 0.05

    def __post_init__(self):
        if not 0 < self.min_window_s <= self.max_window_s:
            raise ValueError("need 0 < min_window_s <= max_window_s")
        if not 1 <= self.min_workers <= self.max_workers:
            raise ValueError("need 1 <= min_workers <= max_workers")
        if not 0.0 <= self.window_smoothing < 1.0:
            raise ValueError("window_smoothing must be in [0, 1)")
        if self.hysteresis_ticks < 1:
            raise ValueError("hysteresis_ticks must be >= 1")


@dataclasses.dataclass(frozen=True)
class ControlDecision:
    """One actuation: the knob values the service should apply now."""

    at_s: float
    window_s: float
    workers: int
    arrival_rate: float               # the EMA the decision was based on
    reason: str


class FeedbackController:
    """Windowed-metrics consumer driving the two admission knobs (see
    module docstring).  One instance per service; not thread-safe by
    itself — the admission pump is its single caller."""

    def __init__(self, config: ControllerConfig | None = None, *,
                 recorder: SpanRecorder | None = None):
        self.config = config or ControllerConfig()
        self.recorder = recorder
        self.arrival_rate = 0.0       # requests/s EMA
        self.decisions: list[ControlDecision] = []
        self._last_t: float | None = None
        self._last_arrivals = 0
        self._up_ticks = 0
        self._down_ticks = 0

    def tick(self, now: float | None = None, *, queue_depth: int,
             arrivals_total: int, layout_backlog: int, inflight_buckets: int,
             layout_workers: int, window_s: float
             ) -> ControlDecision | None:
        """Consume one observation window; returns the decision to apply
        or `None` when nothing should change (first tick, sub-interval
        tick, or knobs already where the policy wants them).

        `arrivals_total` is the service's monotonic submission count —
        deltas are taken here, so a delayed tick still sees every
        arrival.  `layout_backlog` counts buckets waiting in the layout
        queue; `inflight_buckets` the ones running in the pool."""
        cfg = self.config
        if now is None:
            now = time.monotonic()
        if self._last_t is None:
            # Baseline establishes the time origin only: arrivals that
            # raced ahead of the first tick still count in the first
            # observation window (the pump may start ticking after the
            # tenants have already submitted).
            self._last_t = now
            return None
        dt = now - self._last_t
        if dt < cfg.tick_interval_s:
            return None
        arrived = arrivals_total - self._last_arrivals
        self._last_t, self._last_arrivals = now, arrivals_total
        rate = arrived / dt
        self.arrival_rate = (cfg.rate_decay * self.arrival_rate
                             + (1.0 - cfg.rate_decay) * rate)

        # -- coalescing window: ease toward target_batch / rate --------
        target = max(1, cfg.target_batch or 1)
        if self.arrival_rate > 1e-9:
            desired = target / self.arrival_rate
        else:
            desired = cfg.min_window_s   # idle: latency floor
        desired = min(max(desired, cfg.min_window_s), cfg.max_window_s)
        new_window = (cfg.window_smoothing * window_s
                      + (1.0 - cfg.window_smoothing) * desired)
        new_window = min(max(new_window, cfg.min_window_s),
                         cfg.max_window_s)

        # -- pool width: backlog pressure with hysteresis --------------
        new_workers = layout_workers
        reasons = []
        busy_frac = inflight_buckets / max(layout_workers, 1)
        if layout_backlog >= cfg.scale_up_backlog * layout_workers \
                and layout_workers < cfg.max_workers:
            self._up_ticks += 1
            self._down_ticks = 0
            if self._up_ticks >= cfg.hysteresis_ticks:
                new_workers = layout_workers + 1
                self._up_ticks = 0
                reasons.append(
                    f"backlog {layout_backlog} >= "
                    f"{cfg.scale_up_backlog:g}/worker: grow pool")
        elif layout_backlog == 0 and busy_frac == 0.0 \
                and layout_workers > cfg.min_workers:
            self._down_ticks += 1
            self._up_ticks = 0
            if self._down_ticks >= cfg.hysteresis_ticks:
                new_workers = layout_workers - 1
                self._down_ticks = 0
                reasons.append("pool idle: shrink")
        else:
            self._up_ticks = self._down_ticks = 0

        window_moved = abs(new_window - window_s) > 1e-3 * window_s
        if not window_moved and new_workers == layout_workers:
            return None
        if window_moved:
            reasons.insert(0, f"rate {self.arrival_rate:.2f}/s -> "
                              f"window {new_window:.3f}s")
        decision = ControlDecision(
            at_s=now, window_s=new_window if window_moved else window_s,
            workers=new_workers, arrival_rate=self.arrival_rate,
            reason="; ".join(reasons))
        self.decisions.append(decision)
        if self.recorder is not None:
            self.recorder.instant(
                "control", cat="control", at=now,
                window_s=decision.window_s, workers=decision.workers,
                arrival_rate=round(self.arrival_rate, 4),
                queue_depth=queue_depth, layout_backlog=layout_backlog,
                reason=decision.reason)
        return decision
