from repro.kernels.maze_route.frontier import wavefront_distance_frontier
from repro.kernels.maze_route.ops import INF, pad_blocked, wavefront_distance
from repro.kernels.maze_route.oracle import wavefront_distance_bfs
from repro.kernels.maze_route.ref import wavefront_distance_ref

__all__ = ["INF", "pad_blocked", "wavefront_distance",
           "wavefront_distance_bfs", "wavefront_distance_frontier",
           "wavefront_distance_ref"]
