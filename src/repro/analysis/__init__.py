"""House-rules static analysis: trace purity, lock discipline, schema
drift.  CLI front end: ``tools/repro_lint.py`` (CI gate); rule catalog
and suppression syntax: ``docs/static_analysis.md``.
"""
from repro.analysis.core import (Finding, Module, RULES,
                                 apply_suppressions, load_tree)
from repro.analysis import lock_discipline, schema_drift, trace_purity

__all__ = ["Finding", "Module", "RULES", "apply_suppressions",
           "load_tree", "run_all", "lock_discipline", "schema_drift",
           "trace_purity"]


def run_all(root, modules=None, *, strict=False):
    """Run every pass over ``root`` and return (kept, suppressed)."""
    import pathlib

    root = pathlib.Path(root)
    if modules is None:
        modules = load_tree(root)
    findings = []
    findings.extend(trace_purity.run(modules))
    findings.extend(lock_discipline.run(modules))
    findings.extend(schema_drift.run(modules, root=root))
    return apply_suppressions(findings, modules, strict=strict)
