"""Attention mixers: GQA/MQA/MHA (+bias, +qk_norm) and DeepSeek MLA.

Each mixer provides `init_*`, a full-sequence forward (training / prefill)
and a single-token decode step against a preallocated cache.  Shapes follow
(B, S, H, Dh); caches are (B, KV, S_max, Dh) so the sequence axis can be
sharded over the "model" mesh axis for long-context decode (flash-decoding
style split-KV: GSPMD turns the softmax reductions into per-shard partials
plus a small cross-shard combine).

MLA decode uses the absorbed formulation (cache = compressed latent c_kv +
shared rope key), which shrinks the 32k-decode cache by ~`n_heads *
head_dim / (kv_lora + rope_dim)` vs a GQA cache — this is why
deepseek-v2-lite's decode_32k cell is memory-cheap despite MHA-like heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MLAConfig
from repro.models import common
from repro.models.common import NEG_INF, apply_rope, dense_init
from repro.parallel.axes import logical

Array = jax.Array


# ---------------------------------------------------------------------------
# GQA / MQA / MHA
# ---------------------------------------------------------------------------
def init_attention(key: Array, cfg: ArchConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * dh)),
        "wk": dense_init(ks[1], (d, kv * dh)),
        "wv": dense_init(ks[2], (d, kv * dh)),
        "wo": dense_init(ks[3], (h * dh, d)),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * dh,), jnp.float32)
        p["bk"] = jnp.zeros((kv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((kv * dh,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = common.init_rmsnorm(dh)
        p["k_norm"] = common.init_rmsnorm(dh)
    return p


def _project_qkv(p: dict, x: Array, cfg: ArchConfig, positions: Array):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.attn_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kv, dh)
    v = v.reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = common.rmsnorm(p["q_norm"], q)
        k = common.rmsnorm(p["k_norm"], k)
    if cfg.pos == "rope":
        q = apply_rope(q.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
    q = logical(q, "batch", "qseq", "heads", "head_dim")
    k = logical(k, "batch", "kvseq", "kv_heads", "head_dim")
    v = logical(v, "batch", "kvseq", "kv_heads", "head_dim")
    return q, k, v


def _padded_heads() -> int | None:
    from repro.parallel.axes import current_rules

    ctx = current_rules()
    if ctx is None:
        return None
    return ctx[1].get("padded_heads")


def attention_fwd(p: dict, x: Array, cfg: ArchConfig, *, mask: Array,
                  positions: Array) -> Array:
    """Full-sequence attention.  mask: (S, T) bool (True = attend).

    When the sharding rules request `padded_heads` (head count not
    divisible by TP, e.g. arctic's 56 on a 16-way axis), attention runs in
    merged repeat-KV form with H zero-padded to the next TP multiple: the
    +|pad|/H extra FLOPs buy a shardable head axis and eliminate GSPMD's
    involuntary full rematerialization of the bwd score tensors.
    """
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // kv
    q, k, v = _project_qkv(p, x, cfg, positions)
    hp = _padded_heads()
    if hp and hp > h:
        rep = hp // kv
        # pad per KV group so q head j maps to kv head j // rep
        qg = q.reshape(b, s, kv, g, dh)
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, rep - g), (0, 0)))
        qm = qg.reshape(b, s, hp, dh)
        kx = jnp.repeat(k, rep, axis=2)
        vx = jnp.repeat(v, rep, axis=2)
        qm = logical(qm, "batch", "qseq", "merged_heads", "head_dim")
        kx = logical(kx, "batch", "kvseq", "merged_heads", "head_dim")
        vx = logical(vx, "batch", "kvseq", "merged_heads", "head_dim")
        scores = jnp.einsum("bshd,bthd->bhst", qm, kx) / np.sqrt(dh)
        scores = jnp.where(mask[None, None], scores.astype(jnp.float32), NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, vx)
        out = out.reshape(b, s, kv, rep, dh)[:, :, :, :g, :].reshape(b, s, h * dh)
        return out @ p["wo"].astype(x.dtype)
    qg = q.reshape(b, s, kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / np.sqrt(dh)
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v).reshape(b, s, h * dh)
    return out @ p["wo"].astype(x.dtype)


def attention_fwd_blockwise(p: dict, x: Array, cfg: ArchConfig, *,
                            positions: Array, kv_block: int = 1024,
                            prefix_len: int = 0) -> Array:
    """Flash-style online-softmax attention over KV blocks (pure JAX).

    Never materializes the (S, S) score matrix — required for the 32k+
    prefill shapes.  Mask: causal, plus bidirectional over the first
    `prefix_len` positions (PaliGemma prefix-LM).  Forward path for
    prefill/serving; the Pallas kernel (`repro.kernels.flash_attention`)
    implements the same math for TPU with this as its oracle partner.
    """
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // kv
    q, k, v = _project_qkv(p, x, cfg, positions)
    return _blockwise_core(q.reshape(b, s, kv, g, dh), k, v,
                           kv_block=kv_block, prefix_len=prefix_len,
                           out_dtype=x.dtype) .reshape(b, s, h * dh) \
        @ p["wo"].astype(x.dtype)


def _blockwise_core(qg: Array, k: Array, v: Array, *, kv_block: int,
                    prefix_len: int, out_dtype) -> Array:
    """qg: (B,S,KV,G,Dh); k/v: (B,T,KV,Dh).  Returns (B,S,KV,G,Dh)."""
    b, s, kvh, g, dh = qg.shape
    t = k.shape[1]
    kv_block = min(kv_block, t)
    while t % kv_block:           # e.g. 32768 + 256 patches -> block 256
        kv_block //= 2
    nblk = t // kv_block
    scale = 1.0 / np.sqrt(dh)
    q_idx = jnp.arange(s)

    kb = jnp.moveaxis(k.reshape(b, nblk, kv_block, kvh, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nblk, kv_block, kvh, dh), 1, 0)

    def step(carry, inp):
        acc, m, l = carry
        jblk, kj, vj = inp
        k_idx = jblk * kv_block + jnp.arange(kv_block)
        mask = (k_idx[None, :] <= q_idx[:, None]) | (
            (q_idx[:, None] < prefix_len) & (k_idx[None, :] < prefix_len))
        sc = jnp.einsum("bskgd,btkd->bskgt", qg, kj) * scale
        sc = jnp.where(mask[None, :, None, None, :], sc.astype(jnp.float32),
                       NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p_ = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p_, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", p_.astype(qg.dtype), vj).astype(jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, s, kvh, g, dh), jnp.float32)
    m0 = jnp.full((b, s, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, kvh, g), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  (jnp.arange(nblk), kb, vb))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(out_dtype)


def mla_fwd_blockwise(p: dict, x: Array, cfg: ArchConfig, *,
                      positions: Array, kv_block: int = 1024) -> Array:
    """Blockwise MLA prefill via expansion to per-head keys
    k' = [k_nope, k_rope(broadcast)], q' = [q_nope, q_rope]."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c = common.rmsnorm(p["kv_norm"], x @ p["w_dkv"].astype(x.dtype))
    k_nope = (c @ p["w_uk"].astype(x.dtype)).reshape(b, s, h, m.nope_dim)
    v = (c @ p["w_uv"].astype(x.dtype)).reshape(b, s, h, m.v_dim)
    k_rope = apply_rope(x @ p["w_kr"].astype(x.dtype), positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], -1)[:, :, :, None, :]  # (B,S,H,1,dq)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (b, s, h, m.rope_dim))], -1)
    # pad v to k's head dim so one blockwise core serves both reductions
    dq = m.nope_dim + m.rope_dim
    # scale inside core uses sqrt(dq) == MLA's 1/sqrt(nope+rope)  ✓
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dq - m.v_dim)))
    out = _blockwise_core(q, k, vpad, kv_block=kv_block, prefix_len=0,
                          out_dtype=x.dtype)
    out = out[:, :, :, 0, : m.v_dim].reshape(b, s, h * m.v_dim)
    return out @ p["wo"].astype(x.dtype)


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (batch, kv, max_seq, dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(p: dict, x_t: Array, cache: dict, pos: Array,
                     cfg: ArchConfig) -> tuple[Array, dict]:
    """One-token decode.  x_t: (B, D); cache k/v: (B, KV, S, Dh); pos: scalar.

    The score/value reductions run over the cache sequence axis, which the
    sharding policy may place on the "model" mesh axis (split-KV decode).
    """
    b, d = x_t.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // kv
    x = x_t[:, None, :]
    q, k, v = _project_qkv(p, x, cfg, jnp.full((1,), pos, jnp.int32))
    # k[:, 0]: (B, KV, Dh) -> written at cache[:, :, pos, :]
    k_cache = jax.lax.dynamic_update_index_in_dim(
        cache["k"], k[:, 0].astype(cache["k"].dtype), pos, axis=2)
    v_cache = jax.lax.dynamic_update_index_in_dim(
        cache["v"], v[:, 0].astype(cache["v"].dtype), pos, axis=2)
    qh = q[:, 0].reshape(b, kv, g, dh)
    scores = jnp.einsum("bkgd,bktd->bkgt", qh, k_cache.astype(qh.dtype)) / np.sqrt(dh)
    t = k_cache.shape[2]
    valid = jnp.arange(t) <= pos
    scores = jnp.where(valid[None, None, None], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x_t.dtype)
    out = jnp.einsum("bkgt,bktd->bkgd", probs, v_cache.astype(probs.dtype))
    out = out.reshape(b, h * dh) @ p["wo"].astype(x_t.dtype)
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed-KV latent attention, decoupled RoPE
# ---------------------------------------------------------------------------
def init_mla(key: Array, cfg: ArchConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * (m.nope_dim + m.rope_dim))),
        "w_dkv": dense_init(ks[1], (d, m.kv_lora)),
        "w_kr": dense_init(ks[2], (d, m.rope_dim)),
        "kv_norm": common.init_rmsnorm(m.kv_lora),
        "w_uk": dense_init(ks[3], (m.kv_lora, h * m.nope_dim)),
        "w_uv": dense_init(ks[4], (m.kv_lora, h * m.v_dim)),
        "wo": dense_init(ks[5], (h * m.v_dim, d)),
    }


def _mla_q(p: dict, x: Array, cfg: ArchConfig, positions: Array):
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, m.nope_dim + m.rope_dim)
    q_nope, q_rope = q[..., : m.nope_dim], q[..., m.nope_dim:]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
    return q_nope, q_rope


def mla_fwd(p: dict, x: Array, cfg: ArchConfig, *, mask: Array,
            positions: Array) -> Array:
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    scale = 1.0 / np.sqrt(m.nope_dim + m.rope_dim)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c = common.rmsnorm(p["kv_norm"], x @ p["w_dkv"].astype(x.dtype))
    k_nope = (c @ p["w_uk"].astype(x.dtype)).reshape(b, s, h, m.nope_dim)
    v = (c @ p["w_uv"].astype(x.dtype)).reshape(b, s, h, m.v_dim)
    k_rope = apply_rope(x @ p["w_kr"].astype(x.dtype), positions, cfg.rope_theta)
    scores = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
              + jnp.einsum("bshd,btd->bhst", q_rope, k_rope)) * scale
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(b, s, h * m.v_dim)
    return out @ p["wo"].astype(x.dtype)


def init_mla_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    m: MLAConfig = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_seq, m.kv_lora), dtype),
            "k_rope": jnp.zeros((batch, max_seq, m.rope_dim), dtype)}


def mla_decode(p: dict, x_t: Array, cache: dict, pos: Array,
               cfg: ArchConfig) -> tuple[Array, dict]:
    """Absorbed MLA decode: scores/value work entirely in the 512-d latent.

    q_abs[b,h,c] = sum_d q_nope[b,h,d] * w_uk[c, h*d]  (absorb W_uk into q)
    score[t]     = (q_abs . c_kv[t] + q_rope . k_rope[t]) * scale
    out_latent   = sum_t p[t] c_kv[t];  out_h = out_latent @ W_uv_h
    """
    m: MLAConfig = cfg.mla
    b, _ = x_t.shape
    h = cfg.n_heads
    scale = 1.0 / np.sqrt(m.nope_dim + m.rope_dim)
    x = x_t[:, None, :]
    posv = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg, posv)
    c_t = common.rmsnorm(p["kv_norm"], x @ p["w_dkv"].astype(x.dtype))[:, 0]
    kr_t = apply_rope(x @ p["w_kr"].astype(x.dtype), posv, cfg.rope_theta)[:, 0]
    c_cache = jax.lax.dynamic_update_index_in_dim(
        cache["c_kv"], c_t.astype(cache["c_kv"].dtype), pos, axis=1)
    kr_cache = jax.lax.dynamic_update_index_in_dim(
        cache["k_rope"], kr_t.astype(cache["k_rope"].dtype), pos, axis=1)
    w_uk = p["w_uk"].astype(x_t.dtype).reshape(m.kv_lora, h, m.nope_dim)
    q_abs = jnp.einsum("bhd,chd->bhc", q_nope[:, 0], w_uk)
    scores = (jnp.einsum("bhc,btc->bht", q_abs, c_cache.astype(q_abs.dtype))
              + jnp.einsum("bhd,btd->bht", q_rope[:, 0],
                           kr_cache.astype(q_rope.dtype))) * scale
    t = c_cache.shape[1]
    valid = jnp.arange(t) <= pos
    scores = jnp.where(valid[None, None], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x_t.dtype)
    out_lat = jnp.einsum("bht,btc->bhc", probs, c_cache.astype(probs.dtype))
    w_uv = p["w_uv"].astype(x_t.dtype).reshape(m.kv_lora, h, m.v_dim)
    out = jnp.einsum("bhc,chd->bhd", out_lat, w_uv).reshape(b, h * m.v_dim)
    return out @ p["wo"].astype(x_t.dtype), {"c_kv": c_cache, "k_rope": kr_cache}
