"""Generic decoder-only LM assembled from an ArchConfig.

Covers the dense (qwen2.5/codeqwen/granite/qwen3), MoE (arctic/deepseek+MLA),
SSM (xlstm), and hybrid (zamba2) families.  Whisper (enc-dec) and PaliGemma
(VLM prefix) build on the same blocks in their own modules.

Layer stacks are parameter-stacked (leading n_layers axis) and run under
`jax.lax.scan` so HLO size is depth-independent; MoE aux losses accumulate
through the scan carry.  Decode steps scan over the same stacked params with
per-layer cache slices.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import common, mamba2, mlp, xlstm
from repro.models.common import apply_norm, causal_mask, embed_init, init_norm
from repro.parallel.axes import logical

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# block init / apply (one transformer layer)
# ---------------------------------------------------------------------------
def _init_block(key: Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"ln1": init_norm(d, cfg.norm)}
    if cfg.family == "ssm":       # xLSTM pair: (mLSTM, sLSTM)
        p["mlstm"] = xlstm.init_mlstm(ks[0], cfg)
        p["ln2"] = init_norm(d, cfg.norm)
        p["slstm"] = xlstm.init_slstm(ks[1], cfg)
        return p
    if cfg.family == "hybrid":    # Zamba2 mamba layer
        p["mamba"] = mamba2.init_mamba2(ks[0], cfg)
        return p
    # attention + ffn block
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(ks[0], cfg)
    else:
        p["attn"] = attn.init_attention(ks[0], cfg)
    p["ln2"] = init_norm(d, cfg.norm)
    if cfg.moe is not None:
        p["ffn"] = mlp.init_moe(ks[1], d, cfg)
    else:
        p["ffn"] = mlp.init_mlp(ks[1], d, cfg.d_ff, cfg)
    return p


def _block_fwd(p: dict, x: Array, cfg: ArchConfig, *, mask: Array,
               positions: Array, mlstm_chunked: bool = False,
               attn_impl: str = "dense", prefix_len: int = 0) -> tuple[Array, Array]:
    """Returns (y, aux_loss).  attn_impl: 'dense' | 'blockwise' (32k+ seqs)."""
    aux = jnp.float32(0.0)
    if cfg.family == "ssm":
        h = apply_norm(p["ln1"], x, cfg.norm)
        fwd = xlstm.mlstm_fwd_chunked if mlstm_chunked else xlstm.mlstm_fwd
        x = x + fwd(p["mlstm"], h, cfg)
        h = apply_norm(p["ln2"], x, cfg.norm)
        x = x + xlstm.slstm_fwd(p["slstm"], h, cfg)
        return x, aux
    if cfg.family == "hybrid":
        h = apply_norm(p["ln1"], x, cfg.norm)
        x = x + mamba2.mamba2_fwd(p["mamba"], h, cfg)
        return x, aux
    h = apply_norm(p["ln1"], x, cfg.norm)
    if cfg.mla is not None:
        if attn_impl == "blockwise":
            a = attn.mla_fwd_blockwise(p["attn"], h, cfg, positions=positions)
        else:
            a = attn.mla_fwd(p["attn"], h, cfg, mask=mask, positions=positions)
    elif attn_impl == "blockwise":
        a = attn.attention_fwd_blockwise(p["attn"], h, cfg, positions=positions,
                                         prefix_len=prefix_len)
    else:
        a = attn.attention_fwd(p["attn"], h, cfg, mask=mask, positions=positions)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        y, aux = mlp.moe_fwd(p["ffn"], h, cfg)
    else:
        y = mlp.mlp_fwd(p["ffn"], h, cfg)
    return x + y, aux


# ---------------------------------------------------------------------------
# shared-attention block (Zamba2)
# ---------------------------------------------------------------------------
def _zamba_attn_cfg(cfg: ArchConfig) -> ArchConfig:
    import dataclasses

    hy = cfg.hybrid
    return dataclasses.replace(cfg, n_heads=hy.attn_heads,
                               n_kv_heads=hy.attn_kv_heads, head_dim=0,
                               attn_bias=False, qk_norm=False)


def _init_shared_block(key: Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    acfg = _zamba_attn_cfg(cfg)
    return {
        "ln1": init_norm(d, cfg.norm),
        "attn": attn.init_attention(ks[0], acfg),
        "ln2": init_norm(d, cfg.norm),
        "ffn": mlp.init_mlp(ks[1], d, cfg.hybrid.shared_ff, cfg),
    }


def _shared_block_fwd(p: dict, x: Array, cfg: ArchConfig, *, mask, positions,
                      attn_impl: str = "dense"):
    acfg = _zamba_attn_cfg(cfg)
    h = apply_norm(p["ln1"], x, cfg.norm)
    if attn_impl == "blockwise":
        a = attn.attention_fwd_blockwise(p["attn"], h, acfg, positions=positions)
    else:
        a = attn.attention_fwd(p["attn"], h, acfg, mask=mask, positions=positions)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg.norm)
    return x + mlp.mlp_fwd(p["ffn"], h, cfg)


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------
def n_stacked_layers(cfg: ArchConfig) -> int:
    if cfg.family == "ssm":
        assert cfg.n_layers % 2 == 0
        return cfg.n_layers // 2          # (mLSTM, sLSTM) pairs
    return cfg.n_layers


def init_lm(key: Array, cfg: ArchConfig) -> PyTree:
    nl = n_stacked_layers(cfg)
    k_emb, k_blocks, k_head, k_shared, k_pos = jax.random.split(key, 5)
    block_keys = jax.random.split(k_blocks, nl)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(block_keys)
    p = {
        "emb": embed_init(k_emb, (cfg.vocab, cfg.d_model)),
        "blocks": blocks,
        "final_norm": init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        p["head"] = common.dense_init(k_head, (cfg.d_model, cfg.vocab))
    if cfg.pos == "learned":
        p["pos_emb"] = embed_init(k_pos, (common.MAX_LEARNED_POS, cfg.d_model))
    if cfg.family == "hybrid":
        p["shared"] = _init_shared_block(k_shared, cfg)
    return p


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------
def lm_hidden(params: PyTree, tokens: Array, cfg: ArchConfig, *,
              mask: Array | None = None, prefix_embeds: Array | None = None,
              mlstm_chunked: bool = False, remat: bool = False,
              attn_impl: str = "dense") -> tuple[Array, Array]:
    """Embed -> blocks -> final norm.  Returns (hidden (B,S,D), aux_loss).

    prefix_embeds (B, P, D): modality-stub embeddings prepended to the token
    embeddings (PaliGemma patches); callers account for the longer sequence.
    attn_impl='blockwise' never materializes (S,S) scores (32k+ prefill).
    """
    x = params["emb"][tokens].astype(jnp.bfloat16)
    prefix_len = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = prefix_embeds.shape[1]
    x = logical(x, "batch", "seq", "embed")
    b, s, _ = x.shape
    positions = jnp.arange(s)
    if cfg.pos == "learned":
        x = x + params["pos_emb"][:s].astype(x.dtype)[None]
    if mask is None and attn_impl == "dense":
        mask = causal_mask(s)

    if cfg.family == "hybrid":
        per = cfg.hybrid.shared_attn_every
        nl = cfg.n_layers
        assert nl % per == 0
        n_groups = nl // per
        blocks = params["blocks"]
        # regroup stacked params: (nl, ...) -> (n_groups, per, ...)
        grouped = jax.tree.map(lambda a: a.reshape((n_groups, per) + a.shape[1:]),
                               blocks)
        shared = params["shared"]

        def group_step(carry, gparams):
            x = carry
            x = _shared_block_fwd(shared, x, cfg, mask=mask, positions=positions,
                                  attn_impl=attn_impl)

            def layer_step(xx, lp):
                y, _ = _block_fwd(lp, xx, cfg, mask=mask, positions=positions)
                return y, None

            if remat:
                layer_step = jax.checkpoint(layer_step)
            x, _ = jax.lax.scan(layer_step, x, gparams)
            return x, None

        if remat:
            group_step = jax.checkpoint(group_step)
        x, _ = jax.lax.scan(group_step, x, grouped)
        aux = jnp.float32(0.0)
    else:
        def layer_step(carry, lp):
            x, aux = carry
            y, a = _block_fwd(lp, x, cfg, mask=mask, positions=positions,
                              mlstm_chunked=mlstm_chunked, attn_impl=attn_impl,
                              prefix_len=prefix_len)
            # optional sharded residual carry ("embed_carry" -> "model"):
            # remat then stores per-layer activations 1/TP-sized (arctic)
            y = logical(y, "batch", "seq", "embed_carry")
            return (y, aux + a), None

        if remat:
            layer_step = jax.checkpoint(layer_step)
        (x, aux), _ = jax.lax.scan(layer_step, (x, jnp.float32(0.0)),
                                   params["blocks"])

    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux


def lm_logits(params: PyTree, hidden: Array, cfg: ArchConfig) -> Array:
    head = params["emb"].T if cfg.tie_embeddings else params["head"]
    logits = hidden @ head.astype(hidden.dtype)
    # "logits_seq" (default None) keeps vocab as the sharded dim: the CE
    # logsumexp then reduces over "model" with tiny (B,S) collectives.
    if logits.ndim == 2:            # decode: (B, V)
        return logical(logits, "batch", "vocab")
    return logical(logits, "batch", "logits_seq", "vocab")


def lm_loss(params: PyTree, batch: dict, cfg: ArchConfig, *,
            mlstm_chunked: bool = False, remat: bool = False) -> tuple[Array, dict]:
    hidden, aux = lm_hidden(params, batch["inputs"], cfg,
                            mlstm_chunked=mlstm_chunked, remat=remat)
    logits = lm_logits(params, hidden, cfg)
    loss, metrics = common.softmax_cross_entropy(logits, batch["targets"])
    metrics["aux_loss"] = aux
    return loss + aux, metrics


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def _init_layer_cache(cfg: ArchConfig, batch: int, max_seq: int):
    if cfg.family == "ssm":
        return {"mlstm": xlstm.init_mlstm_state(cfg, batch),
                "slstm": xlstm.init_slstm_state(cfg, batch)}
    if cfg.family == "hybrid":
        return mamba2.init_mamba2_state(cfg, batch)
    if cfg.mla is not None:
        return attn.init_mla_cache(cfg, batch, max_seq)
    return attn.init_kv_cache(cfg, batch, max_seq)


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int) -> PyTree:
    nl = n_stacked_layers(cfg)
    one = _init_layer_cache(cfg, batch, max_seq)
    caches = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (nl,) + a.shape), one)
    state = {"caches": caches, "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.hybrid.shared_attn_every
        acfg = _zamba_attn_cfg(cfg)
        sc = attn.init_kv_cache(acfg, batch, max_seq)
        state["shared_caches"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), sc)
    return state


def _layer_decode(p: dict, x_t: Array, cache: PyTree, pos: Array,
                  cfg: ArchConfig) -> tuple[Array, PyTree]:
    if cfg.family == "ssm":
        h = apply_norm(p["ln1"], x_t[:, None], cfg.norm)[:, 0]
        y, mc = xlstm.mlstm_decode(p["mlstm"], h, cache["mlstm"], cfg)
        x_t = x_t + y
        h = apply_norm(p["ln2"], x_t[:, None], cfg.norm)[:, 0]
        y, sc = xlstm.slstm_decode(p["slstm"], h, cache["slstm"], cfg)
        return x_t + y, {"mlstm": mc, "slstm": sc}
    if cfg.family == "hybrid":
        h = apply_norm(p["ln1"], x_t[:, None], cfg.norm)[:, 0]
        y, c2 = mamba2.mamba2_decode(p["mamba"], h, cache, cfg)
        return x_t + y, c2
    h = apply_norm(p["ln1"], x_t[:, None], cfg.norm)[:, 0]
    if cfg.mla is not None:
        a, c2 = attn.mla_decode(p["attn"], h, cache, pos, cfg)
    else:
        a, c2 = attn.attention_decode(p["attn"], h, cache, pos, cfg)
    x_t = x_t + a
    h = apply_norm(p["ln2"], x_t[:, None], cfg.norm)[:, 0]
    if cfg.moe is not None:
        y, _ = mlp.moe_fwd(p["ffn"], h[:, None], cfg)
        y = y[:, 0]
    else:
        y = mlp.mlp_fwd(p["ffn"], h, cfg)
    return x_t + y, c2


def decode_step(params: PyTree, state: PyTree, tokens: Array,
                cfg: ArchConfig) -> tuple[Array, PyTree]:
    """One decode step: tokens (B,) int32 -> (logits (B,V), new state)."""
    pos = state["pos"]
    x = params["emb"][tokens].astype(jnp.bfloat16)
    if cfg.pos == "learned":
        x = x + params["pos_emb"][pos].astype(x.dtype)[None]

    if cfg.family == "hybrid":
        per = cfg.hybrid.shared_attn_every
        n_groups = cfg.n_layers // per
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, per) + a.shape[1:]), params["blocks"])
        gcaches = jax.tree.map(
            lambda a: a.reshape((n_groups, per) + a.shape[1:]), state["caches"])
        shared = params["shared"]
        acfg = _zamba_attn_cfg(cfg)

        def group_step(x, inp):
            gp, gc, sc = inp
            h = apply_norm(shared["ln1"], x[:, None], cfg.norm)[:, 0]
            a, sc2 = attn.attention_decode(shared["attn"], h, sc, pos, acfg)
            x = x + a
            h = apply_norm(shared["ln2"], x[:, None], cfg.norm)[:, 0]
            x = x + mlp.mlp_fwd(shared["ffn"], h, cfg)

            def layer_step(xx, lp_lc):
                lp, lc = lp_lc
                y, c2 = _layer_decode(lp, xx, lc, pos, cfg)
                return y, c2

            x, gc2 = jax.lax.scan(layer_step, x, (gp, gc))
            return x, (gc2, sc2)

        x, (new_g, new_s) = jax.lax.scan(group_step, x,
                                         (grouped, gcaches, state["shared_caches"]))
        new_caches = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_g)
        new_state = {"caches": new_caches, "pos": pos + 1,
                     "shared_caches": new_s}
    else:
        # fori_loop with in-place dynamic updates: the while-loop carry
        # aliases its buffers, so the stacked cache is updated in place
        # (a scan-with-outputs would double-buffer the full cache).
        nl = n_stacked_layers(cfg)

        def layer_step(i, carry):
            x, caches = carry
            lp = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(
                a, i, 0, keepdims=False), params["blocks"])
            lc = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(
                a, i, 0, keepdims=False), caches)
            y, c2 = _layer_decode(lp, x, lc, pos, cfg)
            caches = jax.tree.map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), i, 0), caches, c2)
            return y, caches

        x, new_caches = jax.lax.fori_loop(0, nl, layer_step,
                                          (x, state["caches"]))
        new_state = {"caches": new_caches, "pos": pos + 1}

    x = apply_norm(params["final_norm"], x[:, None], cfg.norm)[:, 0]
    logits = lm_logits(params, x, cfg)
    return logits.astype(jnp.float32), new_state
