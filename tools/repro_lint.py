"""House-rules linter CLI: trace purity, lock discipline, schema drift.

Runs the `repro.analysis` passes over the tree and prints findings as
``path:line: [rule] message``.  Exit status is the number of kept
findings, so CI can gate on it directly.

  python tools/repro_lint.py                 # all passes, suppressions honoured
  python tools/repro_lint.py --strict        # + reasonless/unused suppressions fail
  python tools/repro_lint.py --pass locks    # one pass family
  python tools/repro_lint.py --update-manifest   # regenerate schema manifest
  python tools/repro_lint.py --list-rules    # rule catalog

Suppression syntax (see docs/static_analysis.md):

  x[i] = v   # lint: disable=inplace-store -- trace-time probe, host dict
"""
from __future__ import annotations

import argparse
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import (RULES, apply_suppressions, load_tree,  # noqa: E402
                            lock_discipline, schema_drift, trace_purity)

PASSES = {
    "trace": trace_purity.run,
    "locks": lock_discipline.run,
    "schema": None,       # needs root; special-cased below
}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description="trace-purity / lock-discipline / schema-drift linter")
    ap.add_argument("--root", type=pathlib.Path, default=REPO,
                    help="repo root to scan (default: this checkout)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail reasonless, unknown-rule, or unused "
                         "suppressions")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=sorted(PASSES),
                    help="run only this pass family (repeatable; "
                         "default: all)")
    ap.add_argument("--update-manifest", action="store_true",
                    help="regenerate the committed schema manifest from "
                         "the live tree and exit")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print findings silenced by lint: disable "
                         "comments")
    args = ap.parse_args(argv)

    if args.list_rules:
        width = max(len(r) for r in RULES)
        for rule in sorted(RULES):
            print(f"{rule:<{width}}  {RULES[rule]}")
        return 0

    root = args.root.resolve()
    modules = load_tree(root)
    if not modules:
        print(f"repro_lint: no modules under {root}/src/repro",
              file=sys.stderr)
        return 1

    if args.update_manifest:
        path = schema_drift.write_manifest(root, modules)
        print(f"wrote {path.relative_to(root)}")
        return 0

    wanted = args.passes or sorted(PASSES)
    findings = []
    if "trace" in wanted:
        findings.extend(trace_purity.run(modules))
    if "locks" in wanted:
        findings.extend(lock_discipline.run(modules))
    if "schema" in wanted:
        findings.extend(schema_drift.run(modules, root=root))

    kept, suppressed = apply_suppressions(findings, modules,
                                          strict=args.strict)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    for f in kept:
        print(f.render())
    if args.show_suppressed:
        for f in suppressed:
            print(f"suppressed: {f.render()}")
    tail = f"{len(kept)} finding(s)"
    if suppressed:
        tail += f", {len(suppressed)} suppressed"
    print(f"repro_lint: {tail} over {len(modules)} modules"
          + (" [strict]" if args.strict else ""))
    return len(kept)


if __name__ == "__main__":
    raise SystemExit(main())
