"""Pareto dominance utilities (paper Sec. 2.2, Eq. 1), vectorized in JAX.

All functions are pure and jit-safe.  The O(P^2) pairwise dominance matrix is
the algorithmic hot spot of NSGA-II's fast non-dominated sort; a Pallas TPU
kernel (`repro.kernels.pareto_dom`) provides a tiled implementation for large
populations, with `dominance_matrix` below as its jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

INF = jnp.inf


def dominates(u: Array, v: Array) -> Array:
    """Eq. 1 (minimization): u dominates v iff u <= v everywhere and < somewhere."""
    return jnp.all(u <= v, axis=-1) & jnp.any(u < v, axis=-1)


def dominance_matrix(f: Array) -> Array:
    """D[i, j] = True iff point i dominates point j.  f: (P, M) objectives."""
    le = jnp.all(f[:, None, :] <= f[None, :, :], axis=-1)
    lt = jnp.any(f[:, None, :] < f[None, :, :], axis=-1)
    return le & lt


def constrained_dominance_matrix(f: Array, cv: Array) -> Array:
    """Deb's constraint-domination: cv (P,) total constraint violation (>=0).

    i cdom j iff (i feasible, j not) or (both infeasible, cv_i < cv_j) or
    (both feasible and i pareto-dominates j).
    """
    feas_i = cv[:, None] <= 0.0
    feas_j = cv[None, :] <= 0.0
    dom = dominance_matrix(f)
    both_feas = feas_i & feas_j
    i_only = feas_i & ~feas_j
    both_infeas = ~feas_i & ~feas_j
    return i_only | (both_infeas & (cv[:, None] < cv[None, :])) | (both_feas & dom)


def non_dominated_mask(f: Array) -> Array:
    """(P,) True where no other point dominates this one."""
    return ~jnp.any(dominance_matrix(f), axis=0)


def non_dominated_rank(f: Array, dom: Array | None = None) -> Array:
    """Fast non-dominated sort.  Returns (P,) int32 front index (0 = Pareto).

    Iterative peeling: points whose remaining in-degree (number of
    not-yet-peeled dominators) is zero form the next front.  The loop runs
    once per front (<< P in practice) with O(P^2) bool-matmul work per
    iteration — MXU-friendly.
    """
    if dom is None:
        dom = dominance_matrix(f)
    p = f.shape[0]
    domf = dom.astype(jnp.float32)

    def cond(state):
        ranks, _ = state
        return jnp.any(ranks < 0)

    def body(state):
        ranks, front = state
        alive = (ranks < 0).astype(jnp.float32)
        indeg = alive @ domf  # indeg[j] = #alive dominators of j
        newfront = (ranks < 0) & (indeg == 0.0)
        ranks = jnp.where(newfront, front, ranks)
        return ranks, front + 1

    ranks0 = jnp.full((p,), -1, jnp.int32)
    ranks, _ = jax.lax.while_loop(cond, body, (ranks0, jnp.int32(0)))
    return ranks


def crowding_distance(f: Array, ranks: Array) -> Array:
    """NSGA-II crowding distance computed per front, vectorized.

    For each objective, points are sorted with (rank, value) lexicographic
    keys so fronts are contiguous; interior points get the normalized gap to
    their in-front neighbours, front boundary points get +inf.  The
    per-objective pass is `vmap`-ed over the objective axis (one fused sort
    batch instead of a Python loop of M lexsorts).
    """
    p, m = f.shape
    big = jnp.float32(1e30)

    def per_objective(v: Array) -> Array:
        # lexicographic sort by (rank, v):
        order = jnp.lexsort((v, ranks))
        rs = ranks[order]
        vs = v[order]
        seg_start = jnp.concatenate([jnp.array([True]), rs[1:] != rs[:-1]])
        seg_end = jnp.concatenate([rs[1:] != rs[:-1], jnp.array([True])])
        prev = jnp.concatenate([vs[:1], vs[:-1]])
        nxt = jnp.concatenate([vs[1:], vs[-1:]])
        # per-front min/max via segment ops
        fmin = jax.ops.segment_min(vs, rs, num_segments=p)
        fmax = jax.ops.segment_max(vs, rs, num_segments=p)
        span = jnp.maximum(fmax - fmin, 1e-12)[rs]
        d = (nxt - prev) / span
        d = jnp.where(seg_start | seg_end, big, d)
        return jnp.zeros((p,), jnp.float32).at[order].set(d)

    return jnp.sum(jax.vmap(per_objective, in_axes=1)(f), axis=0)


def pareto_front_indices(f: Array) -> Array:
    """Boolean mask of the Pareto-optimal set (front 0)."""
    return non_dominated_mask(f)
