"""Routing benchmark: Jacobi-sweep wavefronts vs the frontier-bucketed
engine, and the per-slot `lax.scan` routing program vs the concurrent
conflict-aware scheduler.

Two columns, matching the two layers of ROADMAP item 2:

  * `wavefront` — one batch of full distance-field expansions on the
    largest routing grids of the spec set: the jitted jnp reference
    (full-grid Jacobi sweeps, one per BFS level) against the host
    frontier engine (per-level work proportional to the active
    frontier).  Both fields are asserted equal to the pure-Python BFS
    oracle, cell for cell — `fields_equal` in the output.

  * `routing` — the end-to-end batched route of the derived net set:
    engine="scan" (one wavefront dispatch per net slot, O(nets) sweeps)
    against engine="concurrent" (greedy bbox-coloring co-dispatches
    non-conflicting nets, collision-checked commits, O(conflict-depth)
    rounds).  `results_equal` requires routed/failed/wirelength/
    congestion to match exactly — the concurrent engine is the same
    router, faster, not an approximation.

Results land in `BENCH_route.json` at the repo root; CI runs `--smoke`
and asserts both equality flags plus the schema.

  PYTHONPATH=src python -m benchmarks.route_bench [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import time

import jax
import numpy as np

from benchmarks.layout_bench import SPECS_FULL, SPECS_SMOKE
from repro.eda.batched_flow import (_nets_program, _place_program,
                                    batched_route, stack_layout_operands)
from repro.eda.placer import BatchDims, geometry
from repro.eda.router import grid_shape
from repro.kernels.maze_route import (wavefront_distance,
                                      wavefront_distance_bfs)

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _derive_nets(specs, coarse=64):
    geom = geometry()
    dims = BatchDims.for_specs(specs)
    ops = stack_layout_operands(specs, geom)
    tensors = _place_program(ops, dims=dims, geom=geom)
    nets = _nets_program(tensors, ops, dims=dims, geom=geom, coarse=coarse)
    return nets, np.asarray(ops.width), np.asarray(ops.height)


def _wavefront_column(widths, heights, n_fields: int) -> dict:
    """Full-field expansion on the spec set's largest routing grid."""
    gh, gw = max(grid_shape(int(w), int(h), 64)
                 for w, h in zip(widths, heights))
    rng = np.random.default_rng(0)
    occ = rng.random((n_fields, gh, gw)) < 0.15
    seed = np.zeros((n_fields, gh, gw), bool)
    seed[np.arange(n_fields),
         rng.integers(0, gh, n_fields), rng.integers(0, gw, n_fields)] = True
    occ_j, seed_j = jax.numpy.asarray(occ), jax.numpy.asarray(seed)

    oracle = wavefront_distance_bfs(occ, seed)
    jax.block_until_ready(wavefront_distance(occ_j, seed_j, impl="ref"))
    t0 = time.perf_counter()
    ref = wavefront_distance(occ_j, seed_j, impl="ref")
    jax.block_until_ready(ref)
    jacobi_s = time.perf_counter() - t0

    wavefront_distance(occ, seed, impl="frontier")
    t0 = time.perf_counter()
    fro = wavefront_distance(occ, seed, impl="frontier")
    frontier_s = time.perf_counter() - t0

    fields_equal = (np.array_equal(np.asarray(ref), oracle)
                    and np.array_equal(fro, oracle))
    return {
        "grid": [int(gh), int(gw)],
        "n_fields": n_fields,
        "jacobi_warm_s": jacobi_s,
        "frontier_warm_s": frontier_s,
        "frontier_speedup": jacobi_s / frontier_s,
        "fields_equal": fields_equal,
    }


def _routing_column(nets, w, h) -> dict:
    t0 = time.perf_counter()
    scan = batched_route(nets, w, h, engine="scan")
    scan_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    scan = batched_route(nets, w, h, engine="scan")
    scan_warm = time.perf_counter() - t0

    t0 = time.perf_counter()
    conc = batched_route(nets, w, h, engine="concurrent")
    conc_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    conc = batched_route(nets, w, h, engine="concurrent")
    conc_warm = time.perf_counter() - t0

    results_equal = (np.array_equal(conc.routed, scan.routed)
                     and np.array_equal(conc.failed, scan.failed)
                     and np.array_equal(conc.wirelength, scan.wirelength)
                     and np.array_equal(conc.occ_count, scan.occ_count))
    return {
        "net_slots": int(np.asarray(nets.nmask).shape[1]),
        "nets": int(np.asarray(nets.nmask).sum()),
        "scan": {"cold_s": scan_cold, "warm_s": scan_warm},
        "concurrent": {"cold_s": conc_cold, "warm_s": conc_warm,
                       "rounds": conc.rounds,
                       "collisions": conc.collisions},
        "concurrent_speedup_cold": scan_cold / conc_cold,
        "concurrent_speedup_warm": scan_warm / conc_warm,
        "results_equal": results_equal,
    }


def run(smoke: bool = False) -> dict:
    specs = SPECS_SMOKE if smoke else SPECS_FULL
    nets, w, h = _derive_nets(specs)
    wavefront = _wavefront_column(w, h, n_fields=4 if smoke else 8)
    routing = _routing_column(nets, w, h)
    return {
        "specs": [s.as_tuple() for s in specs],
        "smoke": smoke,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "wavefront": wavefront,
        "routing": routing,
        "results_equal": (wavefront["fields_equal"]
                          and routing["results_equal"]),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller spec set for CI")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_route.json"))
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    wf, rt = result["wavefront"], result["routing"]
    print(f"wavefront: jacobi={wf['jacobi_warm_s']:.3f}s "
          f"frontier={wf['frontier_warm_s']:.3f}s "
          f"speedup={wf['frontier_speedup']:.2f}x")
    print(f"routing: scan={rt['scan']['warm_s']:.3f}s "
          f"concurrent={rt['concurrent']['warm_s']:.3f}s "
          f"speedup(warm)={rt['concurrent_speedup_warm']:.2f}x "
          f"rounds={rt['concurrent']['rounds']} "
          f"collisions={rt['concurrent']['collisions']}")
    print(f"results_equal={result['results_equal']} -> {args.out}")


if __name__ == "__main__":
    main()
