from repro.kernels.pareto_dom.ops import (dominance_matrix,
                                          non_dominated_rank, rank_and_crowd)
from repro.kernels.pareto_dom.ref import (crowding_distance_ref,
                                          dominance_matrix_ref,
                                          non_dominated_rank_ref)

__all__ = ["dominance_matrix", "non_dominated_rank", "rank_and_crowd",
           "dominance_matrix_ref", "non_dominated_rank_ref",
           "crowding_distance_ref"]
