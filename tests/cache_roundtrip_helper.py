"""Subprocess half of the cross-process artifact-cache round trip.

Run as `python tests/cache_roundtrip_helper.py <cache_dir> <request_json>`
(with `PYTHONPATH=src`): opens a *fresh* `DesignSession` over the given
persistent cache, runs the request, and prints a JSON report the parent
test (`tests/test_design_service_async.py`) and the CI smoke step
assert on — a repeat request must be served entirely from disk
(`explorer_dispatches == 0`, provenance `served_from ==
"artifact_cache"`) with content equal to the parent's artifact.
"""
import json
import sys


def main() -> None:
    cache_dir, request_json = sys.argv[1], sys.argv[2]
    from repro.api import DesignRequest, DesignSession

    session = DesignSession(artifact_cache=cache_dir)
    artifact = session.run(DesignRequest.from_json(request_json))
    json.dump({
        "explorer_dispatches": int(session.stats["explorer_dispatches"]),
        "layout_dispatches": int(session.stats["layout_dispatches"]),
        "artifact_cache_hits": int(session.stats["artifact_cache_hits"]),
        "served_from": artifact.provenance.served_from,
        "ok": artifact.ok,
        "summary": artifact.summary(),
    }, sys.stdout)


if __name__ == "__main__":
    main()
