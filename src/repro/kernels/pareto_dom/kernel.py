"""Pallas TPU kernels for NSGA-II's fast non-dominated sort.

Two entry points:

`dominance_matrix_kernel` — the tiled pairwise dominance matrix.  The
O(P^2 * M) matrix is the hot spot of the sort (population P up to several
thousand in the distributed explorer; M = 4 objectives).  Objectives are
passed transposed, (M, P), so population indexes the 128-wide lane
dimension; each (bi, bj) output tile loads two thin (M, b) strips into
VMEM and reduces over M on the VPU.

    D[i, j] = all_m(F[m,i] <= F[m,j]) & any_m(F[m,i] < F[m,j])

`nds_rank_kernel` — the fused rank path.  Instead of materializing the
(P, P) f32 matrix to HBM and running the front-peeling loop as repeated
dense matmuls (the jnp oracle `repro.core.pareto.non_dominated_rank`),
this kernel builds the dominance matrix 32 dominator rows at a time in
VMEM, bit-packs each 32-row strip into one uint32 lane vector (a (P/32, P)
scratch — 32x smaller than the bool matrix, 128x smaller than f32), and
peels fronts on-device: per iteration, the still-unranked ("alive") mask
is packed into per-word masks and the remaining in-degree of every point
is a popcount-accumulate over the packed words.  Nothing of size P^2 ever
leaves VMEM, and no (P, P) f32 tensor exists at any point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(fi_ref, fj_ref, o_ref):
    fi = fi_ref[...]   # (M, bi)
    fj = fj_ref[...]   # (M, bj)
    le = jnp.all(fi[:, :, None] <= fj[:, None, :], axis=0)
    lt = jnp.any(fi[:, :, None] < fj[:, None, :], axis=0)
    o_ref[...] = (le & lt).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dominance_matrix_kernel(f_t: jax.Array, *, block: int = 256,
                            interpret: bool = False) -> jax.Array:
    """f_t: (M, P) objectives, P % block == 0.  Returns (P, P) int8 where
    D[i, j] = 1 iff point i dominates point j (minimization, Eq. 1)."""
    m, p = f_t.shape
    assert p % block == 0, (p, block)
    grid = (p // block, p // block)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block), lambda i, j: (0, i)),
            pl.BlockSpec((m, block), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, p), jnp.int8),
        interpret=interpret,
    )(f_t.astype(jnp.float32), f_t.astype(jnp.float32))


# ----------------------------------------------------------------------
# Fused rank path: dominance + bit-pack + front peel, all in VMEM
# ----------------------------------------------------------------------
def _rank_kernel(f_ref, ft_ref, ranks_ref, packed_ref):
    """f_ref (P, M), ft_ref (M, P) — same objectives in both layouts so the
    dominator strip is a sublane slice and the dominated axis stays on
    lanes.  ranks_ref (1, P) int32 out; packed_ref (P//32, P) uint32
    scratch: bit k of packed[w, j] == "point 32w+k dominates point j"."""
    p, m = f_ref.shape
    n_words = p // 32
    ft = ft_ref[...]                                     # (M, P)
    strip_bit = jax.lax.broadcasted_iota(jnp.uint32, (32, 1), 0)

    def build(wi, carry):
        fi = f_ref[pl.ds(wi * 32, 32), :]                # (32, M) dominators
        le = jnp.all(fi[:, :, None] <= ft[None, :, :], axis=1)   # (32, P)
        lt = jnp.any(fi[:, :, None] < ft[None, :, :], axis=1)
        dom = (le & lt).astype(jnp.uint32)
        packed_ref[pl.ds(wi, 1), :] = jnp.sum(dom << strip_bit, axis=0,
                                              keepdims=True)
        return carry

    jax.lax.fori_loop(0, n_words, build, 0)

    lane_bit = jax.lax.broadcasted_iota(jnp.uint32, (1, 32), 1)

    def cond(state):
        ranks, _ = state
        return jnp.any(ranks < 0)

    def body(state):
        ranks, front = state
        alive = (ranks < 0).astype(jnp.uint32)           # (1, P)
        # pack the alive mask along the dominator axis: (1, P) -> (W, 1)
        alive_w = jnp.sum(alive.reshape(n_words, 32) << lane_bit, axis=1,
                          keepdims=True)
        masked = packed_ref[...] & alive_w               # (W, P)
        indeg = jnp.sum(jax.lax.population_count(masked).astype(jnp.int32),
                        axis=0, keepdims=True)           # (1, P)
        newfront = (ranks < 0) & (indeg == 0)
        return jnp.where(newfront, front, ranks), front + 1

    ranks0 = jnp.full((1, p), -1, jnp.int32)
    ranks, _ = jax.lax.while_loop(cond, body, (ranks0, jnp.int32(0)))
    ranks_ref[...] = ranks


@functools.partial(jax.jit, static_argnames=("interpret",))
def nds_rank_kernel(f: jax.Array, *, interpret: bool = False) -> jax.Array:
    """f: (P, M) objectives, P % 256 == 0 (pad with +inf rows; see ops).
    Returns (P,) int32 non-dominated-sort front indices (0 = Pareto)."""
    p, m = f.shape
    assert p % 256 == 0, p
    f = f.astype(jnp.float32)
    ranks = pl.pallas_call(
        _rank_kernel,
        in_specs=[
            pl.BlockSpec((p, m), lambda: (0, 0)),
            pl.BlockSpec((m, p), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, p), jnp.int32),
        scratch_shapes=[pltpu.VMEM((p // 32, p), jnp.uint32)],
        interpret=interpret,
    )(f, f.T)
    return ranks[0]
