"""Explorer benchmark: single-size sequential sweep vs batched one-compile
sweep.

Times `explore_sizes`-style sequential exploration (one `nsga2.run`
dispatch per (size, seed) cell, per-cell operand building on the host)
against the coalescing front door (`repro.serve.design_service
.DesignService`: every (size, seed) cell submitted as a `DesignRequest`
and folded into one vmapped device program for the whole sweep), and
counts traces of the generation program via the
`nsga2.TRACE_COUNTS["run_cell"]` probe.  Two views are reported:

  * end-to-end cold — full sweep including compilation and Pareto-front
    distillation, what a fresh interactive session pays;
  * device warm — min-over-reps wall-clock of just the compiled sweep
    program(s), the steady-state cost of re-running the sweep.

Results land in `BENCH_explorer.json` at the repo root so future PRs have
a perf trajectory.

  PYTHONPATH=src python -m benchmarks.explorer_bench [--smoke] [--out PATH]

`--smoke` shrinks population/generations for CI.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import time

import jax
import jax.numpy as jnp

from repro.api import DesignRequest
from repro.core import explorer, nsga2
from repro.core.batched_explorer import stack_spaces, sweep_program
from repro.serve.design_service import DesignService

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

SIZES = (4096, 16384, 65536)
SEEDS = (0, 1)


def _sequential_sweep(pop: int, gens: int):
    """The pre-batching baseline: one run per (size, seed) cell."""
    out = {}
    for s in SIZES:
        for sd in SEEDS:
            cfg = nsga2.NSGA2Config(array_size=s, pop_size=pop,
                                    generations=gens, seed=sd)
            popu = nsga2.run(cfg)
            out[(s, sd)] = explorer.pareto_result_from_population(
                s, popu.genes, popu.objs)
    return out


def _batched_sweep(pop: int, gens: int):
    """The unified-API path: every cell is a request, the service
    coalesces all of them into one explorer dispatch."""
    svc = DesignService(max_coalesce=len(SIZES) * len(SEEDS))
    tickets = {(s, sd): svc.submit(DesignRequest(
        array_size=s, seed=sd, pop_size=pop, generations=gens,
        layout=False)) for s in SIZES for sd in SEEDS}
    arts = svc.run()
    stats = svc.stats()
    assert stats["explorer_dispatches"] == 1, stats
    return {c: arts[t].pareto for c, t in tickets.items()}


def _cold(fn, *args):
    n0 = nsga2.TRACE_COUNTS["run_cell"]
    t0 = time.perf_counter()
    out = fn(*args)
    cold = time.perf_counter() - t0
    return out, cold, nsga2.TRACE_COUNTS["run_cell"] - n0


def _device_warm(fn, reps: int = 5) -> float:
    fn()  # ensure compiled
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def run(smoke: bool = False) -> dict:
    pop, gens = (48, 8) if smoke else (192, 60)
    statics = nsga2.EvolveStatics(pop_size=pop)
    cells = [(s, sd) for s in SIZES for sd in SEEDS]

    seq, seq_cold, seq_traces = _cold(_sequential_sweep, pop, gens)
    bat, bat_cold, bat_traces = _cold(_batched_sweep, pop, gens)
    fronts_equal = all(
        {(sp.h, sp.w, sp.l, sp.b_adc) for sp in seq[c].specs}
        == {(sp.h, sp.w, sp.l, sp.b_adc) for sp in bat[c].specs}
        for c in seq
    )

    # device-program steady state (no host-side front distillation)
    def seq_device():
        for s, sd in cells:
            space = nsga2.space_operands(nsga2.NSGA2Config(array_size=s))
            jax.block_until_ready(nsga2.run_cell_jit(
                jax.random.key(sd), space, statics=statics, n_gens=gens))

    spaces = stack_spaces([
        nsga2.space_operands(nsga2.NSGA2Config(array_size=s))
        for s, _ in cells])
    keys = jnp.stack([jax.random.key(sd) for _, sd in cells])

    def bat_device():
        jax.block_until_ready(sweep_program(keys, spaces, statics=statics,
                                            n_gens=gens))

    seq_warm = _device_warm(seq_device)
    bat_warm = _device_warm(bat_device)

    return {
        "sizes": list(SIZES),
        "seeds": list(SEEDS),
        "pop_size": pop,
        "generations": gens,
        "smoke": smoke,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "sequential": {"end_to_end_cold_s": seq_cold,
                       "device_warm_s": seq_warm,
                       "generation_program_traces": seq_traces},
        "batched": {"end_to_end_cold_s": bat_cold,
                    "device_warm_s": bat_warm,
                    "generation_program_traces": bat_traces},
        "batched_speedup_cold": seq_cold / bat_cold,
        "batched_speedup_warm": seq_warm / bat_warm,
        "batched_le_sequential": (bat_warm <= seq_warm
                                  and bat_cold <= seq_cold),
        "fronts_equal": fronts_equal,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny pop/generations for CI")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_explorer.json"))
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    for side in ("sequential", "batched"):
        r = result[side]
        print(f"{side}: cold={r['end_to_end_cold_s']:.3f}s "
              f"device_warm={r['device_warm_s']:.3f}s "
              f"traces={r['generation_program_traces']}")
    print(f"speedup(warm)={result['batched_speedup_warm']:.2f}x "
          f"fronts_equal={result['fronts_equal']} -> {args.out}")


if __name__ == "__main__":
    main()
