"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual.  [hf:Snowflake/snowflake-arctic-base; hf]

Arctic's dense-MoE hybrid: every layer has a small dense FFN residual branch
in parallel with the 128-expert top-2 MoE (both width 4864).
"""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, head_dim=128,
    norm="rmsnorm", act="silu", mlp_gated=True,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864, n_shared=0,
                  capacity_factor=1.25, group_size=512, dense_ff=4864),
    source="hf:Snowflake/snowflake-arctic-base; hf",
)

REDUCED = dataclasses.replace(
    CONFIG, name="arctic-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=512,
    head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, n_shared=0,
                  capacity_factor=1.25, group_size=64, dense_ff=96),
)
