from repro.kernels.acim_matmul.ops import acim_matmul, acim_matmul_ste, mismatch_weights
from repro.kernels.acim_matmul.ref import acim_matmul_ref

__all__ = ["acim_matmul", "acim_matmul_ste", "acim_matmul_ref", "mismatch_weights"]
