"""Subprocess half of the cross-process artifact-cache round trips.

Run as `python tests/cache_roundtrip_helper.py <cache_dir> <request_json>
[--remote URI]` (with `PYTHONPATH=src`): opens a *fresh*
`DesignSession` over the given persistent cache — a plain
`ArtifactCache` on `<cache_dir>`, or, with `--remote`, a two-tier
`TieredArtifactCache` (`<cache_dir>` is the worker-local L1, the URI
the shared L2) — runs the request, and prints a JSON report the parent
asserts on.  Single-tier round trip
(`tests/test_design_service_async.py`, CI smoke): a repeat request is
served entirely from disk (`explorer_dispatches == 0`,
`served_from == "artifact_cache"`).  Fleet round trip (same test file
and `benchmarks/service_bench.py`'s fleet scenario): a second worker
process with a cold L1 but the first worker's L2 serves with zero
explorer dispatches and `served_from == "artifact_cache_l2"`.

The report carries the session's cache/dispatch counters, the
artifact's mesh provenance (device count, migration topology/rounds —
the parent records them in `BENCH_service.json`), and the
provenance-free content summary for cross-process equality checks.
"""
import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("cache_dir")
    ap.add_argument("request_json")
    ap.add_argument("--remote", default=None,
                    help="shared L2 URI: run over a TieredArtifactCache")
    args = ap.parse_args()
    from repro.api import DesignRequest, DesignSession, TieredArtifactCache

    cache = (args.cache_dir if args.remote is None
             else TieredArtifactCache(args.cache_dir, args.remote))
    session = DesignSession(artifact_cache=cache)
    artifact = session.run(DesignRequest.from_json(args.request_json))
    prov = artifact.provenance
    json.dump({
        "explorer_dispatches": int(session.stats["explorer_dispatches"]),
        "layout_dispatches": int(session.stats["layout_dispatches"]),
        "artifact_cache_hits": int(session.stats["artifact_cache_hits"]),
        "served_from": prov.served_from,
        "ok": artifact.ok,
        "summary": artifact.summary(),
        "tier_stats": {k: int(session.stats[k]) for k in (
            "artifact_cache_l1_hits", "artifact_cache_l1_misses",
            "artifact_cache_l2_hits", "artifact_cache_l2_misses",
            "artifact_cache_promotions", "artifact_cache_l2_writes")},
        "mesh": {"mesh_devices": prov.mesh_devices,
                 "islands": prov.islands,
                 "migration_topology": prov.migration_topology,
                 "migration_rounds": prov.migration_rounds,
                 "n_devices": __import__("jax").device_count()},
    }, sys.stdout)


if __name__ == "__main__":
    main()
