"""Fixture: a minimal versioned wire format, parsed by the schema-drift
tests under the name ``repro.telemetry.spans`` so the `trace` spec
applies.  `schema_drifted.py` / `schema_bumped.py` are its mutations.
"""
TRACE_SCHEMA = 1


class TraceExport:
    def __init__(self, name, spans):
        self.name = name
        self.spans = spans

    def to_dict(self):
        return {"schema": TRACE_SCHEMA, "name": self.name,
                "spans": list(self.spans)}

    def to_events(self):
        return [{"ph": "X", "name": self.name}]
