"""Codesign recommendations + CIM-in-the-loop training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as creg
from repro.core.acim_spec import MacroSpec
from repro.core.codesign import (extract_gemms, mapping_utilization,
                                 recommend_macro)
from repro.quant.cim_linear import CIMConfig, cim_linear


class TestCodesign:
    def test_extract_gemms_all_archs(self):
        for name in creg.ARCH_IDS:
            gs = extract_gemms(creg.get(name))
            assert gs, name
            assert all(g.k > 0 and g.cols > 0 for g in gs), name

    def test_mapping_utilization_bounds(self):
        spec = MacroSpec(512, 128, 4, 5)
        for g in extract_gemms(creg.get("qwen3_8b")):
            u = mapping_utilization(spec, g)
            assert 0 < u <= 1.0

    def test_recommendation_meets_snr_floor(self):
        rec = recommend_macro(creg.get("qwen2_5_3b"), array_size=16384,
                              min_snr_db=5.0, pop_size=96, generations=25)
        assert rec.snr_db >= 5.0
        assert rec.utilization > 0.3
        assert rec.macro_count_for_rate >= 1

    def test_perfect_k_match_prefers_full_rows(self):
        g_fit = [g for g in extract_gemms(creg.get("qwen2_5_3b"))
                 if g.name == "wq"][0]     # K = 2048
        u_fit = mapping_utilization(MacroSpec(512, 32, 2, 5), g_fit)  # N=256
        u_waste = mapping_utilization(MacroSpec(3072 // 3 * 2, 24, 2, 5)
                                      if False else MacroSpec(1024, 16, 2, 5),
                                      g_fit)
        assert u_fit >= u_waste * 0.99


class TestCIMLinear:
    def test_digital_path_identity(self):
        x = jax.random.normal(jax.random.key(0), (4, 64))
        w = jax.random.normal(jax.random.key(1), (64, 16))
        np.testing.assert_allclose(np.asarray(cim_linear(x, w, None)),
                                   np.asarray(x @ w), rtol=1e-6)

    def test_cim_path_correlates_with_exact(self):
        spec = MacroSpec(128, 16, 2, 5)
        cim = CIMConfig(spec, mismatch=False)
        x = jax.random.normal(jax.random.key(2), (64, 64))
        w = 0.1 * jax.random.normal(jax.random.key(3), (64, 16))
        y = np.asarray(cim_linear(x, w, cim)).ravel()
        ref = np.asarray(x @ w).ravel()
        corr = np.corrcoef(y, ref)[0, 1]
        # 1b x 1b of Gaussian operands: expected correlation ~2/pi ~= 0.64
        # (sign-quantization of both factors); ADC adds a little on top.
        assert corr > 0.55, corr

    def test_gradients_flow(self):
        spec = MacroSpec(128, 8, 2, 4)
        cim = CIMConfig(spec)
        x = jax.random.normal(jax.random.key(4), (8, 64))
        w = 0.1 * jax.random.normal(jax.random.key(5), (64, 8))
        g = jax.grad(lambda w: jnp.sum(cim_linear(x, w, cim) ** 2))(w)
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.linalg.norm(g)) > 0

    def test_cim_in_the_loop_training_decreases_loss(self):
        """Tiny regression net with its hidden layer on the macro."""
        spec = MacroSpec(128, 16, 2, 5)
        cim = CIMConfig(spec, mismatch=True)
        key = jax.random.key(6)
        w1 = 0.3 * jax.random.normal(key, (16, 64))
        w2 = 0.3 * jax.random.normal(jax.random.key(7), (64, 1))
        xs = jax.random.normal(jax.random.key(8), (256, 16))
        ys = jnp.sin(xs.sum(-1, keepdims=True))

        def loss_fn(params):
            h = jnp.tanh(cim_linear(xs, params["w1"], cim))
            pred = h @ params["w2"]
            return jnp.mean((pred - ys) ** 2)

        params = {"w1": w1, "w2": w2}
        l0 = float(loss_fn(params))
        for _ in range(60):
            g = jax.grad(loss_fn)(params)
            params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
        l1 = float(loss_fn(params))
        assert l1 < 0.7 * l0, (l0, l1)
