"""Config registry: ``get(name)`` returns the exact assigned ArchConfig;
``reduced(name)`` returns the same-family CPU smoke-test variant."""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = (
    "arctic_480b",
    "deepseek_v2_lite_16b",
    "xlstm_125m",
    "qwen2_5_3b",
    "codeqwen1_5_7b",
    "granite_34b",
    "qwen3_8b",
    "whisper_large_v3",
    "zamba2_2_7b",
    "paligemma_3b",
)

ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def canonical(name: str) -> str:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return name


def get(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def reduced(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.REDUCED


def all_configs():
    return {n: get(n) for n in ARCH_IDS}
