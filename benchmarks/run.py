"""Benchmark driver: one section per paper table/figure, printed as CSV.

  PYTHONPATH=src python -m benchmarks.run [--skip fig9,...]

Sections:
  fig8   — 16 kb layout design points (throughput/area/SNR vs paper)
  fig9   — design-space sweep + monotone trend checks
  fig10  — EE/area span + SOTA comparison
  table2 — flow wall-clock comparison
  snr_mc — Monte-Carlo SNR vs analytical model (Eqs. 2-6)
  kernels— Pallas kernel microbenchmarks (CPU interpret timings)
  roofline — dry-run roofline table (if runs/dryrun is populated)
"""
from __future__ import annotations

import argparse


def _section(name: str) -> None:
    print(f"\n#### {name}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip", default="", help="comma-separated sections")
    args = ap.parse_args()
    skip = set(args.skip.split(",")) if args.skip else set()

    if "fig8" not in skip:
        _section("fig8_layouts")
        from benchmarks import fig8_layouts

        fig8_layouts.main()

    if "fig9" not in skip:
        _section("fig9_design_space")
        from benchmarks import fig9_design_space

        fig9_design_space.main()

    if "fig10" not in skip:
        _section("fig10_sota")
        from benchmarks import fig10_sota

        fig10_sota.main()

    if "table2" not in skip:
        _section("table2_flow")
        from benchmarks import table2_flow

        table2_flow.main()

    if "snr_mc" not in skip:
        _section("snr_model_vs_mc")
        from benchmarks import snr_mc

        snr_mc.main()

    if "kernels" not in skip:
        _section("kernel_microbench")
        from benchmarks import kernels as kb

        kb.main()

    if "roofline" not in skip:
        _section("roofline (from runs/dryrun)")
        try:
            from benchmarks import roofline

            roofline.main()
        except Exception as e:  # noqa: BLE001
            print(f"roofline unavailable: {e}")


if __name__ == "__main__":
    main()
