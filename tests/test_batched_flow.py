"""Batched layout flow vs the sequential per-spec path.

The contract (asserted per spec): identical placed rectangles, identical
DRC verdict, identical routed/failed counts and wirelength — the batched
path is the sequential path, vectorized, not an approximation of it.
"""
import numpy as np
import pytest

from repro.core.acim_spec import MacroSpec
from repro.eda import netlist as nl, router
from repro.eda.batched_flow import (NetBatch, _Buffered, _bbox_overlap,
                                    _concurrent_route, _nets_program,
                                    _place_program, _still_valid,
                                    batched_route, generate_layouts,
                                    stack_layout_operands)
from repro.eda.flow import generate_layout
from repro.eda.placer import BatchDims, geometry
from repro.kernels.maze_route import wavefront_distance_bfs
from repro.kernels.maze_route.frontier import canvas_index

# Mixed extents on purpose: every BatchDims axis gets real padding.
SPECS = (MacroSpec(64, 16, 2, 3), MacroSpec(128, 32, 4, 3),
         MacroSpec(256, 16, 8, 3), MacroSpec(128, 8, 4, 2),
         MacroSpec(64, 8, 2, 5))


@pytest.fixture(scope="module")
def results():
    return generate_layouts(SPECS), [generate_layout(s) for s in SPECS]


class TestEquivalence:
    def test_same_rects_per_spec(self, results):
        bat, seq = results
        for i, lr in enumerate(seq):
            rb = {(r.name, r.cell, r.x, r.y, r.w, r.h)
                  for r in bat.placements()[i].rects}
            rs = {(r.name, r.cell, r.x, r.y, r.w, r.h)
                  for r in lr.placement.rects}
            assert rb == rs, SPECS[i]

    def test_same_drc_verdict_per_spec(self, results):
        bat, seq = results
        for i, lr in enumerate(seq):
            assert int(bat.drc_overlaps[i]) == lr.drc.overlaps
            assert int(bat.drc_oob[i]) == lr.drc.out_of_bounds
            assert bool(bat.drc_clean[i]) == lr.drc.clean
            assert bat.drc_reports()[i] == lr.drc

    def test_same_routing_per_spec(self, results):
        bat, seq = results
        for i, lr in enumerate(seq):
            assert int(bat.routing.routed[i]) == len(lr.routing.wires)
            assert int(bat.routing.failed[i]) == len(lr.routing.failed)
            assert (int(bat.routing.wirelength[i])
                    == lr.routing.total_wirelength)
            assert (float(bat.routing.success_rate[i])
                    == lr.routing.success_rate)

    def test_metrics_rows_match(self, results):
        bat, seq = results
        for row, lr in zip(bat.metrics_rows(), seq):
            m = lr.metrics()
            # batched rows are pure content: no wall-clock key
            assert set(row) == set(m) - {"elapsed_s"}
            for k in ("h", "w", "l", "b_adc", "routed_nets", "failed_nets",
                      "route_success", "wirelength", "drc_clean"):
                assert row[k] == m[k], k
            for k in ("layout_area_f2_per_bit", "estimator_area_f2_per_bit",
                      "area_model_error"):
                assert row[k] == pytest.approx(m[k]), k

    def test_netlist_stats_closed_form(self, results):
        bat, seq = results
        for i, lr in enumerate(seq):
            assert bat.netlist_stats[i] == lr.netlist_stats
            assert nl.stats_for_spec(SPECS[i]) == lr.netlist_stats


class TestBatchedPlacement:
    def test_operand_stack_shape(self):
        ops = stack_layout_operands(SPECS, geometry())
        for leaf in ops:
            assert leaf.shape == (len(SPECS),)

    def test_batch_dims_are_maxima(self):
        d = BatchDims.for_specs(SPECS)
        assert d.w == max(s.w for s in SPECS)
        assert d.n_la == max(s.n_caps for s in SPECS)
        assert d.l == max(s.l for s in SPECS)
        assert d.b == max(s.b_adc for s in SPECS)

    def test_single_spec_batch_matches_sequential(self):
        spec = MacroSpec(64, 16, 2, 3)
        bat = generate_layouts([spec])
        lr = generate_layout(spec)
        assert len(bat) == 1
        row = bat.metrics_rows()[0]
        m = lr.metrics()
        assert row["wirelength"] == m["wirelength"]
        assert row["drc_clean"] and m["drc_clean"]

    def test_congestion_map_totals_wirelength(self, results):
        bat, _ = results
        # every routed path point increments exactly one occupancy cell
        per_spec = bat.routing.occ_count.sum(axis=(1, 2))
        np.testing.assert_array_equal(per_spec, bat.routing.wirelength)

    def test_empty_batch_rejected(self):
        with pytest.raises(ValueError):
            generate_layouts([])


# ----------------------------------------------------------------------
# Conflict-aware concurrent scheduler
# ----------------------------------------------------------------------
def _grid_nets(slots, gh, gw):
    """Build a single-spec NetBatch from (hub, [targets]) grid-cell slots."""
    n = len(slots)
    hubs = np.zeros((1, n, 2), np.int32)
    tgts = np.zeros((1, n, 2, 2), np.int32)
    tmask = np.zeros((1, n, 2), bool)
    nmask = np.ones((1, n), bool)
    for s, (hub, targets) in enumerate(slots):
        hubs[0, s] = hub
        for j, t in enumerate(targets):
            tgts[0, s, j] = t
            tmask[0, s, j] = True
        for j in range(len(targets), 2):
            tgts[0, s, j] = hub
    return NetBatch(hubs, tgts, tmask, nmask)


def _sequential_reference(nets, gh, gw, capacity):
    """`router.route`'s occupancy evolution on grid-cell nets, slot order.

    Reuses the sequential router's own backtrace (tie-break included) so
    the comparison is against the real per-net semantics, not a re-model
    of them."""
    hubs, tgts, tmask, nmask = (np.asarray(a) for a in nets)
    occ_count = np.zeros((gh, gw), np.int32)
    routed = failed = wl = 0
    for s in range(nmask.shape[1]):
        if not nmask[0, s]:
            continue
        seed = np.zeros((gh, gw), bool)
        seed[tuple(hubs[0, s])] = True
        dist = wavefront_distance_bfs(occ_count >= capacity, seed)
        pts, ok = [], True
        for j in range(2):
            if not tmask[0, s, j]:
                continue
            path = router.backtrace(dist, tuple(tgts[0, s, j]))
            if path is None:
                ok = False
                break
            pts.extend(path)
        if ok:
            for y, x in pts:
                occ_count[y, x] += 1
            routed += 1
            wl += len(pts)
        else:
            failed += 1
    return routed, failed, wl, occ_count


def _run_concurrent(nets, gh, gw, capacity):
    grids = np.array([[gh, gw]], np.int64)
    occ0 = np.zeros((1, gh, gw), np.int32)
    return _concurrent_route(nets, grids, occ0, capacity=capacity,
                             record=True)


@pytest.fixture(scope="module")
def netbatch():
    """Real derived nets for SPECS, plus the spec extents."""
    geom = geometry()
    dims = BatchDims.for_specs(SPECS)
    ops = stack_layout_operands(SPECS, geom)
    tensors = _place_program(ops, dims=dims, geom=geom)
    nets = _nets_program(tensors, ops, dims=dims, geom=geom, coarse=64)
    return nets, np.asarray(ops.width), np.asarray(ops.height)


class TestConflictScheduler:
    def test_no_round_codispatches_overlapping_nets(self, netbatch):
        nets, w, h = netbatch
        res = batched_route(nets, w, h, engine="concurrent",
                            record_schedule=True)
        sched = res.schedule
        assert sched is not None and sched.rounds == res.rounds
        assert len(sched.dispatches) == sched.rounds
        checked = 0
        for lanes in sched.dispatches:
            per_spec: dict[int, list] = {}
            for b, s in lanes:
                per_spec.setdefault(b, []).append(sched.bboxes[b, s])
            for boxes in per_spec.values():
                for i in range(len(boxes)):
                    for j in range(i + 1, len(boxes)):
                        assert not _bbox_overlap(boxes[i], boxes[j])
                        checked += 1
        assert checked > 0          # the sweep actually batched something

    def test_identical_bbox_nets_serialize(self):
        # Three nets sharing one corridor: the greedy coloring must put
        # them in three separate rounds, one commit each, no collisions.
        slots = [((2, 2), [(2, 6)])] * 3
        nets = _grid_nets(slots, gh=8, gw=12)
        occ, routed, failed, wl, rounds, collisions, sched = \
            _run_concurrent(nets, 8, 12, capacity=100)
        assert [len(d) for d in sched.dispatches] == [1, 1, 1]
        assert rounds == 3 and collisions == 0
        assert int(routed[0]) == 3 and int(failed[0]) == 0
        assert int(wl[0]) == 3 * 5          # d0 = 4, path = 5 cells each
        s_routed, s_failed, s_wl, s_occ = \
            _sequential_reference(nets, 8, 12, capacity=100)
        assert (int(routed[0]), int(failed[0]), int(wl[0])) \
            == (s_routed, s_failed, s_wl)
        np.testing.assert_array_equal(occ[0], s_occ)

    def test_collision_retry_converges_and_matches_sequential(self):
        # capacity=1: slot 0 (row 0) and slot 1 (row 3) have disjoint
        # bboxes, so they co-dispatch — but slot 0's commit crosses
        # capacity at cells whose distance from slot 1's hub undercuts
        # slot 1's farthest target, so the validity bound must drop and
        # re-route slot 1 (the collision-retry path).
        slots = [((0, 0), [(0, 2)]), ((3, 3), [(3, 9)])]
        nets = _grid_nets(slots, gh=8, gw=12)
        occ, routed, failed, wl, rounds, collisions, sched = \
            _run_concurrent(nets, 8, 12, capacity=1)
        assert len(sched.dispatches[0]) == 2     # co-dispatched round 1
        assert collisions >= 1                   # ...and slot 1 was dropped
        assert rounds >= 2                       # retry took another round
        s_routed, s_failed, s_wl, s_occ = \
            _sequential_reference(nets, 8, 12, capacity=1)
        assert (int(routed[0]), int(failed[0]), int(wl[0])) \
            == (s_routed, s_failed, s_wl)
        np.testing.assert_array_equal(occ[0], s_occ)

    def test_blocked_corridor_failures_match_sequential(self):
        # capacity=1 and four nets forced through one 3-cell corridor
        # mouth: later nets must fail exactly like the sequential router.
        slots = [((4, 0), [(4, 8)]), ((3, 0), [(3, 8)]),
                 ((5, 0), [(5, 8)]), ((4, 1), [(4, 7)])]
        nets = _grid_nets(slots, gh=8, gw=12)
        occ, routed, failed, wl, _, _, _ = \
            _run_concurrent(nets, 8, 12, capacity=1)
        s_routed, s_failed, s_wl, s_occ = \
            _sequential_reference(nets, 8, 12, capacity=1)
        assert (int(routed[0]), int(failed[0]), int(wl[0])) \
            == (s_routed, s_failed, s_wl)
        np.testing.assert_array_equal(occ[0], s_occ)

    def test_engines_bit_identical(self, netbatch):
        nets, w, h = netbatch
        conc = batched_route(nets, w, h, engine="concurrent")
        scan = batched_route(nets, w, h, engine="scan")
        assert conc.engine == "concurrent" and scan.engine == "scan"
        np.testing.assert_array_equal(conc.routed, scan.routed)
        np.testing.assert_array_equal(conc.failed, scan.failed)
        np.testing.assert_array_equal(conc.wirelength, scan.wirelength)
        np.testing.assert_array_equal(conc.occ_count, scan.occ_count)

    def test_unknown_engine_rejected(self, netbatch):
        nets, w, h = netbatch
        with pytest.raises(ValueError, match="engine"):
            batched_route(nets, w, h, engine="astar")


class TestStillValidBound:
    def test_manhattan_entry(self):
        e = _Buffered(cells=np.zeros(0, np.int64), wl=5, ok=True,
                      d0max=4, dist=None, hub=(0, 0))
        far = (np.array([3]), np.array([3]))      # |3|+|3| = 6 >= 4
        near = (np.array([1]), np.array([2]))     # |1|+|2| = 3 <  4
        assert _still_valid(e, *far, stride=14)
        assert not _still_valid(e, *near, stride=14)
        edge = (np.array([2]), np.array([2]))     # exactly d0max: still ok
        assert _still_valid(e, *edge, stride=14)

    def test_dist_field_entry(self):
        gh, gw = 6, 10
        stride = gw + 2
        dist = np.full((gh + 2) * stride, 2 ** 29, np.int32)
        dist[canvas_index(1, 1, stride)] = 2
        e = _Buffered(cells=np.zeros(0, np.int64), wl=4, ok=True,
                      d0max=3, dist=dist, hub=None)
        assert not _still_valid(e, np.array([1]), np.array([1]), stride)
        e2 = _Buffered(cells=np.zeros(0, np.int64), wl=3, ok=True,
                       d0max=2, dist=dist, hub=None)
        assert _still_valid(e2, np.array([1]), np.array([1]), stride)

    def test_failed_and_trivial_entries_always_valid(self):
        failed = _Buffered(cells=np.zeros(0, np.int64), wl=0, ok=False,
                           d0max=9, dist=None, hub=(0, 0))
        trivial = _Buffered(cells=np.zeros(0, np.int64), wl=0, ok=True,
                            d0max=-1, dist=None, hub=(0, 0))
        yx = (np.array([0]), np.array([0]))
        assert _still_valid(failed, *yx, stride=14)
        assert _still_valid(trivial, *yx, stride=14)


class TestDistillAndLayout:
    def test_explore_to_batched_layouts(self):
        from repro.core.explorer import distill_and_layout

        # agile distillation thresholds keep the laid-out batch small
        distilled, layouts = distill_and_layout(
            4096, pop_size=48, generations=10, seed=0,
            min_tops=0.5, min_snr_db=10.0)
        assert len(distilled) == len(layouts) >= 2
        rows = layouts.metrics_rows()
        assert all(r["drc_clean"] for r in rows)
        assert [(r["h"], r["w"], r["l"], r["b_adc"]) for r in rows] \
            == [s.as_tuple() for s in distilled.specs]

    def test_overfiltered_raises(self):
        from repro.core.explorer import distill_and_layout

        with pytest.raises(ValueError):
            distill_and_layout(4096, pop_size=32, generations=5,
                               min_tops=1e9)
