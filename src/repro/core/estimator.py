"""ACIM performance-estimation model (paper Eqs. 2-11), vectorized in JAX.

Every public function accepts (h, w, l, b_adc) as scalars or equal-shaped
arrays and is `jit`/`vmap`-safe; the NSGA-II explorer evaluates whole
populations in one fused XLA call (the paper evaluates per-individual on a
Xeon — the vectorized evaluation is one of our TPU adaptations).

Model summary
-------------
SNR   (Eqs. 2-6): harmonic combination of input-quantization SQNR_i,
       analog noise SNR_a (cap mismatch + kT/C thermal + charge injection),
       and ADC quantization SQNR_y.  Dot-product length N = H/L.
SNR   (Eq. 11, simplified): 6*B - 10log10(H/L) - 10log10(k3/C0) + k4,
       with (k3, k4) fitted from the full model (`fit_eq11_constants`).
T     (Eq. 7): (H/L)*W / (t_com + t_set + t_conv); t_set = 0.69*tau*B,
       t_conv = t_conv_bit * B.  Reported as OPS = 2 * MACs.
E     (Eqs. 8-9): E_cc + E_ADC/(H/L) per 1b-MAC;
       E_ADC = k1*(B + log2 Vdd) + k2*4^B*Vdd^2  (Murmann [29]).
A     (Eq. 10): A_SRAM + A_LC/L + A_COMP/H + B*A_DFF/H   [F^2/bit].
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constants import CAL28, CalibConstants

Array = jax.Array


# ----------------------------------------------------------------------
# SNR: full model, Eqs. 2-6
# ----------------------------------------------------------------------
def sqnr_input(n, cal: CalibConstants = CAL28):
    """SQNR_i = sigma_y0^2 / sigma_qi^2  (Eqs. 3-4), linear scale.

    For 1-bit signals the inputs are natively discrete, so input
    quantization noise vanishes; the paper's experiments are 1b x 1b and
    Eq. 11 carries no B_x/B_w term.  We keep the generic multi-bit form
    and return +inf when B_x == B_w == 1.
    """
    n = jnp.asarray(n, jnp.float32)
    if cal.b_w == 1 and cal.b_x == 1:
        return jnp.full_like(n, jnp.inf)
    delta_w = cal.w_m * 2.0 ** (-cal.b_w + 1)
    delta_x = cal.x_m * 2.0 ** (-cal.b_x)
    var_qi = (n / 12.0) * (delta_x**2 * cal.sigma_w**2 + delta_w**2 * cal.e_x2)
    var_y0 = n * cal.sigma_w**2 * cal.e_x2
    return var_y0 / var_qi


def snr_analog(n, cal: CalibConstants = CAL28):
    """SNR_a = sigma_y0^2 / sigma_eta^2  (Eq. 5), linear scale.

    sigma_eta^2 = (2/3)(1-4^-Bw) * N * (E[x^2] sigma_C0^2/C0^2
                                        + 2 sigma_theta^2 / Vdd^2
                                        + sigma_inj^2)
    with sigma_C0/C0 = kappa/sqrt(C0_fF) (metal-fringe mismatch [28]) and
    sigma_theta^2 = kT/C0.  N cancels against sigma_y0^2 = N sigma_w^2 E[x^2]:
    SNR_a is design-point independent for fixed C0 — which is exactly why
    Eq. 11 folds it into the constant -10log10(k3/C0) + k4 term.
    """
    n = jnp.asarray(n, jnp.float32)
    c0_f = cal.c0_ff * 1e-15
    mism_rel = (cal.kappa / np.sqrt(cal.c0_ff)) ** 2          # (sigma_C0/C0)^2
    therm_rel = 2.0 * (cal.kt / c0_f) / cal.v_dd**2           # 2 sigma_th^2/Vdd^2
    pref = (2.0 / 3.0) * (1.0 - 4.0 ** (-cal.b_w))
    var_eta_per_n = pref * (cal.e_x2 * mism_rel + therm_rel + cal.sigma_inj2)
    var_y0_per_n = cal.sigma_w**2 * cal.e_x2
    return jnp.broadcast_to(var_y0_per_n / var_eta_per_n, n.shape)


def sqnr_adc_db(n, b_adc, cal: CalibConstants = CAL28):
    """SQNR_y in dB (Eq. 6): 6*B_y + 4.8 - (zeta_x + zeta_w)_dB - 10log10(N)."""
    n = jnp.asarray(n, jnp.float32)
    b = jnp.asarray(b_adc, jnp.float32)
    return 6.0 * b + 4.8 - (cal.zeta_x_db + cal.zeta_w_db) - 10.0 * jnp.log10(n)


def snr_total_db(h, l, b_adc, cal: CalibConstants = CAL28):
    """SNR_T (Eq. 2): harmonic combination of SNR_pre and SQNR_y, in dB."""
    h = jnp.asarray(h, jnp.float32)
    l = jnp.asarray(l, jnp.float32)
    n = h / l
    inv_pre = 1.0 / snr_analog(n, cal) + 1.0 / sqnr_input(n, cal)
    sqnr_y = 10.0 ** (sqnr_adc_db(n, b_adc, cal) / 10.0)
    snr_t = 1.0 / (inv_pre + 1.0 / sqnr_y)
    return 10.0 * jnp.log10(snr_t)


# ----------------------------------------------------------------------
# SNR: simplified Eq. 11
# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=8)
def fit_eq11_constants(cal: CalibConstants = CAL28) -> tuple[float, float]:
    """Fit (k3, k4) of Eq. 11 against the full model over the feasible space.

    Eq. 11: SNR_dB = 6*B - 10log10(H/L) - 10log10(k3/C0) + k4.
    We absorb the fit into the combined constant
        c = -10log10(k3/C0) + k4
    (only the combination is observable for fixed C0) and additionally
    report k3 derived analytically from Eq. 5 so that the C0 dependence is
    faithful:  k3 = pref * (E[x^2]*kappa^2 + 2*kT*1e15/Vdd^2) / (sw^2 E[x^2])
    in fF units, then k4 = c + 10log10(k3/C0).
    """
    pref = (2.0 / 3.0) * (1.0 - 4.0 ** (-cal.b_w))
    k3 = pref * (cal.e_x2 * cal.kappa**2 + 2.0 * cal.kt * 1e15 / cal.v_dd**2) / (
        cal.sigma_w**2 * cal.e_x2)
    # least-squares for the additive constant c over the feasible grid
    pts = []
    for he in range(4, 13):
        for le in range(1, 6):
            for b in range(1, 9):
                if le <= he and (he - le) >= b:
                    pts.append((2**he, 2**le, b))
    hh = np.array([p[0] for p in pts], np.float32)
    ll = np.array([p[1] for p in pts], np.float32)
    bb = np.array([p[2] for p in pts], np.float32)
    full = np.asarray(snr_total_db(hh, ll, bb, cal))
    base = 6.0 * bb - 10.0 * np.log10(hh / ll)
    c = float(np.mean(full - base))
    k4 = c + 10.0 * float(np.log10(k3 / cal.c0_ff))
    return float(k3), float(k4)


def snr_simplified_db(h, l, b_adc, cal: CalibConstants = CAL28):
    """Eq. 11 with fitted (k3, k4)."""
    k3, k4 = fit_eq11_constants(cal)
    h = jnp.asarray(h, jnp.float32)
    l = jnp.asarray(l, jnp.float32)
    b = jnp.asarray(b_adc, jnp.float32)
    return 6.0 * b - 10.0 * jnp.log10(h / l) - 10.0 * np.log10(k3 / cal.c0_ff) + k4


# ----------------------------------------------------------------------
# Throughput, Eq. 7
# ----------------------------------------------------------------------
def cycle_time_s(b_adc, cal: CalibConstants = CAL28):
    b = jnp.asarray(b_adc, jnp.float32)
    t_set = 0.69 * cal.tau * b
    t_conv = cal.t_conv_bit * b
    return cal.t_com + t_set + t_conv


def throughput_ops(h, w, l, b_adc, cal: CalibConstants = CAL28):
    """Eq. 7 in OPS (1 MAC = 2 ops).  One conversion yields (H/L)*W MACs."""
    h = jnp.asarray(h, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    l = jnp.asarray(l, jnp.float32)
    macs_per_cycle = (h / l) * w
    return 2.0 * macs_per_cycle / cycle_time_s(b_adc, cal)


# ----------------------------------------------------------------------
# Energy, Eqs. 8-9
# ----------------------------------------------------------------------
def adc_energy_fj(b_adc, cal: CalibConstants = CAL28):
    """Eq. 9 (Murmann): E_ADC = k1*(B + log2 Vdd) + k2*4^B*Vdd^2, in fJ."""
    b = jnp.asarray(b_adc, jnp.float32)
    return cal.k1_fj * (b + jnp.log2(cal.v_dd)) + cal.k2_fj * 4.0**b * cal.v_dd**2


def energy_per_mac_fj(h, l, b_adc, cal: CalibConstants = CAL28):
    """Eq. 8: per-1b-MAC energy; the ADC is amortized over H/L MACs."""
    h = jnp.asarray(h, jnp.float32)
    l = jnp.asarray(l, jnp.float32)
    return cal.e_cc_fj + adc_energy_fj(b_adc, cal) / (h / l)


def energy_efficiency_tops_w(h, l, b_adc, cal: CalibConstants = CAL28):
    """TOPS/W = 2 ops / E_mac; with E in fJ this is 2000/E_fJ."""
    return 2000.0 / energy_per_mac_fj(h, l, b_adc, cal)


# ----------------------------------------------------------------------
# Area, Eq. 10
# ----------------------------------------------------------------------
def area_f2_per_bit(h, l, b_adc, cal: CalibConstants = CAL28):
    h = jnp.asarray(h, jnp.float32)
    l = jnp.asarray(l, jnp.float32)
    b = jnp.asarray(b_adc, jnp.float32)
    return cal.a_sram + cal.a_lc / l + cal.a_comp / h + b * cal.a_dff / h


# ----------------------------------------------------------------------
# Objective stack (Eq. 12): minimize [-f_SNR, -f_T, f_E, f_A]
# ----------------------------------------------------------------------
def objectives(h, w, l, b_adc, cal: CalibConstants = CAL28) -> Array:
    """Stack the four objectives, minimization orientation, shape (..., 4).

    Delegates to `objectives_from_operands` so the Eqs. 2-11 physics exists
    in exactly one place (the operand-traced form the explorers compile)."""
    return objectives_from_operands(h, w, l, b_adc, cal_operands(cal))


OBJECTIVE_NAMES = ("neg_snr_db", "neg_tops", "energy_fj_per_mac", "area_f2_per_bit")


# ----------------------------------------------------------------------
# Traced calibration operands (one-compile sweep support)
# ----------------------------------------------------------------------
class CalOperands(NamedTuple):
    """Calibration constants as traced f32 scalars.

    `objectives()` closes over a static `CalibConstants`, so every distinct
    calibration (and, upstream, every distinct array size) forces a retrace.
    `CalOperands` carries the same physics as *operand* arrays: the batched
    explorer vmaps one compiled program over a stack of these.  Design-point
    independent combinations (the pre-ADC inverse SNR, the ADC dB offset)
    are folded on the host so the traced math stays minimal.
    """

    inv_pre: Array        # 1/SNR_a + 1/SQNR_i (linear; N-independent, Eqs. 3-5)
    adc_off_db: Array     # 4.8 - zeta_x_dB - zeta_w_dB  (Eq. 6 constant)
    t_com: Array          # [s]
    t_set_per_b: Array    # 0.69 * tau [s/bit]
    t_conv_bit: Array     # [s/bit]
    e_cc_fj: Array        # E_compute + E_control [fJ]
    k1_fj: Array
    k2_fj: Array
    log2_vdd: Array
    vdd2: Array
    a_sram: Array
    a_lc: Array
    a_comp: Array
    a_dff: Array


def cal_operands(cal: CalibConstants = CAL28) -> CalOperands:
    """Fold a static `CalibConstants` into traced scalar operands."""
    n_probe = jnp.float32(1.0)  # SNR_a and SQNR_i are N-independent (see Eq. 5)
    inv_pre = 1.0 / snr_analog(n_probe, cal) + 1.0 / sqnr_input(n_probe, cal)
    f32 = lambda v: jnp.float32(v)  # noqa: E731
    return CalOperands(
        inv_pre=jnp.reshape(inv_pre, ()).astype(jnp.float32),
        adc_off_db=f32(4.8 - cal.zeta_x_db - cal.zeta_w_db),
        t_com=f32(cal.t_com),
        t_set_per_b=f32(0.69 * cal.tau),
        t_conv_bit=f32(cal.t_conv_bit),
        e_cc_fj=f32(cal.e_cc_fj),
        k1_fj=f32(cal.k1_fj),
        k2_fj=f32(cal.k2_fj),
        log2_vdd=f32(np.log2(cal.v_dd)),
        vdd2=f32(cal.v_dd**2),
        a_sram=f32(cal.a_sram),
        a_lc=f32(cal.a_lc),
        a_comp=f32(cal.a_comp),
        a_dff=f32(cal.a_dff),
    )


def objectives_from_operands(h, w, l, b_adc, ops: CalOperands) -> Array:
    """Eq. 12 objective stack with *traced* calibration operands.

    Same model as `objectives()` (Eqs. 2-11) but every constant is an
    operand, so one compiled program serves any (array size, calibration)
    batch.  Shapes broadcast: scalar operands with (...,) design points, or
    leading batch dims on both under `vmap`.
    """
    h = jnp.asarray(h, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    l = jnp.asarray(l, jnp.float32)
    b = jnp.asarray(b_adc, jnp.float32)
    n = h / l
    # SNR_T (Eqs. 2-6): pre-ADC inverse SNR is a folded constant.
    sqnr_y_db = 6.0 * b + ops.adc_off_db - 10.0 * jnp.log10(n)
    sqnr_y = 10.0 ** (sqnr_y_db / 10.0)
    snr_db = 10.0 * jnp.log10(1.0 / (ops.inv_pre + 1.0 / sqnr_y))
    # Throughput (Eq. 7), TOPS.
    t_cycle = ops.t_com + ops.t_set_per_b * b + ops.t_conv_bit * b
    tops = 2.0 * n * w / t_cycle / 1e12
    # Energy (Eqs. 8-9), fJ per 1b MAC.
    e_adc = ops.k1_fj * (b + ops.log2_vdd) + ops.k2_fj * 4.0**b * ops.vdd2
    e = ops.e_cc_fj + e_adc / n
    # Area (Eq. 10), F^2/bit.
    a = ops.a_sram + ops.a_lc / l + ops.a_comp / h + b * ops.a_dff / h
    return jnp.stack([-snr_db, -tops, e, a], axis=-1)


def evaluate_report(h, w, l, b_adc, cal: CalibConstants = CAL28) -> dict:
    """Human-oriented metrics for one or more design points."""
    return {
        "snr_db": snr_total_db(h, l, b_adc, cal),
        "snr_eq11_db": snr_simplified_db(h, l, b_adc, cal),
        "tops": throughput_ops(h, w, l, b_adc, cal) / 1e12,
        "energy_fj_per_mac": energy_per_mac_fj(h, l, b_adc, cal),
        "tops_per_w": energy_efficiency_tops_w(h, l, b_adc, cal),
        "area_f2_per_bit": area_f2_per_bit(h, l, b_adc, cal),
        "cycle_ns": cycle_time_s(b_adc, cal) * 1e9,
    }
