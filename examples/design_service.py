"""Multi-tenant design service demo: concurrent users, one dispatch.

Several tenants submit different `DesignRequest`s — different array
sizes, seeds, and application requirements — and the `DesignService`
coalesces them: one compiled MOGA sweep program runs every tenant's
cell in a single device dispatch, and the union of surviving specs is
laid out in routing-grid-shape buckets before being demuxed back into
per-ticket artifacts.

  PYTHONPATH=src python examples/design_service.py
"""
from repro.api import DesignRequest, Requirements
from repro.serve.design_service import DesignService

TENANTS = {
    "edge-snr": DesignRequest(
        array_size=4096, pop_size=96, generations=30,
        requirements=Requirements(min_snr_db=20.0)),
    "edge-tops": DesignRequest(
        array_size=4096, pop_size=96, generations=30, seed=1,
        requirements=Requirements(min_tops=0.5, min_snr_db=15.0)),
    # screening query: Pareto front only, no layouts
    "cloud-eff": DesignRequest(
        array_size=16384, pop_size=96, generations=30,
        requirements=Requirements(min_tops_per_w=100.0), layout=False),
}


def main() -> None:
    svc = DesignService()
    tickets = {name: svc.submit(req) for name, req in TENANTS.items()}
    done = svc.run()

    for name, ticket in tickets.items():
        art = done[ticket]
        p = art.provenance
        if not art.ok or not len(art.pareto):
            print(f"{name:10s} ticket={ticket} | no surviving solution "
                  f"({art.error or 'requirements removed every point'})")
            continue
        best = art.pareto.best("tops_per_w")
        laid = ("front only" if art.layout_rows is None
                else f"{p.layout_dispatches} layout bucket(s)")
        print(f"{name:10s} ticket={ticket} | {len(art.pareto)} survivors, "
              f"best H={best.h} W={best.w} L={best.l} B={best.b_adc} | "
              f"coalesced with {p.coalesced - 1} other request(s), {laid}")
    s = svc.stats
    print(f"\nservice: {s['requests_served']} requests -> "
          f"{s['explorer_dispatches']} explorer dispatch(es), "
          f"{s['run_cell_traces']} sweep-program trace(s), "
          f"{s['layout_dispatches']} layout bucket dispatch(es)")


if __name__ == "__main__":
    main()
