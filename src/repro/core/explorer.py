"""MOGA-based design-space explorer (paper Sec. 3.2) with agile filtering.

`explore()` runs NSGA-II for a user-given array size and returns a
`ParetoResult`: the deduplicated Pareto-frontier set with both raw objective
values and human-oriented metrics.  `ParetoResult.filter(...)` implements the
paper's "agile interaction": users prune the frontier with application
requirements (min SNR, min throughput, max energy, max area) before handing
the survivors to the netlist generator / placer / router
(`repro.eda.flow.generate_layout`).

One-compile sweep contract: every front-end path bottoms out in
`repro.core.batched_explorer.explore_cells` — the array size, gene
bounds, and calibration constants are traced operands of a single
compiled NSGA-II program (`repro.core.nsga2.run_cell`), so a whole
(array_size x seed) sweep is one trace, one compile, and one device
dispatch.  The per-cell fronts are identical to the sequential
`nsga2.run` reference path.

Front-end note: the supported way to drive the flow is `repro.api`
(`DesignRequest` / `DesignSession` / the multi-tenant
`repro.serve.design_service.DesignService`).  `explore()`,
`explore_sizes()` and `distill_and_layout()` below are deprecation
shims over it, kept for source compatibility.
"""
from __future__ import annotations

import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator, nsga2, pareto
from repro.core.acim_spec import MacroSpec
from repro.core.constants import CAL28, CalibConstants


@dataclasses.dataclass(frozen=True)
class ParetoResult:
    array_size: int
    specs: tuple[MacroSpec, ...]          # deduplicated Pareto-frontier set
    metrics: dict                          # name -> np.ndarray aligned w/ specs

    def __len__(self) -> int:
        return len(self.specs)

    def filter(self, *, min_snr_db: float = -np.inf, min_tops: float = 0.0,
               max_energy_fj: float = np.inf, max_area: float = np.inf,
               min_tops_per_w: float = 0.0) -> "ParetoResult":
        """Agile user distillation of the Pareto set (paper Fig. 4, arrow
        'remove undesired solutions')."""
        if not self.specs:
            raise ValueError(
                "cannot filter an empty Pareto frontier (an earlier filter "
                "already removed every solution)")
        m = self.metrics
        keep = ((m["snr_db"] >= min_snr_db) & (m["tops"] >= min_tops)
                & (m["energy_fj_per_mac"] <= max_energy_fj)
                & (m["area_f2_per_bit"] <= max_area)
                & (m["tops_per_w"] >= min_tops_per_w))
        idx = np.nonzero(keep)[0]
        return ParetoResult(
            self.array_size,
            tuple(self.specs[i] for i in idx),
            {k: v[idx] for k, v in m.items()},
        )

    def best(self, metric: str, maximize: bool = True) -> MacroSpec:
        if not self.specs:
            raise ValueError(
                f"cannot select best({metric!r}) from an empty Pareto "
                f"frontier; relax the filter requirements")
        v = self.metrics[metric]
        i = int(np.argmax(v) if maximize else np.argmin(v))
        return self.specs[i]

    def to_rows(self) -> list[dict]:
        rows = []
        for i, s in enumerate(self.specs):
            row = {"h": s.h, "w": s.w, "l": s.l, "b_adc": s.b_adc}
            row.update({k: float(v[i]) for k, v in self.metrics.items()})
            rows.append(row)
        return rows

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"array_size": self.array_size, "points": self.to_rows()},
                      f, indent=1)

    @classmethod
    def from_rows(cls, array_size: int, rows: list[dict]) -> "ParetoResult":
        """Rebuild from `to_rows()` output.  Metric arrays come back as
        float64 (exact widenings of the stored floats); an empty row list
        yields an empty frontier with no metric columns."""
        spec_keys = ("h", "w", "l", "b_adc")
        specs = tuple(MacroSpec(*(int(r[k]) for k in spec_keys))
                      for r in rows)
        metric_keys = [k for k in (rows[0] if rows else {})
                       if k not in spec_keys]
        metrics = {k: np.array([r[k] for r in rows]) for k in metric_keys}
        return cls(int(array_size), specs, metrics)

    @classmethod
    def from_json(cls, path: str) -> "ParetoResult":
        """Inverse of `to_json`: load a frontier back from disk."""
        with open(path) as f:
            d = json.load(f)
        return cls.from_rows(d["array_size"], d["points"])


def _dedup_pareto(genes: np.ndarray, objs: np.ndarray):
    """Unique genes restricted to the non-dominated set."""
    uniq, idx = np.unique(genes, axis=0, return_index=True)
    objs_u = objs[idx]
    mask = np.asarray(pareto.non_dominated_mask(jnp.asarray(objs_u)))
    return uniq[mask], objs_u[mask]


def pareto_result_from_population(array_size: int, genes: np.ndarray,
                                  objs: np.ndarray,
                                  cal: CalibConstants = CAL28) -> ParetoResult:
    """Distill a final NSGA-II population into a `ParetoResult`."""
    genes, _ = _dedup_pareto(np.asarray(genes), np.asarray(objs))
    h = (2 ** genes[:, 0]).astype(np.int64)
    w = (array_size // h).astype(np.int64)
    l = (2 ** genes[:, 1]).astype(np.int64)
    b = genes[:, 2].astype(np.int64)
    specs = tuple(MacroSpec(int(hh), int(ww), int(ll), int(bb))
                  for hh, ww, ll, bb in zip(h, w, l, b))
    rep = estimator.evaluate_report(h.astype(np.float32), w.astype(np.float32),
                                    l.astype(np.float32), b.astype(np.float32), cal)
    metrics = {k: np.asarray(v) for k, v in rep.items()}
    return ParetoResult(array_size, specs, metrics)


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.core.explorer.{old} is deprecated; use {new} "
        f"(see docs/api.md)", DeprecationWarning, stacklevel=3)


def explore(array_size: int, *, pop_size: int = 256, generations: int = 80,
            seed: int = 0, cal: CalibConstants = CAL28,
            use_pallas_dominance: bool = False,
            use_pallas_rank: bool = False) -> ParetoResult:
    """Deprecated shim over `repro.api`: run the MOGA explorer for one
    array size and return the (undistilled) `ParetoResult`.

    Use `DesignSession().run(DesignRequest(array_size, layout=False))`
    instead; repeated shim calls share the process-wide default session's
    program and front caches."""
    from repro.api import DesignRequest, default_session

    _deprecated("explore", "repro.api.DesignSession.run")
    req = DesignRequest(array_size=array_size, seed=seed, pop_size=pop_size,
                        generations=generations, cal=cal,
                        use_pallas_dominance=use_pallas_dominance,
                        use_pallas_rank=use_pallas_rank, layout=False)
    return default_session().run(req).pareto


def explore_sizes(sizes=(4096, 16384, 65536), *, seed: int = 0,
                  **kw) -> dict[int, ParetoResult]:
    """Deprecated shim over `repro.api`: Fig. 9(a)(b)-style sweep over
    array sizes, coalesced by a `DesignService` into one compiled
    program / one dispatch for the whole sweep."""
    from repro.api import DesignRequest, default_session
    from repro.serve.design_service import DesignService

    _deprecated("explore_sizes", "repro.serve.design_service.DesignService")
    sizes = tuple(sizes)
    svc = DesignService(session=default_session(),
                        max_coalesce=max(len(sizes), 1))
    tickets = {int(s): svc.submit(DesignRequest(
        array_size=int(s), seed=seed, layout=False, **kw)) for s in sizes}
    arts = svc.run()
    return {s: arts[tickets[int(s)]].pareto for s in sizes}


def distill_and_layout(array_size: int, *, pop_size: int = 256,
                       generations: int = 80, seed: int = 0,
                       cal: CalibConstants = CAL28, coarse: int = 64,
                       capacity: int = 4, use_pallas_dominance: bool = False,
                       use_pallas_rank: bool = False, **filter_kw):
    """Deprecated shim over `repro.api`: MOGA sweep -> agile distillation
    -> batched layout generation (paper Fig. 4 end to end).

    `filter_kw` are `ParetoResult.filter` thresholds (the
    `repro.api.Requirements` fields).  Returns `(distilled, layouts)`
    exactly like `DesignSession.run(...)`'s artifact carries them."""
    from repro.api import DesignRequest, Requirements, default_session

    _deprecated("distill_and_layout", "repro.api.DesignSession.run")
    req = DesignRequest(array_size=array_size, seed=seed, pop_size=pop_size,
                        generations=generations, cal=cal,
                        use_pallas_dominance=use_pallas_dominance,
                        use_pallas_rank=use_pallas_rank,
                        requirements=Requirements(**filter_kw),
                        coarse=coarse, capacity=capacity, layout=True)
    artifact = default_session().run(req)
    return artifact.pareto, artifact.layouts


def full_design_space(array_size: int, cal: CalibConstants = CAL28):
    """Exhaustive enumeration of the (small, power-of-two) feasible space.

    The feasible space per array size is tiny (< 400 points), so exhaustive
    evaluation is tractable; the explorer's value is (a) fidelity to the
    paper's flow, (b) scaling to non-power-of-two/continuous extensions, and
    (c) this enumeration gives the tests a ground-truth Pareto front to
    compare NSGA-II against.
    """
    cfg = nsga2.NSGA2Config(array_size=array_size, cal=cal)
    h_lo, h_hi = cfg.h_exp_bounds
    l_lo, l_hi = cfg.l_exp_bounds
    b_lo, b_hi = cfg.b_bounds
    pts = [(he, le, b)
           for he in range(h_lo, h_hi + 1)
           for le in range(l_lo, min(l_hi, he) + 1)
           for b in range(b_lo, min(b_hi, he - le) + 1)]
    genes = jnp.asarray(np.array(pts, np.int32))
    objs = nsga2.evaluate(genes, cfg)
    return genes, objs
