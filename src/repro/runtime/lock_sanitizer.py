"""Runtime lock-order sanitizer: the dynamic companion of
`repro.analysis.lock_discipline`.

Env-gated (``REPRO_LOCK_SANITIZER=1``): the threaded code paths create
their locks through :func:`make_lock` / :func:`make_condition`, which
return plain ``threading`` primitives when the gate is off (zero
overhead) and named :class:`InstrumentedLock` wrappers when it is on.
Instrumented locks record, per thread, the stack of locks held at every
acquisition; each acquisition while another lock is held contributes an
edge ``held -> acquired`` to a global acquisition-order graph.

At test-suite teardown (`tests/conftest.py`) — or any time via
:func:`assert_clean` — a cycle in that graph is reported as an
AssertionError naming the inversion, the same property the static
lock-order pass proves over ``with`` blocks.  The dynamic view catches
what static analysis cannot: acquisition orders through callbacks,
``Condition.wait`` reacquisitions, and data-dependent paths.

Re-acquiring a non-reentrant instrumented lock on the same thread is
reported *immediately* (it would deadlock for real), with both
acquisition sites named.
"""
from __future__ import annotations

import os
import threading

ENV_FLAG = "REPRO_LOCK_SANITIZER"


def enabled() -> bool:
    return os.environ.get(ENV_FLAG, "").strip().lower() not in (
        "", "0", "false", "no")


class LockOrderRegistry:
    """Global acquisition-order graph over named locks."""

    def __init__(self) -> None:
        self._mu = threading.Lock()          # raw: guards the graph itself
        self._edges: dict[tuple[str, str], int] = {}
        self._held = threading.local()

    # -- per-thread held stack ----------------------------------------
    def _stack(self) -> list[str]:
        st = getattr(self._held, "stack", None)
        if st is None:
            st = self._held.stack = []
        return st

    def check_deadlock(self, name: str) -> None:
        """Raise if the current thread already holds ``name``.  Must run
        *before* blocking on the underlying lock — a same-thread
        re-acquisition would otherwise deadlock for real instead of
        reporting."""
        stack = self._stack()
        if name in stack:
            raise AssertionError(
                f"lock sanitizer: {name} acquired while already held on "
                f"{threading.current_thread().name} (held: {stack}) — "
                f"guaranteed deadlock")

    def note_acquire(self, name: str, *, reentrant: bool = False) -> None:
        stack = self._stack()
        if not reentrant:
            self.check_deadlock(name)
        if stack:
            edge = (stack[-1], name)
            if edge[0] != edge[1]:
                with self._mu:
                    self._edges[edge] = self._edges.get(edge, 0) + 1
        stack.append(name)

    def note_release(self, name: str) -> None:
        stack = self._stack()
        if name in stack:
            # remove the innermost occurrence: releases may be
            # out-of-order under Condition.wait bookkeeping
            for i in range(len(stack) - 1, -1, -1):
                if stack[i] == name:
                    del stack[i]
                    break

    # -- verdicts ------------------------------------------------------
    def edges(self) -> dict[tuple[str, str], int]:
        with self._mu:
            return dict(self._edges)

    def find_cycle(self) -> list[str] | None:
        edges = self.edges()
        adj: dict[str, set[str]] = {}
        for a, b in edges:
            adj.setdefault(a, set()).add(b)

        def dfs(start: str, node: str, path: list[str]) -> list[str] | None:
            for nxt in sorted(adj.get(node, ())):
                if nxt == start:
                    return path
                if nxt not in path and len(path) < 8:
                    hit = dfs(start, nxt, path + [nxt])
                    if hit is not None:
                        return hit
            return None

        for start in sorted(adj):
            cyc = dfs(start, start, [start])
            if cyc is not None:
                return cyc
        return None

    def assert_clean(self) -> None:
        cyc = self.find_cycle()
        if cyc is not None:
            counts = self.edges()
            detail = ", ".join(
                f"{a}->{b} (x{counts.get((a, b), 0)})"
                for a, b in zip(cyc, cyc[1:] + cyc[:1]))
            raise AssertionError(
                "lock sanitizer: acquisition-order inversion observed: "
                + " -> ".join(cyc + [cyc[0]]) + f" [{detail}]")

    def reset(self) -> None:
        with self._mu:
            self._edges.clear()


#: process-wide registry the instrumented locks report into
GLOBAL_REGISTRY = LockOrderRegistry()


class InstrumentedLock:
    """A named ``threading.Lock`` that reports acquisition order.

    Duck-types a plain lock (``acquire`` / ``release`` / context
    manager / ``locked``), so ``threading.Condition`` can wrap it: the
    Condition's own ``wait()`` release/reacquire cycles route through
    these methods and are order-checked like any other acquisition.
    """

    def __init__(self, name: str,
                 registry: LockOrderRegistry | None = None) -> None:
        self.name = name
        self._registry = registry or GLOBAL_REGISTRY
        self._inner = threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        # Pre-check before blocking: a same-thread re-acquisition must
        # raise, not sit on the inner lock forever.  Non-blocking probes
        # are exempt — they cannot deadlock, and Condition._is_owned
        # legitimately tries acquire(False) on a lock it already holds.
        if blocking:
            self._registry.check_deadlock(self.name)
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._registry.note_acquire(self.name)
        return got

    def release(self) -> None:
        self._registry.note_release(self.name)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<InstrumentedLock {self.name} {self._inner!r}>"


def make_lock(name: str):
    """A lock for a threaded subsystem: instrumented under the
    sanitizer gate, a plain ``threading.Lock`` otherwise."""
    if enabled():
        return InstrumentedLock(name)
    return threading.Lock()


def make_condition(lock):
    """A ``threading.Condition`` over a :func:`make_lock` result (plain
    or instrumented — Condition only needs acquire/release)."""
    return threading.Condition(lock)
