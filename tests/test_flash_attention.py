"""Flash attention Pallas kernel vs naive oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention


def _qkv(seed, b, s, t, h, kv, dh):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kv, dh), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kv,dh,causal", [
    (1, 128, 4, 4, 64, True),
    (2, 256, 8, 2, 64, True),
    (1, 128, 4, 1, 128, True),
    (2, 64, 2, 2, 32, False),
])
def test_matches_oracle(b, s, h, kv, dh, causal):
    q, k, v = _qkv(b * 31 + s, b, s, s, h, kv, dh)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    g = h // kv
    kx = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    vx = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    ref = attention_ref(qf, kx, vx, causal=causal)
    ref = ref.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_matches_model_blockwise_core():
    """Three-way: Pallas kernel == jnp blockwise core == naive."""
    from repro.models.attention import _blockwise_core

    b, s, kv, g, dh = 2, 128, 2, 2, 64
    q = jax.random.normal(jax.random.key(0), (b, s, kv, g, dh))
    k = jax.random.normal(jax.random.key(1), (b, s, kv, dh))
    v = jax.random.normal(jax.random.key(2), (b, s, kv, dh))
    core = _blockwise_core(q, k, v, kv_block=32, prefix_len=0,
                           out_dtype=jnp.float32)
    qh = q.reshape(b, s, kv * g, dh)
    out = flash_attention(qh, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(core.reshape(b, s, kv * g, dh)),
                               np.asarray(out), atol=3e-5, rtol=3e-5)
