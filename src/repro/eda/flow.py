"""End-to-end layout flow (paper Fig. 4, right half): netlist generation ->
hierarchical template placement -> grid routing -> DRC-lite -> metrics +
GDS-like JSON export.

`generate_layout(spec)` is the thin single-spec path: it composes the
same vectorized components the batched flow vmaps (`placer.rect_tensors`
for placement, the `kernels.maze_route` wavefront for routing), plus the
full named-instance / wire-geometry materialization that only makes
sense one spec at a time.  To lay out a whole distilled Pareto set, use
`repro.eda.batched_flow.generate_layouts` (or
`repro.core.explorer.distill_and_layout`) — one dispatch per stage for
the entire batch, identical per-spec results.

`drc_lite` here is the host sweep-line reference; the batched flow
vectorizes the same checks as a pairwise-overlap reduction.
"""
from __future__ import annotations

import dataclasses
import json
import time

from repro.core import estimator
from repro.core.acim_spec import MacroSpec
from repro.eda import netlist as nl_mod
from repro.eda.placer import Placement, place
from repro.eda.router import RoutingResult, route


@dataclasses.dataclass
class DRCReport:
    overlaps: int
    out_of_bounds: int

    @property
    def clean(self) -> bool:
        return self.overlaps == 0 and self.out_of_bounds == 0


def drc_lite(p: Placement) -> DRCReport:
    """No-overlap + bounds checks on the placed rectangles (grid spacing is
    honored by construction inside the templates).  Sweep-line over x."""
    rects = sorted(p.rects, key=lambda r: (r.x, r.y))
    overlaps = 0
    oob = 0
    # per-column buckets: templates abut but must not overlap
    active: list = []
    for r in rects:
        if r.y + r.h > p.height + 1 or r.x + r.w > p.width + 1:
            oob += 1
        active = [a for a in active if a.x + a.w > r.x]
        for a in active:
            if a.name.split("_")[0] != r.name.split("_")[0]:
                continue  # different columns can't overlap by construction
            if r.x < a.x + a.w and a.x < r.x + r.w and \
                    r.y < a.y + a.h and a.y < r.y + r.h:
                overlaps += 1
        active.append(r)
    return DRCReport(overlaps, oob)


@dataclasses.dataclass
class LayoutResult:
    spec: MacroSpec
    placement: Placement
    routing: RoutingResult
    drc: DRCReport
    netlist_stats: dict
    elapsed_s: float

    def metrics(self) -> dict:
        est_area = float(estimator.area_f2_per_bit(
            self.spec.h, self.spec.l, self.spec.b_adc))
        return {
            "h": self.spec.h, "w": self.spec.w, "l": self.spec.l,
            "b_adc": self.spec.b_adc,
            "layout_area_f2_per_bit": self.placement.area_f2_per_bit(),
            "estimator_area_f2_per_bit": est_area,
            "area_model_error": self.placement.area_f2_per_bit() / est_area - 1.0,
            "routed_nets": len(self.routing.wires),
            "failed_nets": len(self.routing.failed),
            "route_success": self.routing.success_rate,
            "wirelength": self.routing.total_wirelength,
            "drc_clean": self.drc.clean,
            "elapsed_s": self.elapsed_s,
        }

    def to_json(self, path: str) -> None:
        doc = {
            "spec": self.spec.as_tuple(),
            "metrics": self.metrics(),
            "cells": [[r.name, r.cell, r.x, r.y, r.w, r.h]
                      for r in self.placement.rects[:20000]],
            "wires": [[w.net, list(map(list, w.points))]
                      for w in self.routing.wires[:5000]],
        }
        with open(path, "w") as f:
            json.dump(doc, f)


def _top_level_nets(spec: MacroSpec, p: Placement):
    """Inter-template nets for the maze router: per-column RBL trunk
    (array foot -> comparator) and the RWL trunks (driver -> row)."""
    by_name = {r.name: r for r in p.rects}
    nets = []
    for j in range(spec.w):
        comp = by_name[f"c{j}_comp"]
        cap0 = by_name[f"c{j}_la0_cap"]
        top = by_name[f"c{j}_la{spec.n_caps - 1}_cap"]
        nets.append((f"c{j}_rbl", [(int(comp.cx), int(comp.cy)),
                                   (int(cap0.cx), int(cap0.cy)),
                                   (int(top.cx), int(top.cy))]))
        sar = by_name[f"c{j}_sar"]
        nets.append((f"c{j}_cmp", [(int(comp.cx), int(comp.cy)),
                                   (int(sar.cx), int(sar.cy))]))
    for r in range(min(spec.h, nl_mod.MAX_ROW_DRIVERS)):
        drv = by_name.get(f"rd{r}")
        if drv is None:
            continue
        la, k = divmod(r, spec.l)
        far = by_name.get(f"c{spec.w - 1}_la{la}_s{k}")
        if far is not None:
            nets.append((f"rwl{r}", [(int(drv.cx), int(drv.cy)),
                                     (int(far.cx), int(far.cy))]))
    return nets


def generate_layout(spec: MacroSpec) -> LayoutResult:
    t0 = time.time()
    nl = nl_mod.generate(spec)
    p = place(spec)
    nets = _top_level_nets(spec, p)
    r = route(p, nets)
    d = drc_lite(p)
    return LayoutResult(spec, p, r, d, nl.stats(), time.time() - t0)
