"""`repro.api` — the unified front-end for the EasyACIM flow.

One declarative request type, one long-lived session, one service:

    from repro.api import DesignRequest, DesignSession, Requirements

    req = DesignRequest(array_size=16384,
                        requirements=Requirements(min_tops=1.0))
    artifact = DesignSession().run(req)
    artifact.to_json("artifact.json")

`DesignRequest` captures the whole query (MOGA budget, calibration,
backend knobs, application requirements, layout options);
`DesignSession` owns the compiled-program and Pareto-front caches and
optionally a persistent, cross-process `ArtifactCache`
(`DesignSession(artifact_cache="/path")`);
`repro.serve.design_service.DesignService` adds the queue-backed
multi-tenant layer (request coalescing, grid-shape layout bucketing,
and the thread-pumped `serve()` loop with latency-bounded coalescing
windows).
The legacy entry points (`repro.core.explorer.explore` and friends)
survive as thin deprecation shims over this package.
"""
from repro.api.request import DesignRequest, Requirements
from repro.api.session import (BucketResult, DesignArtifact, DesignSession,
                               DistilledBatch, ExploredBatch, LayoutBucket,
                               Provenance)
from repro.api.artifact_cache import (ArtifactCache, FileRemoteStore,
                                      RemoteStore, TicketJournal,
                                      TieredArtifactCache)

_DEFAULT_SESSION: DesignSession | None = None


def default_session() -> DesignSession:
    """Process-wide session backing the legacy shims."""
    global _DEFAULT_SESSION
    if _DEFAULT_SESSION is None:
        _DEFAULT_SESSION = DesignSession()
    return _DEFAULT_SESSION


__all__ = ["DesignRequest", "Requirements", "DesignArtifact",
           "DesignSession", "Provenance", "ArtifactCache",
           "TieredArtifactCache", "RemoteStore", "FileRemoteStore",
           "TicketJournal", "ExploredBatch", "DistilledBatch",
           "LayoutBucket", "BucketResult", "default_session"]
