"""Behavioral numerics of the synthesizable ACIM macro (paper Sec. 3.1).

This module defines the *semantics* of executing a GEMM on the generated
macro, used three ways:
  1. `repro.kernels.acim_matmul.ref` wraps `acim_matmul_ref` as the pure-jnp
     oracle for the Pallas kernel;
  2. `repro.quant.cim_linear` routes model projections through it for
     hardware-in-the-loop training/eval (quantization + analog noise);
  3. `tests/test_acim_numerics.py` Monte-Carlo-validates the analytical SNR
     model (Eqs. 2-6) against this simulation — the two halves of the paper
     check each other.

Compute model (QR, Fig. 2(c) / Fig. 6):
  * Weights are stored bit-serially in the 8T array; activations are applied
    as RWL pulses.  The paper's silicon results are 1b x 1b; multi-bit
    operands are handled bit-serially with digital shift-add (ops layer).
  * One ADC conversion digitizes the charge-redistributed average of
    N = H/L products.  In sum units the ADC input is s = sum_k x_k*w_k in
    [-N, N]; the B-bit mid-tread SAR quantizer has step delta = 2N/2^B —
    which reproduces Eq. 6's SQNR_y exactly (see tests).
  * Analog non-idealities (Eq. 5): static capacitor mismatch (a per-instance
    draw — the same hardware always errs the same way), kT/C thermal noise
    per conversion, charge injection ~ 0 (bottom-plate sampling).
  * K > N is tiled into ceil(K/N) chunks; inter-chunk accumulation is
    digital (exact), as in the real macro's output accumulator.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acim_spec import MacroSpec
from repro.core.constants import CAL28, CalibConstants

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class NoiseParams:
    """Per-element (per 1b-product) relative noise std-devs, from Eq. 5."""

    mismatch_rel: float   # sigma(dC/C) = kappa / sqrt(C0_fF): static
    thermal_rel: float    # sqrt(2 kT / C0) / Vdd: per conversion
    prefactor: float      # (2/3)(1 - 4^-Bw) bit-weighting factor

    @staticmethod
    def from_cal(cal: CalibConstants = CAL28) -> "NoiseParams":
        c0_f = cal.c0_ff * 1e-15
        return NoiseParams(
            mismatch_rel=cal.kappa / float(np.sqrt(cal.c0_ff)),
            thermal_rel=float(np.sqrt(2.0 * cal.kt / c0_f)) / cal.v_dd,
            prefactor=(2.0 / 3.0) * (1.0 - 4.0 ** (-cal.b_w)),
        )


def adc_quantize_sum(s: Array, n: int, b_adc: int) -> Array:
    """B-bit mid-tread SAR quantization of a sum in [-N, N].

    delta = 2N / 2^B; codes clipped to [-(2^(B-1)), 2^(B-1) - 1] like a real
    two's-complement SAR register.  Returns the *dequantized* sum (float).
    """
    delta = 2.0 * n / (2.0**b_adc)
    code = jnp.round(s / delta)
    code = jnp.clip(code, -(2.0 ** (b_adc - 1)), 2.0 ** (b_adc - 1) - 1.0)
    return code * delta


def _pad_k(x: Array, w: Array, n: int):
    """Zero-pad the contraction dim to a multiple of the chunk size N.

    Zero-padding is what the hardware does: unused rows of the local array
    keep their caps at V_CM and contribute no charge.
    """
    k = x.shape[-1]
    k_pad = (-k) % n
    if k_pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, k_pad)])
        w = jnp.pad(w, [(0, k_pad), (0, 0)])
    return x, w, (k + k_pad) // n


def acim_matmul_ref(x: Array, w: Array, spec: MacroSpec, *,
                    noise: NoiseParams | None = None,
                    instance_key: Array | None = None,
                    conversion_key: Array | None = None) -> Array:
    """Simulate y = x @ w on the macro.  x: (..., K) in {-1, +1} (or any
    bounded analog value |x|<=1 — the RWL pulse width); w: (K, C) in
    {-1, +1}.  Returns (..., C) float32.

    With `noise=None` the path is deterministic (ideal caps) and bit-exact
    against the Pallas kernel.  With noise, `instance_key` draws the static
    per-(chunk-position, column) capacitor mismatch and `conversion_key` the
    per-conversion thermal noise.
    """
    n, b = spec.n_caps, spec.b_adc
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    x, w, n_chunks = _pad_k(x, w, n)
    cols = w.shape[-1]
    xc = x.reshape(x.shape[:-1] + (n_chunks, n))
    wc = w.reshape(n_chunks, n, cols)

    # partial sums per chunk: (..., n_chunks, cols)
    s = jnp.einsum("...ck,ckj->...cj", xc, wc)

    if noise is not None:
        if instance_key is None or conversion_key is None:
            raise ValueError("noisy simulation needs instance_key and conversion_key")
        # static mismatch: eps per (chunk, k, col) cap; error = sum_k q_k eps_k.
        # E[q^2] = E[x^2 w^2] <= 1; we inject with the actual products to stay
        # faithful: err_mismatch = einsum(q, eps).
        eps = noise.mismatch_rel * jax.random.normal(
            instance_key, (n_chunks, n, cols), jnp.float32)
        q = xc[..., None] * wc  # (..., c, k, j) products — memory heavy for
        # large tiles; ref oracle only (kernel fuses this).
        err_mm = jnp.sum(q * eps, axis=-2)
        sigma_th = noise.thermal_rel * float(np.sqrt(n))  # sum-referred kT/C
        err_th = sigma_th * jax.random.normal(conversion_key, s.shape, jnp.float32)
        pref = float(np.sqrt(noise.prefactor))
        s = s + pref * (err_mm + err_th)

    y_hat = adc_quantize_sum(s, n, b)
    return jnp.sum(y_hat, axis=-2)


def acim_matmul_multibit_ref(x_int: Array, w_int: Array, spec: MacroSpec,
                             b_x: int, b_w: int) -> Array:
    """Bit-serial multi-bit GEMM on the macro (digital shift-add of 1b planes).

    x_int: (..., K) signed ints in [-2^(bx-1), 2^(bx-1)-1]; w_int likewise.

    Bipolar recoding keeps every plane in the macro's native {-1,+1} domain:
    with offset-binary bits u_i of (v + 2^(b-1)) and p_i = 2*u_i - 1,
        v = sum_i p_i 2^(i-1) - 1/2 .
    Expanding x.w therefore gives
        y = sum_ij 2^(i+j-2) <px_i, pw_j>  - (sum_x + sum_w)/2 - K/4 ,
    where the cross terms <px_i, pw_j> run on the macro (ADC-quantized) and
    the rank-1 corrections are exact digital arithmetic (weight sums are
    known at compile time; activation sums are a digital popcount — standard
    practice in bit-serial CIM schedules).
    """
    def planes(v, bits):
        u = v.astype(jnp.int32) + 2 ** (bits - 1)           # offset binary
        return [(((u >> i) & 1) * 2 - 1).astype(jnp.float32) for i in range(bits)]

    xs = planes(x_int, b_x)
    ws = planes(w_int, b_w)
    k = x_int.shape[-1]

    total = 0.0
    for i, px in enumerate(xs):
        for j, pw in enumerate(ws):
            total = total + 2.0 ** (i + j - 2) * acim_matmul_ref(px, pw, spec)
    sum_x = jnp.sum(x_int.astype(jnp.float32), axis=-1, keepdims=True)
    sum_w = jnp.sum(w_int.astype(jnp.float32), axis=0, keepdims=True)
    return total - 0.5 * sum_x - 0.5 * sum_w - k / 4.0


def quantize_symmetric(x: Array, bits: int):
    """Per-tensor symmetric quantization to signed `bits` ints (QAT-style)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    scale = amax / (2.0 ** (bits - 1) - 1.0)
    q = jnp.clip(jnp.round(x / scale), -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1.0)
    return q.astype(jnp.int32), scale


def binarize(x: Array):
    """Sign binarization with per-tensor scale (1b weights/activations)."""
    scale = jnp.mean(jnp.abs(x)) + 1e-8
    return jnp.where(x >= 0, 1.0, -1.0).astype(jnp.float32), scale


def expected_snr_db(spec: MacroSpec, cal: CalibConstants = CAL28) -> float:
    from repro.core import estimator

    return float(estimator.snr_total_db(spec.h, spec.l, spec.b_adc, cal))
