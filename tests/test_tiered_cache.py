"""Two-tier artifact cache: FileRemoteStore contract, L1/L2 cascade with
promotion, validation guards on the remote tier, per-tier session
provenance, and island-request cache keys."""
import json

import pytest

from repro.api import (ArtifactCache, DesignRequest, DesignSession,
                       FileRemoteStore, TieredArtifactCache)

POP, GENS = 48, 10


def _request(array_size=4096, seed=0, **kw):
    kw.setdefault("pop_size", POP)
    kw.setdefault("generations", GENS)
    kw.setdefault("layout", False)
    return DesignRequest(array_size=array_size, seed=seed, **kw)


class TestFileRemoteStore:
    def test_uri_and_roundtrip(self, tmp_path):
        store = FileRemoteStore(f"file://{tmp_path}/l2")
        assert store.uri == f"file://{tmp_path}/l2"
        assert store.get("a.json") is None
        store.put("a.json", b"{}")
        assert store.get("a.json") == b"{}"
        assert store.list() == ["a.json"]
        assert store.size_bytes() == 2
        assert store.delete("a.json") and not store.delete("a.json")
        assert store.list() == []

    def test_plain_path_accepted(self, tmp_path):
        store = FileRemoteStore(tmp_path / "plain")
        store.put("x.json", b"1")
        assert FileRemoteStore(f"file://{tmp_path}/plain").get("x.json") == b"1"

    def test_invalid_keys_rejected(self, tmp_path):
        store = FileRemoteStore(tmp_path)
        for key in ("", ".", "..", "a/b.json"):
            with pytest.raises(ValueError):
                store.put(key, b"x")


class TestTieredArtifactCache:
    def test_cascade_promotion_and_counters(self, tmp_path):
        req = _request()
        art = DesignSession().run(req)
        writer = TieredArtifactCache(tmp_path / "w1", tmp_path / "l2")
        writer.put(art)
        assert writer.lengths() == {"l1": 1, "l2": 1}
        assert writer.stats["l2_writes"] == 1
        assert req in writer

        # fresh worker, cold L1, same L2: served from l2 then promoted
        reader = TieredArtifactCache(tmp_path / "w2", tmp_path / "l2")
        got, tier = reader.get_with_tier(req)
        assert tier == "l2" and got.summary() == art.summary()
        assert reader.stats["promotions"] == 1
        assert reader.lengths()["l1"] == 1
        got, tier = reader.get_with_tier(req)
        assert tier == "l1"
        assert reader.stats == {"l1_misses": 1, "l2_hits": 1,
                                "promotions": 1, "l1_hits": 1}

    def test_l2_guards_mirror_l1(self, tmp_path):
        req = _request()
        cache = TieredArtifactCache(tmp_path / "l1", tmp_path / "l2")
        key = cache.key_for(req)
        # corrupt object -> counted reject, no promotion
        cache.remote.put(key, b"not json")
        assert cache.get_with_tier(req) == (None, None)
        assert cache.stats["l2_rejects"] == 1
        # wrong schema stamp -> reject
        cache.remote.put(key, json.dumps(
            {"schema": -1, "request": req.to_dict()}).encode())
        assert cache.get(req) is None
        assert cache.stats["l2_rejects"] == 2
        assert cache.lengths()["l1"] == 0

    def test_clear_and_prune_by_tier(self, tmp_path):
        reqs = [_request(seed=s) for s in range(3)]
        session = DesignSession()
        cache = TieredArtifactCache(tmp_path / "l1", tmp_path / "l2")
        for r in reqs:
            cache.put(session.run(r))
        assert cache.lengths() == {"l1": 3, "l2": 3}
        assert cache.prune(tier="l2", max_entries=2) == 1
        assert cache.lengths() == {"l1": 3, "l2": 2}
        assert cache.stats["l2_evictions"] == 1
        assert cache.clear(tier="l1") == 3
        assert cache.lengths() == {"l1": 0, "l2": 2}
        assert cache.clear() == 2
        assert cache.lengths() == {"l1": 0, "l2": 0}

    def test_session_stamps_tiers(self, tmp_path):
        """The end-to-end tier contract: explorer -> l2 (cold L1 worker)
        -> l1, with the session mirroring per-tier counters."""
        req = _request(seed=7)
        w1 = DesignSession(
            artifact_cache=TieredArtifactCache(tmp_path / "w1",
                                               tmp_path / "shared"))
        a1 = w1.run(req)
        assert a1.provenance.served_from == "explorer"
        assert w1.stats["artifact_cache_l2_writes"] == 1

        w2 = DesignSession(
            artifact_cache=TieredArtifactCache(tmp_path / "w2",
                                               tmp_path / "shared"))
        a2 = w2.run(req)
        assert a2.provenance.served_from == "artifact_cache_l2"
        assert w2.stats["explorer_dispatches"] == 0
        assert w2.stats["artifact_cache_promotions"] == 1
        assert a2.summary() == a1.summary()
        a3 = w2.run(req)   # artifact cache is consulted before the memo
        assert a3.provenance.served_from == "artifact_cache_l1"
        assert w2.stats["artifact_cache_l1_hits"] == 1

    def test_legacy_single_tier_stamp_unchanged(self, tmp_path):
        req = _request(seed=9)
        cache = ArtifactCache(tmp_path / "flat")
        DesignSession(artifact_cache=cache).run(req)
        again = DesignSession(artifact_cache=cache).run(req)
        assert again.provenance.served_from == "artifact_cache"
