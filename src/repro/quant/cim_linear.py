"""CIM-in-the-loop linear layers: route a projection through the simulated
ACIM macro (quantization + ADC + analog noise) with straight-through
gradients — hardware-aware training for models that will deploy on the
generated macro.

y ~= s_x * s_w * MACRO(bin(x), bin(w))     (1b x 1b, paper Sec. 4 config)

Scales: per-tensor mean-|.| for activations, per-output-column for weights
(keeps the binary GEMM's dynamic range matched per column ADC).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.acim_numerics import NoiseParams
from repro.core.acim_spec import MacroSpec
from repro.kernels.acim_matmul import acim_matmul_ste, mismatch_weights

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    spec: MacroSpec
    mismatch: bool = True           # fold static cap mismatch into weights
    instance_seed: int = 0


@jax.custom_vjp
def _sign_ste(x: Array) -> Array:
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _sign_fwd(x):
    return _sign_ste(x), x


def _sign_bwd(x, g):
    # clipped straight-through (gradients pass inside |x| <= 1)
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


_sign_ste.defvjp(_sign_fwd, _sign_bwd)


def cim_linear(x: Array, w: Array, cim: CIMConfig | None) -> Array:
    """x: (..., K); w: (K, C).  cim=None -> exact matmul (digital path)."""
    if cim is None:
        return x @ w
    s_x = jnp.mean(jnp.abs(x)) + 1e-8
    s_w = jnp.mean(jnp.abs(w), axis=0, keepdims=True) + 1e-8   # per column
    bx = _sign_ste(x / s_x)
    bw = _sign_ste(w / s_w)
    if cim.mismatch:
        bw_run = mismatch_weights(bw, cim.spec,
                                  jax.random.key(cim.instance_seed),
                                  NoiseParams.from_cal())
        bw = bw + jax.lax.stop_gradient(bw_run - bw)
    y = acim_matmul_ste(bx, bw, cim.spec)
    return y * s_x * s_w
