"""Mesh explore engine: sharded-cells bit-equality with the single-device
explorer, island-model determinism/device-count independence (in-process
and under 8 forced host devices), and true-front recovery."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import explorer, nsga2, pareto
from repro.core.batched_explorer import explore_cells
from repro.parallel import distributed_explorer as dx

REPO = pathlib.Path(__file__).resolve().parents[1]
CELLS = ((4096, 0), (16384, 1), (65536, 0))


def _rows(res):
    return res.to_rows()


def _true_front(array_size: int):
    genes, objs = explorer.full_design_space(array_size)
    mask = np.asarray(pareto.non_dominated_mask(objs))
    return {tuple(g) for g, m in zip(np.asarray(genes), mask) if m}


class TestShardedCells:
    def test_bit_equal_to_single_device_engine(self):
        """islands=1 mesh mode is the acceptance contract: per-cell fronts
        (including metrics) identical to `explore_cells` for the same
        request — so mesh on/off never invalidates a cache tier."""
        pop, gens = 48, 8
        ref = explore_cells(CELLS, pop_size=pop, generations=gens)
        out, facts = dx.explore_cells_mesh(CELLS, pop_size=pop,
                                           generations=gens)
        assert facts["migration_topology"] == "sharded"
        assert facts["islands"] == 1 and facts["migration_rounds"] == 0
        assert facts["mesh_devices"] == jax.device_count()
        assert set(out) == set(ref)
        for cell in CELLS:
            assert _rows(out[cell]) == _rows(ref[cell]), cell

    def test_single_trace_of_run_cell(self):
        jax.clear_caches()
        dx._PROGRAMS.clear()
        before = nsga2.TRACE_COUNTS["run_cell"]
        dx.explore_cells_mesh(CELLS, pop_size=40, generations=5)
        assert nsga2.TRACE_COUNTS["run_cell"] - before == 1
        # warm re-dispatch: program cache hit, no new trace
        dx.explore_cells_mesh(CELLS, pop_size=40, generations=5)
        assert nsga2.TRACE_COUNTS["run_cell"] - before == 1


class TestIslands:
    def test_deterministic_and_facts(self):
        pop, gens = 48, 20
        out1, facts = dx.explore_cells_mesh(
            CELLS[:2], islands=4, migrate_every=10,
            pop_size=pop, generations=gens)
        out2, _ = dx.explore_cells_mesh(
            CELLS[:2], islands=4, migrate_every=10,
            pop_size=pop, generations=gens)
        assert facts == {"mesh_devices": dx.devices_for_islands(
                             dx.default_mesh(), 4),
                         "islands": 4, "migration_topology": "ring",
                         "migration_rounds": 1}
        for cell in CELLS[:2]:
            assert _rows(out1[cell]) == _rows(out2[cell]), cell

    def test_explicit_one_device_submesh_matches_default(self):
        """Forcing the 1-device submesh reproduces the default-mesh result:
        the key schedule is a function of global island ids only."""
        kw = dict(islands=4, migrate_every=8, pop_size=40, generations=16)
        base, _ = dx.explore_cells_mesh(CELLS[:1], **kw)
        one, _ = dx.explore_cells_mesh(
            CELLS[:1], mesh=dx.default_mesh(max_devices=1), **kw)
        assert _rows(base[CELLS[0]]) == _rows(one[CELLS[0]])

    def test_round_schedule_and_divisors(self):
        assert dx._round_schedule(80, 20) == (20, 20, 20, 20)
        assert dx._round_schedule(50, 20) == (20, 20, 10)
        assert dx._round_schedule(5, 20) == (5,)
        with pytest.raises(ValueError):
            dx._round_schedule(10, 0)
        mesh = dx.default_mesh()
        assert dx.devices_for_islands(mesh, 1) == 1
        n = dx.mesh_size(mesh)
        assert dx.devices_for_islands(mesh, n * 6) == n
        with pytest.raises(ValueError):
            dx.explore_cells_mesh(CELLS[:1], islands=0)


@pytest.mark.slow
def test_islands_device_count_independent_and_recover_front():
    """8 forced host devices: the islands=8 run is bit-identical to the
    1-device run of the same request, and the merged union front recovers
    the exhaustive ground-truth Pareto set."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, sys
        import jax, numpy as np
        from repro.core import explorer, pareto
        from repro.parallel import distributed_explorer as dx

        assert jax.device_count() == 8
        kw = dict(islands=8, migrate_every=10, pop_size=96, generations=60)
        on8, facts8 = dx.explore_cells_mesh([(16384, 0)], **kw)
        assert facts8["mesh_devices"] == 8 and \\
            facts8["migration_topology"] == "ring", facts8
        on1, facts1 = dx.explore_cells_mesh(
            [(16384, 0)], mesh=dx.default_mesh(max_devices=1), **kw)
        assert facts1["mesh_devices"] == 1
        assert on8[(16384, 0)].to_rows() == on1[(16384, 0)].to_rows()

        genes, objs = explorer.full_design_space(16384)
        mask = np.asarray(pareto.non_dominated_mask(objs))
        truth = {tuple(g) for g, m in zip(np.asarray(genes), mask) if m}
        found = {(int(np.log2(s.h)), int(np.log2(s.l)), s.b_adc)
                 for s in on8[(16384, 0)].specs}
        assert found <= truth, sorted(found - truth)
        assert len(found) >= 0.8 * len(truth), (len(found), len(truth))
        print("OK", len(found), "/", len(truth), "front points, 8dev == 1dev")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
