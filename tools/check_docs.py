"""Docs link & code-reference checker (stdlib only, CI-friendly).

Checks, over README.md, ROADMAP.md and docs/*.md:

  1. Relative markdown links `[text](target)` point at files that exist
     (http(s) URLs and pure #anchors are skipped).
  2. Inline-code path references — backtick spans that look like repo
     paths (contain "/" and a known suffix, or start with a top-level
     repo directory) — resolve against the repo root.
  3. Inline-code module references starting with `repro.` resolve to a
     module/package under src/.  A trailing attribute segment is
     allowed (`repro.core.explorer.distill_and_layout` passes because
     `src/repro/core/explorer.py` exists), and so is a CapWord class
     segment followed by one attribute
     (`repro.api.DesignSession.run_many`).

Exit status is the number of broken references; each is printed as
`file:line: message`.

  python tools/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
PATH_SUFFIXES = (".py", ".md", ".json", ".yml", ".yaml", ".toml", ".txt")
TOP_DIRS = ("src/", "tests/", "examples/", "benchmarks/", "docs/",
            "tools/", ".github/")


def doc_files() -> list[pathlib.Path]:
    return ([REPO / "README.md", REPO / "ROADMAP.md"]
            + sorted((REPO / "docs").glob("*.md")))


def check_link(md: pathlib.Path, target: str) -> str | None:
    if target.startswith(("http://", "https://", "mailto:", "#")):
        return None
    path = (md.parent / target.split("#")[0]).resolve()
    if not path.exists():
        return f"broken link target: {target}"
    return None


def looks_like_path(span: str) -> bool:
    if any(ch in span for ch in " `$<>|,(){}*"):
        return False
    return (span.startswith(TOP_DIRS)
            or ("/" in span and span.endswith(PATH_SUFFIXES)))


def check_path_ref(span: str) -> str | None:
    # module files are conventionally written relative to src/repro/
    if (REPO / span).exists() or (REPO / "src" / "repro" / span).exists():
        return None
    return f"missing path reference: {span}"


def check_module_ref(span: str) -> str | None:
    parts = span.split(".")
    # longest prefix that resolves to a module file or package dir; the
    # tail may be one attribute, or a CapWord class plus one attribute
    # (`repro.api.DesignSession.run_many`)
    for n in range(len(parts), 0, -1):
        base = REPO / "src" / pathlib.Path(*parts[:n])
        if base.with_suffix(".py").exists() or (base / "__init__.py").exists():
            tail = parts[n:]
            if len(tail) > 2 or (len(tail) == 2 and not tail[0][:1].isupper()):
                return (f"module reference {span}: {'.'.join(parts[:n])} "
                        f"exists but {'.'.join(tail)} nests too deep")
            return None
    return f"unresolvable module reference: {span}"


def main() -> int:
    failures = 0
    for md in doc_files():
        for ln, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                msg = check_link(md, target)
                if msg:
                    print(f"{md.relative_to(REPO)}:{ln}: {msg}")
                    failures += 1
            for span in CODE_RE.findall(line):
                msg = None
                if looks_like_path(span):
                    msg = check_path_ref(span)
                elif re.fullmatch(r"repro(\.\w+)+", span):
                    msg = check_module_ref(span)
                if msg:
                    print(f"{md.relative_to(REPO)}:{ln}: {msg}")
                    failures += 1
    n = len(doc_files())
    print(f"checked {n} docs, {failures} broken reference(s)")
    return min(failures, 125)


if __name__ == "__main__":
    sys.exit(main())
