"""Codesign loop: assigned-architecture workloads -> ACIM macro choice.

This closes the loop the paper leaves open: EasyACIM generates Pareto-
optimal macros for a *given array size*, but which point serves a given
model best depends on the model's GEMM structure.  `extract_gemms` pulls
every weight-stationary GEMM out of an ArchConfig (the CIM-mappable set —
see DESIGN.md §9 for what stays digital); `recommend_macro` scores the
explorer's Pareto set under that workload:

  * mapping efficiency: a GEMM with contraction length K runs in
    ceil(K/N) conversions of N = H/L rows; short-K GEMMs waste rows of a
    tall-N macro (utilization = K / (ceil(K/N)*N));
  * columns: out-dim C tiles over W columns (utilization C/(ceil(C/W)*W));
  * effective throughput = T * util; energy/MAC inflates by 1/util;
  * solution score = workload-weighted energy-delay product, subject to a
    user SNR floor (accuracy requirement of the application — the paper's
    Fig. 1 scenario matching, made quantitative).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import estimator
from repro.core.acim_spec import MacroSpec


@dataclasses.dataclass(frozen=True)
class GemmWorkload:
    name: str
    k: int                  # contraction (dot-product) length
    cols: int               # output columns
    macs_per_token: float   # k * cols * utilization-of-this-gemm per token


def extract_gemms(cfg: ArchConfig) -> list[GemmWorkload]:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    l = cfg.n_layers
    gs: list[GemmWorkload] = []

    def add(name, k, cols, mult=1.0):
        gs.append(GemmWorkload(name, int(k), int(cols),
                               float(k) * cols * mult))

    if cfg.family == "ssm":
        x = cfg.xlstm
        inner = int(x.proj_factor * d)
        per = l // 2
        for nm, kk, cc in [("up", d, inner), ("gate", d, inner),
                           ("wq", inner, inner), ("wk", inner, inner),
                           ("wv", inner, inner), ("down", inner, d),
                           ("slstm_gates", d, 4 * d), ("slstm_down", d, d)]:
            add(nm, kk, cc, per)
    elif cfg.family == "hybrid":
        ss = cfg.ssm
        d_inner = ss.expand * d
        add("mamba_in", d, 2 * d_inner + 2 * ss.state + d_inner // ss.head_dim, l)
        add("mamba_out", d_inner, d, l)
        n_attn = l // cfg.hybrid.shared_attn_every
        add("shared_qkvo", d, 4 * d, n_attn)
        add("shared_ffn", d, 3 * cfg.hybrid.shared_ff, n_attn)
    else:
        if cfg.mla is not None:
            m = cfg.mla
            add("wq", d, h * (m.nope_dim + m.rope_dim), l)
            add("w_dkv", d, m.kv_lora, l)
            add("w_uk", m.kv_lora, h * m.nope_dim, l)
            add("w_uv", m.kv_lora, h * m.v_dim, l)
            add("wo", h * m.v_dim, d, l)
        else:
            add("wq", d, h * dh, l)
            add("wk", d, kv * dh, l)
            add("wv", d, kv * dh, l)
            add("wo", h * dh, d, l)
        if cfg.moe is not None:
            m = cfg.moe
            n_mats = 3 if cfg.mlp_gated else 2
            add("experts", d, m.d_ff_expert * n_mats, l * m.top_k)
            if m.n_shared:
                add("shared", d, m.d_ff_expert * m.n_shared * n_mats, l)
            if m.dense_ff:
                add("dense_ffn", d, m.dense_ff * n_mats, l)
        else:
            n_mats = 3 if cfg.mlp_gated else 2
            add("ffn", d, cfg.d_ff * n_mats, l)
    add("lm_head", d, cfg.vocab, 1)
    return gs


def mapping_utilization(spec: MacroSpec, g: GemmWorkload) -> float:
    n = spec.n_caps
    row_u = g.k / (int(np.ceil(g.k / n)) * n)
    col_u = g.cols / (int(np.ceil(g.cols / spec.w)) * spec.w)
    return row_u * col_u


@dataclasses.dataclass(frozen=True)
class Recommendation:
    arch: str
    spec: MacroSpec
    snr_db: float
    eff_tops: float
    eff_tops_per_w: float
    utilization: float
    macro_count_for_rate: int     # macros to sustain 1 token/us decode


def recommend_macro(cfg: ArchConfig, *, array_size: int = 65536,
                    min_snr_db: float = 3.0, pop_size: int = 192,
                    generations: int = 50, seed: int = 0,
                    session=None) -> Recommendation:
    """Score the explorer's Pareto set under the workload.  Pass a
    `repro.api.DesignSession` to share its program/front caches across
    architectures (the default session is used otherwise)."""
    from repro.api import DesignRequest, Requirements, default_session

    req = DesignRequest(array_size=array_size, seed=seed, pop_size=pop_size,
                        generations=generations,
                        requirements=Requirements(min_snr_db=min_snr_db),
                        layout=False)
    res = (session or default_session()).run(req).pareto
    if not len(res):
        raise ValueError("no Pareto point meets the SNR floor")
    gemms = extract_gemms(cfg)
    total_macs = sum(g.macs_per_token for g in gemms)

    best, best_score = None, None
    for i, spec in enumerate(res.specs):
        util = sum(mapping_utilization(spec, g) * g.macs_per_token
                   for g in gemms) / total_macs
        tops = res.metrics["tops"][i] * util
        e = res.metrics["energy_fj_per_mac"][i] / max(util, 1e-9)
        edp = e / max(tops, 1e-12)           # energy-delay proxy
        if best_score is None or edp < best_score:
            best_score = edp
            best = (spec, util, tops, 2000.0 / e, res.metrics["snr_db"][i])
    spec, util, tops, tpw, snr = best
    rate_macs = total_macs * 1e6             # 1 token/us
    macro_rate = float(estimator.throughput_ops(spec.h, spec.w, spec.l,
                                                spec.b_adc)) / 2 * util
    return Recommendation(cfg.name, spec, float(snr), float(tops), float(tpw),
                          float(util), int(np.ceil(rate_macs / macro_rate)))
