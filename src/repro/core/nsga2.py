"""NSGA-II (Deb et al.) specialized for the EasyACIM design space, in JAX.

The paper uses an off-the-shelf NSGA-II over (H, W, L, B_ADC) with the
Eq. 12 constraints.  Here the whole generation step — evaluation, tournament
selection, crossover, mutation, repair, elitist environmental selection — is
a single jit-compiled function; populations are plain int32 gene arrays so
the explorer can also be sharded across a device mesh (see
`repro.parallel.distributed_explorer`).

Gene encoding (all powers of two, matching the binary-ratioed CDAC):
    gene[0] = h_exp   -> H = 2**h_exp
    gene[1] = l_exp   -> L = 2**l_exp
    gene[2] = b_adc
W is implied by the H*W = array_size equality constraint (Eq. 12), so it is
not a free gene — this is exact constraint elimination rather than penalty
handling.  The two inequality constraints (H >= L, H/L >= 2^B) are handled
by *repair* (clamping), which keeps every individual feasible; a
constrained-domination path (Deb's rules) is also provided for generality
and is exercised by the tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator, pareto
from repro.core.constants import CAL28, CalibConstants

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class NSGA2Config:
    array_size: int
    pop_size: int = 256
    generations: int = 80
    crossover_prob: float = 0.9
    mutation_prob: float = 0.2
    tournament_pairs: int = 2
    seed: int = 0
    cal: CalibConstants = CAL28
    use_pallas_dominance: bool = False  # Pallas kernel for the P^2 hot spot

    @property
    def log2_size(self) -> int:
        s = int(np.log2(self.array_size))
        if 2**s != self.array_size:
            raise ValueError("array_size must be a power of two")
        return s

    @property
    def h_exp_bounds(self) -> tuple[int, int]:
        lo = int(np.log2(self.cal.h_min))
        hi = min(int(np.log2(self.cal.h_max)),
                 self.log2_size - int(np.log2(self.cal.w_min)))
        return lo, hi

    @property
    def l_exp_bounds(self) -> tuple[int, int]:
        return int(np.log2(self.cal.l_min)), int(np.log2(self.cal.l_max))

    @property
    def b_bounds(self) -> tuple[int, int]:
        return self.cal.b_min, self.cal.b_max


class Population(NamedTuple):
    genes: Array   # (P, 3) int32  [h_exp, l_exp, b]
    objs: Array    # (P, 4) float32, minimization orientation


def repair(genes: Array, cfg: NSGA2Config) -> Array:
    """Project genes onto the feasible set (Eq. 12 inequality constraints)."""
    h_lo, h_hi = cfg.h_exp_bounds
    l_lo, l_hi = cfg.l_exp_bounds
    b_lo, b_hi = cfg.b_bounds
    h = jnp.clip(genes[:, 0], h_lo, h_hi)
    # H >= L and room for at least b_min ADC bits: L <= H / 2^b_min
    l = jnp.clip(genes[:, 1], l_lo, jnp.minimum(l_hi, h - b_lo))
    b = jnp.clip(genes[:, 2], b_lo, jnp.minimum(b_hi, h - l))      # H/L >= 2^B
    return jnp.stack([h, l, b], axis=1)


def decode(genes: Array, cfg: NSGA2Config):
    """Genes -> (H, W, L, B) float32 arrays."""
    h = 2.0 ** genes[:, 0].astype(jnp.float32)
    w = float(cfg.array_size) / h
    l = 2.0 ** genes[:, 1].astype(jnp.float32)
    b = genes[:, 2].astype(jnp.float32)
    return h, w, l, b


def evaluate(genes: Array, cfg: NSGA2Config) -> Array:
    h, w, l, b = decode(genes, cfg)
    return estimator.objectives(h, w, l, b, cfg.cal)


def constraint_violation(genes: Array, cfg: NSGA2Config) -> Array:
    """Total violation (0 for feasible) — used by the constrained-dom path."""
    h = genes[:, 0]
    l = genes[:, 1]
    b = genes[:, 2]
    v1 = jnp.maximum(l - h, 0)            # H >= L
    v2 = jnp.maximum(b - (h - l), 0)      # H/L >= 2^B
    return (v1 + v2).astype(jnp.float32)


def init_population(key: Array, cfg: NSGA2Config) -> Array:
    h_lo, h_hi = cfg.h_exp_bounds
    l_lo, l_hi = cfg.l_exp_bounds
    b_lo, b_hi = cfg.b_bounds
    kh, kl, kb = jax.random.split(key, 3)
    p = cfg.pop_size
    h = jax.random.randint(kh, (p,), h_lo, h_hi + 1)
    l = jax.random.randint(kl, (p,), l_lo, l_hi + 1)
    b = jax.random.randint(kb, (p,), b_lo, b_hi + 1)
    return repair(jnp.stack([h, l, b], 1), cfg)


def _rank_and_crowd(objs: Array, cfg: NSGA2Config):
    if cfg.use_pallas_dominance:
        from repro.kernels.pareto_dom import ops as dom_ops

        dom = dom_ops.dominance_matrix(objs)
    else:
        dom = pareto.dominance_matrix(objs)
    ranks = pareto.non_dominated_rank(objs, dom=dom)
    crowd = pareto.crowding_distance(objs, ranks)
    return ranks, crowd


def _tournament(key: Array, ranks: Array, crowd: Array, n: int) -> Array:
    """Binary tournament on (rank asc, crowding desc); returns n winner idx."""
    p = ranks.shape[0]
    idx = jax.random.randint(key, (n, 2), 0, p)
    a, b = idx[:, 0], idx[:, 1]
    a_better = (ranks[a] < ranks[b]) | ((ranks[a] == ranks[b]) & (crowd[a] > crowd[b]))
    return jnp.where(a_better, a, b)


def _variation(key: Array, parents: Array, cfg: NSGA2Config) -> Array:
    """Uniform crossover + random-reset mutation on integer genes."""
    p = parents.shape[0]
    kx, kswap, kmut, kval = jax.random.split(key, 4)
    mates = parents[jnp.roll(jnp.arange(p), 1)]
    do_cx = jax.random.bernoulli(kx, cfg.crossover_prob, (p, 1))
    swap = jax.random.bernoulli(kswap, 0.5, parents.shape)
    children = jnp.where(do_cx & swap, mates, parents)
    # mutation: re-draw a gene uniformly within its box bounds
    h_lo, h_hi = cfg.h_exp_bounds
    l_lo, l_hi = cfg.l_exp_bounds
    b_lo, b_hi = cfg.b_bounds
    lo = jnp.array([h_lo, l_lo, b_lo], jnp.int32)
    hi = jnp.array([h_hi, l_hi, b_hi], jnp.int32)
    u = jax.random.uniform(kval, children.shape)
    rand_gene = (lo + (u * (hi - lo + 1)).astype(jnp.int32)).astype(jnp.int32)
    mut = jax.random.bernoulli(kmut, cfg.mutation_prob, children.shape)
    children = jnp.where(mut, rand_gene, children)
    return repair(children, cfg)


def _environmental_selection(genes: Array, objs: Array, cfg: NSGA2Config):
    """Elitist (mu+lambda) truncation by (rank, -crowding)."""
    ranks, crowd = _rank_and_crowd(objs, cfg)
    order = jnp.lexsort((-crowd, ranks))
    keep = order[: cfg.pop_size]
    return genes[keep], objs[keep]


@functools.partial(jax.jit, static_argnames=("cfg",))
def generation_step(key: Array, genes: Array, objs: Array, cfg: NSGA2Config):
    """One NSGA-II generation: select -> vary -> evaluate -> elitist truncate."""
    ksel, kvar = jax.random.split(key)
    ranks, crowd = _rank_and_crowd(objs, cfg)
    parents_idx = _tournament(ksel, ranks, crowd, cfg.pop_size)
    children = _variation(kvar, genes[parents_idx], cfg)
    child_objs = evaluate(children, cfg)
    comb_genes = jnp.concatenate([genes, children], 0)
    comb_objs = jnp.concatenate([objs, child_objs], 0)
    return _environmental_selection(comb_genes, comb_objs, cfg)


def run(cfg: NSGA2Config, key: Array | None = None) -> Population:
    """Full NSGA-II run; returns the final population (feasible by repair)."""
    if key is None:
        key = jax.random.key(cfg.seed)
    kinit, kgen = jax.random.split(key)
    genes = init_population(kinit, cfg)
    objs = evaluate(genes, cfg)

    @jax.jit
    def loop(key, genes, objs):
        def body(i, state):
            key, genes, objs = state
            key, sub = jax.random.split(key)
            genes, objs = generation_step(sub, genes, objs, cfg)
            return key, genes, objs

        return jax.lax.fori_loop(0, cfg.generations, body, (key, genes, objs))

    _, genes, objs = loop(kgen, genes, objs)
    return Population(genes, objs)
