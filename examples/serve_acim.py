"""Batched serving demo: continuous batching over the decode step.

  PYTHONPATH=src python examples/serve_acim.py --arch qwen2_5_3b
"""
import argparse
import time

import jax

from repro.configs import registry as creg
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = creg.reduced(args.arch)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=args.slots, max_seq=128)
    for uid in range(args.requests):
        eng.submit(Request(uid=uid, prompt=[3 + uid, 7, 11],
                           max_new=args.max_new))
    t0 = time.time()
    done = eng.run(max_steps=512)
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in done)
    print(f"{len(done)} completions, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, {args.slots} slots)")
    for c in sorted(done, key=lambda c: c.uid):
        print(f"  req {c.uid}: {c.tokens}")


if __name__ == "__main__":
    main()
