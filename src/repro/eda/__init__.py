"""Automated layout generation for the synthesizable ACIM architecture
(paper Sec. 3.3 and the right half of Fig. 4).

A `repro.core.acim_spec.MacroSpec` design point — typically distilled
from the MOGA explorer's Pareto set — flows through:

  `netlist`      template-based netlist generation (+ closed-form stats)
  `placer`       data-oriented hierarchical template expansion
  `router`       Lee-wavefront grid routing (kernels.maze_route)
  `flow`         single-spec orchestration: `generate_layout(spec)`
  `batched_flow` the whole spec batch in a few device dispatches:
                 `generate_layouts(specs)`
  `cells`        the customized cell library (calibrated footprints)

The sequential and batched paths share the same vectorized placement and
the same wavefront/backtrace semantics, so per-spec results agree
exactly (tests/test_batched_flow.py).

The supported front door is `repro.api` (`DesignSession` /
`DesignService`): it chains exploration into `batched_flow` and buckets
multi-tenant spec batches by routing-grid shape before dispatch.
"""
