"""Staged pipeline executor (`DesignService.serve(pipelined=True)`):
ticket-for-ticket equality with the sequential stages, bucket
streaming / overlap gauges, drain-on-close, per-stage failure
isolation (error artifacts instead of a dead pipeline), preemption
drain vs collect/close races, and the `stats()` snapshot contract.
The full fault-injection matrix lives in `tests/test_service_faults.py`."""
import dataclasses
import threading
import time

import pytest

from repro.api import DesignRequest, DesignSession, Requirements
from repro.serve.design_service import DesignService

# every test here runs threads; a pipeline bug deadlocks rather than
# fails, so each test carries a hard deadline (pytest-timeout in CI,
# the conftest watchdog otherwise)
pytestmark = pytest.mark.timeout(600)

# Same small budget as tests/test_design_api.py: these ride the shared
# process-wide jit cache instead of paying fresh compiles.
POP, GENS = 48, 10
REQS = Requirements(min_tops=0.5, min_snr_db=10.0)


def _request(array_size=4096, seed=0, **kw):
    kw.setdefault("pop_size", POP)
    kw.setdefault("generations", GENS)
    return DesignRequest(array_size=array_size, seed=seed, **kw)


# -- pipelined == sequential ---------------------------------------------

class TestPipelinedEquality:
    def test_pipelined_equals_sequential_stages(self):
        # a mixed batch: laid-out tenants, a front-only tenant, and a
        # poison tenant whose requirements remove everything
        reqs = [_request(seed=0, requirements=REQS, layout=True),
                _request(seed=1, requirements=REQS, layout=True),
                _request(array_size=16384, layout=False),
                _request(seed=2, requirements=Requirements(min_tops=1e9),
                         layout=True)]
        seq = DesignSession().run_many(reqs, strict=False)

        svc = DesignService(coalesce_window_s=0.25)
        with svc.serve():
            tickets = [svc.submit(r) for r in reqs]
            arts = [svc.collect(t, timeout=600) for t in tickets]
        for r, a in zip(reqs, arts):
            assert a.summary() == seq[r].summary()
            assert a.ok == seq[r].ok
            assert a.provenance.pipelined
        assert not arts[3].ok and "removed every Pareto point" in arts[3].error

    def test_concurrent_submits_multi_batch(self):
        # max_coalesce=2 forces several batches in flight concurrently;
        # every tenant must get its own request's artifact back
        svc = DesignService(max_coalesce=2, coalesce_window_s=0.05)
        seeds = list(range(6))
        results, errors = {}, []

        def tenant(sd):
            try:
                t = svc.submit(_request(seed=sd, requirements=REQS,
                                        layout=True))
                results[sd] = svc.collect(t, timeout=600)
            except Exception as e:   # surfaced below
                errors.append(e)

        with svc.serve():
            threads = [threading.Thread(target=tenant, args=(sd,))
                       for sd in seeds]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        assert not errors
        assert sorted(results) == seeds
        assert {results[sd].request.seed for sd in seeds} == set(seeds)
        seq = DesignSession().run_many(
            [_request(seed=sd, requirements=REQS, layout=True)
             for sd in seeds], strict=False)
        for sd in seeds:
            assert results[sd].summary() == seq[results[sd].request].summary()

    def test_multi_batch_overlap_and_waits(self):
        # one request per batch: batch N+1's explore overlaps batch N's
        # layout, which the occupancy clocks must witness
        svc = DesignService(max_coalesce=1)
        with svc.serve():
            tickets = [svc.submit(_request(seed=sd, requirements=REQS,
                                           layout=True))
                       for sd in (0, 1, 2)]
            arts = [svc.collect(t, timeout=600) for t in tickets]
            stats = svc.stats()
        assert stats["service_batches"] == 3
        busy = stats["stage_busy_s"]
        assert busy["explore"] > 0 and busy["layout"] > 0
        assert busy["distill"] >= 0 and busy["finalize"] > 0
        assert stats["pipeline_overlap_s"] > 0
        assert 0 < stats["pipeline_overlap_fraction"] <= 1.0
        for a in arts:
            assert a.provenance.pipelined
            assert a.provenance.explore_wait_s >= 0.0
            assert a.provenance.layout_wait_s >= 0.0
        # later batches waited on the explore queue behind earlier ones
        assert arts[-1].provenance.explore_wait_s > 0.0

    def test_sequential_driver_reports_not_pipelined(self):
        art = DesignSession().run(_request(requirements=REQS, layout=True))
        assert not art.provenance.pipelined
        assert art.provenance.explore_wait_s == 0.0
        assert art.provenance.layout_wait_s == 0.0


# -- lifecycle ------------------------------------------------------------

class TestPipelineLifecycle:
    def test_mid_pipeline_close_drains_all_tickets(self):
        # close() immediately after a burst of submissions: the final
        # drain must push every admitted AND still-queued batch through
        # all four stages — no ticket lost
        svc = DesignService(max_coalesce=1)
        svc.serve()
        tickets = [svc.submit(_request(seed=sd, layout=False))
                   for sd in range(4)]
        svc.close()
        for t in tickets:
            art = svc.poll(t)
            assert art is not None and art.ok
        assert len(svc) == 0

    def test_front_only_requests_flow_through(self):
        # zero layout buckets: the batch must still traverse the layout
        # stage (as a no-op) and finalize in order
        svc = DesignService(coalesce_window_s=0.05)
        with svc.serve():
            t = svc.submit(_request(layout=False))
            art = svc.collect(t, timeout=600)
        assert art.ok and art.layout_rows is None
        assert art.provenance.layout_dispatches == 0
        assert art.provenance.pipelined

    def test_artifact_cache_hits_flow_through_pipeline(self, tmp_path):
        req = _request(requirements=REQS, layout=True)
        DesignSession(artifact_cache=tmp_path).run(req)   # fill the cache
        svc = DesignService(DesignSession(artifact_cache=tmp_path))
        with svc.serve():
            t = svc.submit(req)
            art = svc.collect(t, timeout=600)
        assert art.provenance.served_from == "artifact_cache"
        assert art.provenance.explorer_dispatches == 0
        assert art.provenance.pipelined

    def test_serial_pump_still_available(self):
        svc = DesignService(coalesce_window_s=0.05)
        with svc.serve(pipelined=False):
            assert svc.serve(pipelined=False) is svc   # same mode: idempotent
            with pytest.raises(RuntimeError, match="close\\(\\) first"):
                svc.serve(pipelined=True)   # mode switch under a live pump
            t = svc.submit(_request(layout=False))
            art = svc.collect(t, timeout=600)
        assert art.ok and not art.provenance.pipelined
        stats = svc.stats()
        assert not stats["pipelined"]
        assert stats["pipeline_overlap_s"] == 0.0

    def test_pipeline_depth_validation(self):
        with pytest.raises(ValueError, match="pipeline_depth"):
            DesignService(pipeline_depth=0)

    def test_serve_refused_while_sync_drain_active(self):
        # the converse of run()-refused-under-pump: a mid-flight
        # run()/step() drain owns the session (simulated the same way
        # test_submit_and_serve_refused_while_closing simulates close)
        svc = DesignService()
        svc._sync_dispatchers = 1
        with pytest.raises(RuntimeError, match="run\\(\\)/step\\(\\) drain"):
            svc.serve()
        svc._sync_dispatchers = 0
        with svc.serve():
            pass


# -- failure isolation ----------------------------------------------------

class TestStageFailureIsolation:
    @pytest.mark.parametrize("stage", ["explore_stage", "distill_stage",
                                       "finalize_stage"])
    def test_batch_stage_failure_isolates_to_error_artifacts(
            self, stage, monkeypatch):
        # an always-failing batch stage no longer kills the pipeline:
        # after the retry budget the batch's tickets complete with
        # error artifacts, and the pump stays alive for the next batch
        svc = DesignService(coalesce_window_s=0.02, max_retries=0,
                            retry_backoff_s=0.001)
        real = getattr(svc.session, stage)

        def boom(*a, **kw):
            raise RuntimeError(f"injected {stage} failure")

        monkeypatch.setattr(svc.session, stage, boom)
        svc.serve()
        tickets = [svc.submit(_request(seed=sd, requirements=REQS,
                                       layout=True))
                   for sd in (0, 1)]
        arts = [svc.collect(t, timeout=600) for t in tickets]
        for a in arts:
            assert not a.ok
            assert f"injected {stage} failure" in a.error
            assert a.provenance.served_from == "error"
        # the pipeline survived: restore the stage, next batch is clean
        monkeypatch.setattr(svc.session, stage, real)
        t2 = svc.submit(_request(seed=2, requirements=REQS, layout=True))
        assert svc.collect(t2, timeout=600).ok
        svc.close()   # clean close: no restore, no re-raise
        assert len(svc) == 0

    def test_layout_failure_isolates_per_bucket(self, monkeypatch):
        # layout failures are finer-grained still: only tickets touching
        # the dead bucket(s) error out (here: all buckets die, so the
        # laid-out tenant errors while its front survives on the artifact)
        svc = DesignService(coalesce_window_s=0.02, max_retries=0,
                            retry_backoff_s=0.001)

        def boom(*a, **kw):
            raise RuntimeError("injected layout failure")

        monkeypatch.setattr(svc.session, "layout_stage", boom)
        svc.serve()
        ticket = svc.submit(_request(requirements=REQS, layout=True))
        art = svc.collect(ticket, timeout=600)
        assert not art.ok and "layout bucket" in art.error
        assert art.pareto.specs          # distilled front still attached
        assert art.layout_rows is None
        assert svc.stats()["bucket_failures"] >= 1
        svc.close()

    def test_blocked_collector_woken_by_error_artifact(self, monkeypatch):
        # the window is long, so the collector blocks BEFORE the batch
        # dispatches; the isolated failure must wake it with the error
        # artifact (not strand it waiting for a dead pipeline)
        svc = DesignService(max_coalesce=2, coalesce_window_s=30.0,
                            max_retries=0, retry_backoff_s=0.001)

        def boom(*a, **kw):
            raise RuntimeError("injected explore failure")

        monkeypatch.setattr(svc.session, "explore_stage", boom)
        svc.serve()
        ticket = svc.submit(_request(layout=False))
        got: list = []

        def collector():
            got.append(svc.collect(ticket, timeout=600))

        th = threading.Thread(target=collector)
        th.start()
        time.sleep(0.2)            # collector is parked on the ticket
        svc.submit(_request(seed=1, layout=False))   # fills the batch
        th.join(timeout=60)
        assert not th.is_alive()
        assert got and not got[0].ok
        assert "injected explore failure" in got[0].error
        svc.close()


# -- preemption drain vs collect()/poll()/close() races --------------------

class TestPreemptDrainRaces:
    def test_collect_and_poll_raced_against_close_during_drain(
            self, tmp_path):
        # a preemption drain is in flight (slow explore keeps batches
        # in the pipeline); close() races blocked collect(timeout=...)
        # callers and a poll() spinner.  Contract: no deadlock, and
        # every ticket resolves exactly one way — an artifact (drained)
        # or PendingTicket (journaled for replay)
        from repro.runtime.fault_tolerance import PreemptionGuard
        from repro.serve.design_service import PendingTicket

        guard = PreemptionGuard()
        svc = DesignService(max_coalesce=1, pipeline_depth=1,
                            coalesce_window_s=0.01, guard=guard,
                            journal=tmp_path / "journal.jsonl")
        real_explore = svc.session.explore_stage

        def slow_explore(reqs):
            time.sleep(0.3)        # hold batches in the pipeline
            return real_explore(reqs)

        svc.session.explore_stage = slow_explore
        svc.serve()
        tickets = [svc.submit(_request(seed=sd, layout=False))
                   for sd in range(4)]
        outcomes: dict[int, str] = {}
        errors: list = []

        def collector(t):
            try:
                svc.collect(t, timeout=120, keep_done=True)
                outcomes[t] = "drained"
            except PendingTicket:
                outcomes[t] = "journaled"
            except Exception as e:
                errors.append((t, e))

        def poller(t):
            try:
                while True:
                    if svc.poll(t) is not None:
                        outcomes[t] = "drained"
                        return
                    time.sleep(0.02)
            except PendingTicket:
                outcomes[t] = "journaled"
            except Exception as e:
                errors.append((t, e))

        threads = [threading.Thread(target=collector, args=(t,))
                   for t in tickets[:-1]]
        threads.append(threading.Thread(target=poller,
                                        args=(tickets[-1],)))
        for th in threads:
            th.start()
        time.sleep(0.1)            # batch 0 is mid-explore
        guard.request()            # preemption drain begins...
        svc.close()                # ...and close() races it
        for th in threads:
            th.join(timeout=120)
            assert not th.is_alive(), "collector/poller deadlocked"
        assert not errors, errors
        assert sorted(outcomes) == sorted(tickets)   # no ticket lost
        assert "drained" in outcomes.values()        # batch 0 made it
        drained = [t for t, o in outcomes.items() if o == "drained"]
        for t in drained:          # drained artifacts are real and ok
            assert svc.done[t].ok
        journaled = [t for t, o in outcomes.items() if o == "journaled"]
        # the WAL holds exactly the tickets that did not drain locally
        # (plus any that drained after being journaled mid-flight)
        assert len(svc.journal) >= len(journaled)
        assert svc.stats()["preemptions"] == 1


# -- stats() snapshot -----------------------------------------------------

class TestStatsSnapshot:
    def test_snapshot_is_isolated_and_gauged(self):
        svc = DesignService()
        t0 = svc.submit(_request(seed=0, layout=False))
        svc.submit(_request(seed=1, layout=False))
        before = svc.stats()
        assert before["queue_depth"] == 2
        assert before["done_count"] == 0
        assert not before["pump_alive"]
        # mutating the snapshot must not corrupt the service
        before["explorer_dispatches"] = 10 ** 9
        before["stage_busy_s"]["explore"] = -1.0
        svc.run()
        after = svc.stats()
        assert after["queue_depth"] == 0
        assert after["done_count"] == 2
        assert after["explorer_dispatches"] < 10 ** 9
        assert after["stage_busy_s"]["explore"] >= 0.0
        assert set(after["stage_queue_depth"]) == {"explore", "distill",
                                                   "layout", "finalize"}
        svc.collect(t0)
        assert svc.stats()["done_count"] == 1

    def test_inflight_gauge_returns_to_zero(self):
        svc = DesignService(coalesce_window_s=0.02)
        with svc.serve():
            t = svc.submit(_request(layout=False))
            svc.collect(t, timeout=600)
        stats = svc.stats()
        assert stats["inflight_batches"] == 0
        assert all(d == 0 for d in stats["stage_queue_depth"].values())


# -- provenance schema ----------------------------------------------------

class TestPipelineProvenance:
    def test_waits_round_trip_through_json(self, tmp_path):
        svc = DesignService(max_coalesce=1)
        with svc.serve():
            tickets = [svc.submit(_request(seed=sd, requirements=REQS,
                                           layout=True))
                       for sd in (0, 1)]
            art = svc.collect(tickets[1], timeout=600)
            svc.collect(tickets[0], timeout=600)
        path = tmp_path / "artifact.json"
        art.to_json(path)
        from repro.api import DesignArtifact

        back = DesignArtifact.from_json(path)
        assert back.provenance == art.provenance
        assert back.provenance.pipelined
        assert back.provenance.explore_wait_s == art.provenance.explore_wait_s

    def test_coalesced_batch_shares_explore_wait(self):
        svc = DesignService(coalesce_window_s=0.2)
        with svc.serve():
            ta = svc.submit(_request(seed=0, layout=False))
            tb = svc.submit(_request(seed=1, layout=False))
            a = svc.collect(ta, timeout=600)
            b = svc.collect(tb, timeout=600)
        assert a.provenance.coalesced == b.provenance.coalesced == 2
        # one batch -> one explore-queue wait, stamped on both tenants
        assert a.provenance.explore_wait_s == b.provenance.explore_wait_s
