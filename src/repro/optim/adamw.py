"""AdamW implemented from scratch (no optax in this environment).

Production features:
  * configurable moment dtype (f32 default; bf16 halves optimizer HBM —
    used by the 480B config to fit 16 GB/chip together with FSDP);
  * global-norm gradient clipping;
  * decoupled weight decay with a no-decay filter (norms, biases, scalars);
  * bias-corrected updates; cosine LR schedule with linear warmup.

State is a plain pytree {m, v, count}, sharded exactly like the parameters
(the sharding policy maps specs leaf-for-leaf), so FSDP shards Adam moments
along with the weights — ZeRO-1/3 style.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32
    quantized_moments: bool = False   # int8 blockwise m/v (4x HBM saving)
    quant_block: int = 256
    # leaves >= this many elements update under lax.map over their leading
    # (stacked-layer) axis: peak optimizer temps drop from O(leaf) to
    # O(leaf / n_layers) — required for the 480B config's 16 GB budget
    scan_update_threshold: int = 1 << 27
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _decay_mask(params: PyTree) -> PyTree:
    """True where weight decay applies: >= 2D tensors (not norms/biases)."""
    return jax.tree.map(lambda p: p.ndim >= 2, params)


def _nblocks(n: int, block: int) -> int:
    return max(1, -(-n // block))


def quantize_blockwise(x: Array, block: int) -> tuple[Array, Array]:
    """Symmetric int8 quantization in blocks along the last axis; shapes
    stay param-aligned so sharding specs carry over (scale drops the last
    dim's sharding)."""
    shape = x.shape
    last = shape[-1] if shape else 1
    nb = _nblocks(last, block)
    pad = nb * block - last
    xp = jnp.pad(x.reshape(shape[:-1] + (last,)) if shape else x[None],
                 [(0, 0)] * (max(len(shape), 1) - 1) + [(0, pad)])
    xb = xp.reshape(xp.shape[:-1] + (nb, block))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0 + 1e-12
    q = jnp.round(xb / scale[..., None]).astype(jnp.int8)
    return q.reshape(xp.shape[:-1] + (nb * block,))[..., :last].reshape(shape) \
        if pad else q.reshape(shape), scale


def dequantize_blockwise(q: Array, scale: Array, block: int) -> Array:
    shape = q.shape
    last = shape[-1] if shape else 1
    nb = scale.shape[-1]
    pad = nb * block - last
    qp = jnp.pad(q if shape else q[None],
                 [(0, 0)] * (max(len(shape), 1) - 1) + [(0, pad)])
    xb = qp.reshape(qp.shape[:-1] + (nb, block)).astype(jnp.float32)
    x = xb * scale[..., None]
    return x.reshape(qp.shape[:-1] + (nb * block,))[..., :last].reshape(shape)


def init(params: PyTree, cfg: AdamWConfig) -> PyTree:
    if cfg.quantized_moments:
        def qzeros(p):
            nb = _nblocks(p.shape[-1] if p.shape else 1, cfg.quant_block)
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "s": jnp.zeros(p.shape[:-1] + (nb,) if p.shape else (nb,),
                                   jnp.float32)}

        return {"m": jax.tree.map(qzeros, params),
                "v": jax.tree.map(qzeros, params),
                "count": jnp.zeros((), jnp.int32)}
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(grads: PyTree, opt_state: PyTree, params: PyTree,
           cfg: AdamWConfig) -> tuple[PyTree, PyTree, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, count)
    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    decay = _decay_mask(params)

    def upd(g, m, v, p, wd):
        g = g.astype(jnp.float32) * scale
        if cfg.quantized_moments:
            mf = dequantize_blockwise(m["q"], m["s"], cfg.quant_block)
            vf = dequantize_blockwise(v["q"], v["s"], cfg.quant_block)
        else:
            mf, vf = m.astype(jnp.float32), v.astype(jnp.float32)
        m2 = cfg.b1 * mf + (1 - cfg.b1) * g
        v2 = cfg.b2 * vf + (1 - cfg.b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if wd:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        if cfg.quantized_moments:
            mq, ms = quantize_blockwise(m2, cfg.quant_block)
            vq, vs = quantize_blockwise(v2, cfg.quant_block)
            return p2, {"q": mq, "s": ms}, {"q": vq, "s": vs}
        return p2, m2.astype(m.dtype), v2.astype(v.dtype)

    def upd_maybe_scanned(g, m, v, p, wd):
        if (p.size >= cfg.scan_update_threshold and p.ndim >= 2
                and p.shape[0] <= 256):
            def one(slc):
                gi, mi, vi, pi = slc
                return upd(gi, mi, vi, pi, wd)

            p2, m2, v2 = jax.lax.map(one, (g, m, v, p))
            return p2, m2, v2
        return upd(g, m, v, p, wd)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_wd = jax.tree.leaves(decay)
    out = [upd_maybe_scanned(g, m, v, p, wd) for g, m, v, p, wd
           in zip(flat_g, flat_m, flat_v, flat_p, flat_wd)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr,
               "clip_scale": scale}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
