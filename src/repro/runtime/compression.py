"""Gradient compression for the cross-pod all-reduce.

int8 error-feedback compression: gradients are quantized to int8 blockwise
before the (DCN-crossing) "pod" all-reduce; the quantization residual is
carried in an error-feedback buffer and added back next step, so the
*accumulated* gradient is unbiased (Karimireddy et al., 2019).  16x ->
4x byte reduction on the slowest link in a multi-pod job.

Implemented with shard_map over the "pod" axis so the collective is
explicit (psum of dequantized int8 blocks); per-pod gradients inside each
pod still use XLA's native reductions.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import dequantize_blockwise, quantize_blockwise
from repro.parallel.axes import shard_map

PyTree = Any


def init_error_feedback(params_like: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params_like)


def compress_decompress(g: jax.Array, ef: jax.Array, block: int = 256):
    """Quantize (g + ef) to int8 blocks; return (dequantized, new_ef)."""
    target = g.astype(jnp.float32) + ef
    q, s = quantize_blockwise(target, block)
    deq = dequantize_blockwise(q, s, block)
    return deq, target - deq


def cross_pod_allreduce_compressed(grads: PyTree, ef: PyTree, mesh,
                                   block: int = 256) -> tuple[PyTree, PyTree]:
    """Mean-reduce grads over the "pod" axis in int8, with error feedback.

    grads are assumed already reduced within each pod (XLA handles that via
    the normal backward pass); this applies only the pod-crossing hop.
    """
    if "pod" not in mesh.axis_names:
        return grads, ef

    npod = mesh.shape["pod"]

    def one(g, e):
        deq, e2 = compress_decompress(g, e, block)

        @partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
                 check_vma=False)
        def psum_pod(x):
            return jax.lax.psum(x, "pod") / npod

        return psum_pod(deq), e2

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])
