"""Telemetry subsystem: span recorder/trace export, metrics registry,
prometheus rendering, the feedback controller (synthetic clocks), and
the service integration contracts — Gantt span sums vs busy clocks,
mid-batch snapshot flushing, pool grow/shrink token conservation."""
import json
import threading
import time

import numpy as np
import pytest

from repro.api import DesignRequest, DesignSession, Requirements
from repro.serve.design_service import DesignService
from repro.telemetry import (DEFAULT_LATENCY_BUCKETS, METRICS_SCHEMA,
                             TRACE_SCHEMA, ControllerConfig,
                             FeedbackController, Histogram, MetricsRegistry,
                             SpanRecorder, Telemetry, TraceExport,
                             atomic_write_json, load_snapshot, percentile,
                             render_prometheus, write_metrics_json)

pytestmark = pytest.mark.timeout(900)

POP, GENS = 48, 10
REQS = Requirements(min_tops=0.5, min_snr_db=10.0)


def _request(array_size=4096, seed=0, **kw):
    kw.setdefault("pop_size", POP)
    kw.setdefault("generations", GENS)
    return DesignRequest(array_size=array_size, seed=seed, **kw)


class _Clock:
    """Deterministic monotonic clock for recorder/controller tests."""

    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


# -- percentile (the shared quantile math) --------------------------------

class TestPercentile:
    def test_matches_numpy_linear_interpolation(self):
        rng = np.random.default_rng(7)
        for n in (1, 2, 3, 10, 101):
            xs = rng.uniform(-50, 50, size=n).tolist()
            for q in (0, 1, 25, 50, 75, 95, 99, 100):
                assert percentile(xs, q) == pytest.approx(
                    float(np.percentile(xs, q)), abs=1e-12)

    def test_edge_contracts(self):
        with pytest.raises(ValueError, match="empty"):
            percentile([], 50)
        with pytest.raises(ValueError, match="outside"):
            percentile([1.0], 101)
        assert percentile([3.0], 95) == 3.0


# -- metrics registry -----------------------------------------------------

class TestMetrics:
    def test_counter_gauge_fn_proxy_wins(self):
        reg = MetricsRegistry()
        box = {"n": 0}
        c = reg.counter("widgets_total", "w", fn=lambda: box["n"])
        box["n"] = 7
        assert c.value == 7.0
        g = reg.gauge("depth", fn=lambda: 3)
        assert g.value == 3.0
        # re-registration returns the same object; kind mismatch raises
        assert reg.counter("widgets_total") is c
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("widgets_total")

    def test_labels_key_separate_series(self):
        reg = MetricsRegistry()
        a = reg.counter("served", labels={"tier": "cache"})
        b = reg.counter("served", labels={"tier": "explorer"})
        assert a is not b
        a.inc(2)
        snap = reg.snapshot()
        assert snap["schema"] == METRICS_SCHEMA
        assert len(snap["metrics"]["served"]) == 2

    def test_histogram_buckets_and_summary(self):
        h = Histogram("lat", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.1, 0.5, 5.0, 50.0):
            h.observe(v)
        d = h.to_dict()
        # le is inclusive: 0.1 lands in the first bucket
        assert [c for _, c in d["buckets"]] == [2, 1, 1]
        assert d["inf_count"] == 1
        assert d["count"] == 5
        s = h.summary()
        assert s["p50"] == pytest.approx(
            float(np.percentile([0.05, 0.1, 0.5, 5.0, 50.0], 50)))
        with pytest.raises(ValueError, match="ascending"):
            Histogram("bad", buckets=(1.0, 1.0))

    def test_default_buckets_are_log_spaced_and_fixed(self):
        assert DEFAULT_LATENCY_BUCKETS[0] == pytest.approx(0.001)
        ratios = {b2 / b1 for b1, b2 in zip(DEFAULT_LATENCY_BUCKETS,
                                            DEFAULT_LATENCY_BUCKETS[1:])}
        assert ratios == {2.0}

    def test_prometheus_render_and_json_roundtrip(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("jobs_total", "jobs", labels={"kind": "a"}).inc(3)
        reg.gauge("depth", fn=lambda: 2)
        h = reg.histogram("lat_seconds", buckets=(0.5, 1.0))
        h.observe(0.25)
        h.observe(2.0)
        snap = reg.snapshot()
        text = render_prometheus(snap)
        assert '# TYPE jobs_total counter' in text
        assert 'jobs_total{kind="a"} 3' in text
        assert 'lat_seconds_bucket{le="0.5"} 1' in text
        assert 'lat_seconds_bucket{le="+Inf"} 2' in text
        assert 'lat_seconds_count 2' in text
        path = tmp_path / "m.json"
        write_metrics_json(snap, path)
        assert load_snapshot(path)["metrics"]["depth"][0]["value"] == 2
        with pytest.raises(ValueError, match="schema"):
            render_prometheus({"schema": 0, "metrics": {}})
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"schema": 999}))
        with pytest.raises(ValueError, match="schema"):
            load_snapshot(bad)

    def test_atomic_write_never_leaves_partials(self, tmp_path):
        path = tmp_path / "x.json"
        atomic_write_json({"ok": 1}, path)
        assert json.loads(path.read_text()) == {"ok": 1}
        assert list(tmp_path.glob("*.tmp")) == []


# -- span recorder + trace export -----------------------------------------

class TestSpans:
    def test_span_lifecycle_and_export(self):
        clk = _Clock()
        rec = SpanRecorder(clock=clk)
        s = rec.begin("explore", cat="stage", batch=0, at=clk.t)
        clk.advance(2.0)
        rec.end(s, at=clk.t)
        rec.instant("admit", cat="pump", batch=1)
        clk.advance(1.0)
        rec.begin("layout", cat="stage", batch=0, bucket=(8, 8))
        exp = rec.export()                       # flushes the open span
        assert exp.schema == TRACE_SCHEMA
        names = [sp.name for sp in exp.spans]
        assert names == ["explore", "admit", "layout"]
        open_span = exp.spans[-1]
        assert open_span.args["open"] is True
        assert open_span.duration_s == pytest.approx(0.0)   # flushed at now
        assert open_span.bucket == "(8, 8)"      # stringified tag
        assert exp.stage_totals() == pytest.approx({"explore": 2.0,
                                                    "layout": 0.0})

    def test_chrome_trace_events_and_roundtrip(self, tmp_path):
        clk = _Clock()
        rec = SpanRecorder(clock=clk)
        with rec.span("distill", cat="stage", batch=3,
                      worker="distill", requests=4):
            clk.advance(0.5)
        rec.instant("shed", cat="fault", bucket="(4, 4)")
        exp = rec.export()
        evs = exp.to_events()
        assert evs[0]["ph"] == "X" and evs[0]["dur"] == pytest.approx(5e5)
        assert evs[0]["args"] == {"requests": 4, "batch": 3}
        assert evs[1]["ph"] == "i"
        path = tmp_path / "trace.json"
        exp.to_json(path)
        back = TraceExport.from_json(path)
        assert [s.name for s in back.spans] == ["distill", "shed"]
        assert back.stage_totals() == pytest.approx({"distill": 0.5})
        bad = dict(json.loads(path.read_text()), schema=0)
        with pytest.raises(ValueError, match="schema"):
            TraceExport.from_dict(bad)

    def test_gantt_groups_by_batch(self):
        clk = _Clock()
        rec = SpanRecorder(clock=clk)
        with rec.span("explore", cat="stage", batch=0):
            clk.advance(1.0)
        with rec.span("explore", cat="stage", batch=1):
            clk.advance(1.0)
        rec.instant("control", cat="control", window_s=0.1)
        g = rec.export().gantt()
        assert g["schema"] == TRACE_SCHEMA
        assert set(g["batches"]) == {0, 1, -1}
        row = g["batches"][0][0]
        assert row["t1_s"] - row["t0_s"] == pytest.approx(1.0)

    def test_threaded_recording_is_complete(self):
        rec = SpanRecorder()

        def work(i):
            for k in range(50):
                with rec.span("unit", cat="stage", batch=i, k=k):
                    pass
        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(rec) == 200


# -- feedback controller (synthetic clock) --------------------------------

def _tick(c, clk, **kw):
    kw.setdefault("queue_depth", 0)
    kw.setdefault("layout_backlog", 0)
    kw.setdefault("inflight_buckets", 0)
    kw.setdefault("layout_workers", 1)
    return c.tick(clk.t, **kw)


class TestFeedbackController:
    def test_burst_widens_idle_narrows_window(self):
        cfg = ControllerConfig(min_window_s=0.01, max_window_s=0.5,
                               target_batch=8, window_smoothing=0.0,
                               rate_decay=0.0, tick_interval_s=0.05)
        c = FeedbackController(cfg)
        clk = _Clock()
        assert _tick(c, clk, arrivals_total=0, window_s=0.01) is None
        clk.advance(1.0)         # 40 arrivals/s: ideal window 8/40 = 0.2
        d = _tick(c, clk, arrivals_total=40, window_s=0.01)
        assert d is not None and d.window_s == pytest.approx(0.2)
        clk.advance(1.0)         # idle: back to the latency floor
        d = _tick(c, clk, arrivals_total=40, window_s=d.window_s)
        assert d is not None and d.window_s == pytest.approx(0.01)

    def test_window_clamped_to_bounds(self):
        cfg = ControllerConfig(min_window_s=0.02, max_window_s=0.1,
                               target_batch=100, window_smoothing=0.0,
                               rate_decay=0.0, tick_interval_s=0.05)
        c = FeedbackController(cfg)
        clk = _Clock()
        _tick(c, clk, arrivals_total=0, window_s=0.05)
        clk.advance(1.0)         # 1/s -> desired 100s, clamped to max
        d = _tick(c, clk, arrivals_total=1, window_s=0.05)
        assert d.window_s == pytest.approx(0.1)

    def test_sub_interval_ticks_are_ignored(self):
        cfg = ControllerConfig(tick_interval_s=0.05, target_batch=4)
        c = FeedbackController(cfg)
        clk = _Clock()
        _tick(c, clk, arrivals_total=0, window_s=0.05)
        clk.advance(0.01)
        assert _tick(c, clk, arrivals_total=99, window_s=0.05) is None
        # the delayed tick still sees every arrival (monotonic counter)
        clk.advance(0.05)
        d = _tick(c, clk, arrivals_total=99, window_s=0.05)
        assert c.arrival_rate > 0

    def test_pool_scaling_needs_hysteresis(self):
        cfg = ControllerConfig(min_workers=1, max_workers=3,
                               scale_up_backlog=2.0, hysteresis_ticks=3,
                               target_batch=4, tick_interval_s=0.05)
        c = FeedbackController(cfg, recorder=SpanRecorder())
        clk = _Clock()
        _tick(c, clk, arrivals_total=0, window_s=0.05)
        grew = []
        for _ in range(6):
            clk.advance(0.1)
            d = _tick(c, clk, arrivals_total=0, window_s=0.05,
                      layout_backlog=8, layout_workers=1,
                      inflight_buckets=1)
            if d is not None and d.workers != 1:
                grew.append(d)
        # exactly every hysteresis_ticks'th pressured tick grows by one
        assert [d.workers for d in grew] == [2, 2]
        # decisions are recorded as control spans
        cats = {s.cat for s in c.recorder.export().spans}
        assert cats == {"control"}

    def test_single_idle_tick_does_not_shrink(self):
        cfg = ControllerConfig(min_workers=1, max_workers=3,
                               hysteresis_ticks=3, target_batch=4,
                               tick_interval_s=0.05)
        c = FeedbackController(cfg)
        clk = _Clock()
        _tick(c, clk, arrivals_total=0, window_s=0.05)
        clk.advance(0.1)        # one idle observation: no actuation
        d = _tick(c, clk, arrivals_total=0, window_s=0.05,
                  layout_workers=2)
        assert d is None or d.workers == 2
        clk.advance(0.1)        # pressure resets the down counter
        _tick(c, clk, arrivals_total=0, window_s=0.05, layout_workers=2,
              layout_backlog=8, inflight_buckets=2)
        for _ in range(2):
            clk.advance(0.1)
            d = _tick(c, clk, arrivals_total=0, window_s=0.05,
                      layout_workers=2)
            assert d is None or d.workers == 2   # counter restarted

    def test_config_validation(self):
        with pytest.raises(ValueError, match="min_window_s"):
            ControllerConfig(min_window_s=0.0)
        with pytest.raises(ValueError, match="min_workers"):
            ControllerConfig(min_workers=2, max_workers=1)
        with pytest.raises(ValueError, match="hysteresis"):
            ControllerConfig(hysteresis_ticks=0)


# -- service integration --------------------------------------------------

class TestServiceTelemetry:
    def test_metrics_work_without_telemetry_opt_in(self):
        svc = DesignService()
        assert svc.trace() is None
        snap = svc.metrics()
        assert snap["schema"] == METRICS_SCHEMA
        gauges = {s["labels"].get("stage"): s["value"]
                  for s in snap["metrics"]["design_stage_busy_seconds"]}
        assert set(gauges) == {"explore", "distill", "layout", "finalize"}

    def test_mid_batch_snapshot_flushes_open_clocks(self):
        # the satellite-2 contract: an OPEN stage clock is flushed into
        # both stats() and the metrics gauges — a mid-batch snapshot
        # reports in-progress stage time, never a stale closed total
        svc = DesignService(telemetry=True)
        t0 = time.monotonic() - 1.0
        with svc._lock:
            svc._mark("explore", busy=True, now=t0)
        open_span = svc.recorder.begin("explore", cat="stage", at=t0)
        try:
            st = svc.stats()
            assert st["stage_busy_s"]["explore"] >= 1.0
            assert st["stage_busy"]["explore"] is True
            snap = svc.metrics()
            busy = {s["labels"]["stage"]: s["value"] for s in
                    snap["metrics"]["design_stage_busy_seconds"]}
            assert busy["explore"] >= 1.0
            trace = svc.trace()              # open span flushed too
            assert trace.stage_totals()["explore"] >= 1.0
        finally:
            with svc._lock:
                svc._mark("explore", busy=False)
            svc.recorder.end(open_span)

    def test_gantt_totals_agree_with_busy_clocks_k1(self):
        # acceptance: with single-occupant stages (K=1) the span edges
        # share the busy clocks' exact monotonic reads, so per-stage
        # span sums equal the busy clocks to float precision
        svc = DesignService(max_coalesce=1, layout_workers=1,
                            telemetry=True)
        with svc.serve():
            tickets = [svc.submit(_request(seed=sd, requirements=REQS,
                                           layout=True))
                       for sd in (0, 1)]
            arts = [svc.collect(t, timeout=600) for t in tickets]
        assert all(a.ok for a in arts)
        totals = svc.trace().stage_totals()
        busy = svc.stats()["stage_busy_s"]
        for stage in ("explore", "distill", "layout", "finalize"):
            assert totals[stage] == pytest.approx(busy[stage], abs=1e-9)
        # the Gantt carries every batch, each with all four stages
        g = svc.trace().gantt()
        for seq in (0, 1):
            names = {r["name"] for r in g["batches"][seq]
                     if r["cat"] == "stage"}
            assert names == {"explore", "distill", "layout", "finalize"}

    def test_metrics_latency_histogram_and_tiers(self, tmp_path):
        ses = DesignSession(artifact_cache=tmp_path)
        svc = DesignService(ses, telemetry=True)
        req = _request(seed=0, requirements=REQS, layout=True)
        with svc.serve():
            a1 = svc.collect(svc.submit(req), timeout=600)
        svc2 = DesignService(DesignSession(artifact_cache=tmp_path))
        with svc2.serve():
            a2 = svc2.collect(svc2.submit(req), timeout=600)
        assert a1.summary() == a2.summary()
        for s, expect_tier in ((svc, "explorer"), (svc2, "artifact_cache")):
            snap = s.metrics()
            lat = snap["metrics"]["design_ticket_latency_seconds"][0]
            assert lat["count"] == 1
            assert lat["summary"]["p50"] > 0
            tiers = {t["labels"]["tier"]: t["value"] for t in
                     snap["metrics"]["design_tickets_served_total"]}
            assert tiers[expect_tier] == 1.0
        text = render_prometheus(svc.metrics())
        assert "design_ticket_latency_seconds_bucket" in text
        assert 'design_tickets_served_total{tier="explorer"} 1' in text

    def test_pool_grow_shrink_conserves_sentinels(self):
        # the deadlock-prone path: grow the pool mid-serve, shrink it
        # back (shrink tokens pending in the layout queue), then close
        # with work still queued — every ticket must land and close()
        # must join every worker (finalize sentinel fired exactly once)
        svc = DesignService(max_coalesce=1, layout_workers=1,
                            telemetry=True)
        with svc.serve():
            with svc._lock:
                svc._grow_pool()
                svc._grow_pool()
            tickets = [svc.submit(_request(seed=sd, requirements=REQS,
                                           layout=True))
                       for sd in (0, 1)]
            with svc._lock:
                svc._shrink_pool()
            arts = [svc.collect(t, timeout=600) for t in tickets]
        assert all(a.ok for a in arts)
        st = svc.stats()
        assert st["pool_scale_ups"] == 2
        assert st["pool_scale_downs"] == 1
        assert svc.layout_workers == 2
        assert not any(t.is_alive() for t in svc._stage_threads)

    def test_adaptive_window_moves_under_load(self):
        cfg = ControllerConfig(min_window_s=0.01, max_window_s=0.3,
                               target_batch=4, tick_interval_s=0.02,
                               window_smoothing=0.0)
        svc = DesignService(max_coalesce=4, coalesce_window_s=0.01,
                            telemetry=True, controller=cfg)
        assert svc.controller.config.target_batch == 4
        with svc.serve():
            tickets = [svc.submit(_request(seed=sd, requirements=REQS,
                                           layout=False))
                       for sd in (0, 1, 2)]
            arts = [svc.collect(t, timeout=600) for t in tickets]
        assert all(a.ok for a in arts)
        st = svc.stats()
        assert st["control_window_updates"] == len(
            [d for d in svc.controller.decisions]) >= 1
        cfg = svc.controller.config
        assert cfg.min_window_s <= svc.coalesce_window_s <= cfg.max_window_s
        # every actuation is auditable as a control span
        control = [s for s in svc.trace().spans if s.cat == "control"]
        assert len(control) >= len(svc.controller.decisions)

    def test_telemetry_bundle_shares_recorder_with_session(self):
        tel = Telemetry()
        svc = DesignService(telemetry=tel)
        assert svc.session.recorder is tel.recorder
        assert svc.recorder is tel.recorder
        assert svc.registry is tel.metrics
