"""Pareto utilities + NSGA-II: hypothesis properties and ground-truth
front recovery against exhaustive enumeration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import explorer, nsga2, pareto

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def objs(draw_rows):
    return jnp.asarray(np.array(draw_rows, np.float32))


@st.composite
def objective_sets(draw):
    p = draw(st.integers(3, 24))
    m = draw(st.integers(2, 4))
    rows = draw(st.lists(
        st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                 min_size=m, max_size=m), min_size=p, max_size=p))
    return np.array(rows, np.float32)


class TestDominance:
    @given(objective_sets())
    def test_irreflexive(self, f):
        d = np.asarray(pareto.dominance_matrix(jnp.asarray(f)))
        assert not d.diagonal().any()

    @given(objective_sets())
    def test_antisymmetric(self, f):
        d = np.asarray(pareto.dominance_matrix(jnp.asarray(f)))
        assert not (d & d.T).any()

    @given(objective_sets())
    def test_transitive(self, f):
        d = np.asarray(pareto.dominance_matrix(jnp.asarray(f)))
        viol = (d.astype(int) @ d.astype(int) > 0) & ~d
        # i dom j, j dom k => i dom k  (true for Pareto dominance)
        assert not viol.any()

    @given(objective_sets())
    def test_rank_zero_iff_nondominated(self, f):
        fj = jnp.asarray(f)
        ranks = np.asarray(pareto.non_dominated_rank(fj))
        nd = np.asarray(pareto.non_dominated_mask(fj))
        assert ((ranks == 0) == nd).all()

    @given(objective_sets())
    def test_rank_matches_bruteforce_peeling(self, f):
        fj = jnp.asarray(f)
        ranks = np.asarray(pareto.non_dominated_rank(fj))
        # brute force peeling
        remaining = list(range(len(f)))
        expect = np.zeros(len(f), int)
        level = 0
        while remaining:
            sub = f[remaining]
            d = np.asarray(pareto.dominance_matrix(jnp.asarray(sub)))
            front = [remaining[i] for i in range(len(remaining))
                     if not d[:, i].any()]
            for i in front:
                expect[i] = level
                remaining.remove(i)
            level += 1
        assert (ranks == expect).all()

    def test_crowding_boundaries_infinite(self):
        f = jnp.asarray(np.array([[0., 5.], [1., 4.], [2., 3.], [3., 2.]],
                                 np.float32))
        ranks = pareto.non_dominated_rank(f)
        crowd = np.asarray(pareto.crowding_distance(f, ranks))
        assert crowd[0] > 1e20 and crowd[-1] > 1e20
        assert np.all(crowd[1:-1] < 1e20)

    def test_constrained_dominance_feasible_beats_infeasible(self):
        f = jnp.asarray(np.array([[5., 5.], [0., 0.]], np.float32))
        cv = jnp.asarray(np.array([0.0, 2.0], np.float32))
        d = np.asarray(pareto.constrained_dominance_matrix(f, cv))
        assert d[0, 1] and not d[1, 0]


class TestNSGA2:
    def test_recovers_true_front_16kb(self):
        genes, objs_all = explorer.full_design_space(16384)
        true_front_mask = np.asarray(pareto.non_dominated_mask(objs_all))
        true_front = {tuple(g) for g, m in
                      zip(np.asarray(genes), true_front_mask) if m}
        res = explorer.explore(16384, pop_size=192, generations=60, seed=3)
        found = {(int(np.log2(s.h)), int(np.log2(s.l)), s.b_adc)
                 for s in res.specs}
        # every found point is truly non-dominated...
        assert found <= true_front
        # ...and covers most of the true front
        assert len(found) >= 0.6 * len(true_front)

    def test_population_always_feasible(self):
        cfg = nsga2.NSGA2Config(array_size=16384, pop_size=64, generations=10)
        pop = nsga2.run(cfg)
        cv = np.asarray(nsga2.constraint_violation(pop.genes, cfg))
        assert (cv == 0).all()
        g = np.asarray(pop.genes)
        h_lo, h_hi = cfg.h_exp_bounds
        assert (g[:, 0] >= h_lo).all() and (g[:, 0] <= h_hi).all()
        assert (g[:, 2] >= 1).all() and (g[:, 2] <= (g[:, 0] - g[:, 1])).all()

    def test_repair_projects_into_feasible_set(self):
        cfg = nsga2.NSGA2Config(array_size=16384)
        bad = jnp.asarray(np.array([[20, 9, 9], [4, 7, 8], [6, 1, 0]], np.int32))
        fixed = np.asarray(nsga2.repair(bad, cfg))
        cv = np.asarray(nsga2.constraint_violation(jnp.asarray(fixed), cfg))
        assert (cv == 0).all()
        assert (fixed[:, 2] >= 1).all()

    def test_agile_filter(self):
        res = explorer.explore(16384, pop_size=96, generations=25, seed=5)
        filt = res.filter(min_tops=0.5)
        assert all(m >= 0.5 for m in filt.metrics["tops"])
        assert len(filt) <= len(res)
