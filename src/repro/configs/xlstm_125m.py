"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks (1:1 alternation).  [arXiv:2405.04517; unverified]

d_ff = 0: xLSTM blocks carry their own up/down projections (proj_factor 2);
there is no separate FFN.  Runs long_500k (recurrent state, O(1)/token).
"""
import dataclasses

from repro.configs.base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    norm="layernorm", act="gelu", mlp_gated=False,
    pos="none",
    xlstm=XLSTMConfig(proj_factor=2.0, conv_width=4, chunk=64, slstm_every=2),
    source="arXiv:2405.04517; unverified",
)

REDUCED = dataclasses.replace(
    CONFIG, name="xlstm-reduced",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, vocab=512,
    xlstm=XLSTMConfig(proj_factor=2.0, conv_width=4, chunk=16, slstm_every=2),
)
