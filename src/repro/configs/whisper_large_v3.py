"""whisper-large-v3 [audio]: 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866 — enc-dec, conv frontend (STUB).  [arXiv:2212.04356; unverified]

32 encoder + 32 decoder layers; the conv frontend is stubbed per the
assignment: `input_specs()` provides precomputed frame embeddings
(B, 1500, 1280).  LayerNorm, plain GELU, MHA, sinusoidal (enc) / learned
(dec) positions, output head tied to the token embedding.
"""
import dataclasses

from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    norm="layernorm", act="gelu", mlp_gated=False, attn_bias=True,
    pos="learned", tie_embeddings=True,
    encdec=EncDecConfig(n_enc_layers=32, enc_frames=1500),
    source="arXiv:2212.04356; unverified",
)

REDUCED = dataclasses.replace(
    CONFIG, name="whisper-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    head_dim=16,
    encdec=EncDecConfig(n_enc_layers=2, enc_frames=32),
)
