"""Fixture: `schema_drifted.py` with the version constant bumped — the
field change is now legitimate, but a manifest still recording version
1 must be reported as manifest-stale until regenerated.
"""
TRACE_SCHEMA = 2


class TraceExport:
    def __init__(self, name, spans):
        self.name = name
        self.spans = spans

    def to_dict(self):
        return {"schema": TRACE_SCHEMA, "name": self.name,
                "spans": list(self.spans), "host": "localhost"}

    def to_events(self):
        return [{"ph": "X", "name": self.name}]
