"""End-to-end driver: train an LM whose FFN projections execute on the
EasyACIM-generated macro (quantization + ADC + mismatch in the loop), with
checkpointing and auto-resume.

  PYTHONPATH=src python examples/train_acim_lm.py --steps 200
  PYTHONPATH=src python examples/train_acim_lm.py --d-model 768 --layers 12 \
      --steps 300            # ~125M-class run (sized for real hardware)

The macro is chosen by the codesign loop (`recommend_macro`); pass
--no-cim to train the same model on the exact digital path for comparison.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.acim_spec import MacroSpec
from repro.core.codesign import recommend_macro
from repro.configs.base import ArchConfig
from repro.data.synthetic import batch_for
from repro.models import lm as lm_mod
from repro.models.common import softmax_cross_entropy
from repro.quant.cim_linear import CIMConfig, cim_linear


def build_cfg(args) -> ArchConfig:
    return ArchConfig(
        name="acim-lm", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=max(2, args.d_model // 64),
        n_kv_heads=max(2, args.d_model // 64), d_ff=args.d_model * 4,
        vocab=2048, norm="rmsnorm", act="silu", mlp_gated=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--no-cim", action="store_true")
    args = ap.parse_args()

    cfg = build_cfg(args)
    if args.no_cim:
        cim = None
        print("digital (exact) FFN path")
    else:
        rec = recommend_macro(cfg, array_size=16384, min_snr_db=3.0,
                              pop_size=96, generations=25)
        cim = CIMConfig(rec.spec)
        print(f"codesign pick: {rec.spec} (SNR {rec.snr_db:.1f} dB, "
              f"util {rec.utilization:.2f}, {rec.eff_tops_per_w:.0f} TOPS/W, "
              f"{rec.macro_count_for_rate} macros @ 1 tok/us)")

    params = lm_mod.init_lm(jax.random.key(0), cfg)

    def loss_fn(params, batch):
        # run the backbone, then rerun FFNs through the macro: here we train
        # a CIM-native variant where every FFN wi/wo executes on the macro
        x = params["emb"][batch["inputs"]].astype(jnp.bfloat16)
        from repro.models.common import apply_norm, causal_mask

        mask = causal_mask(x.shape[1])
        pos = jnp.arange(x.shape[1])

        def block(x, lp):
            from repro.models import attention as attn

            h = apply_norm(lp["ln1"], x, cfg.norm)
            x = x + attn.attention_fwd(lp["attn"], h, cfg, mask=mask,
                                       positions=pos)
            h = apply_norm(lp["ln2"], x, cfg.norm).astype(jnp.float32)
            ff = jax.nn.silu(cim_linear(h, lp["ffn"]["wi"], cim))
            x = x + cim_linear(ff, lp["ffn"]["wo"], cim).astype(x.dtype)
            return x, None

        x, _ = jax.lax.scan(block, x, params["blocks"])
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = lm_mod.lm_logits(params, x, cfg)
        return softmax_cross_entropy(logits, batch["targets"])[0]

    @jax.jit
    def step(params, batch):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        params = jax.tree.map(lambda p, gg: p - args.lr * gg.astype(p.dtype),
                              params, g)
        return params, loss

    t0 = time.time()
    for i in range(args.steps):
        batch = batch_for(cfg, args.seq, args.batch, i)
        params, loss = step(params, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    print("done — CIM-in-the-loop training converged" if not args.no_cim
          else "done — digital baseline")


if __name__ == "__main__":
    main()
