"""EasyACIM quickstart: explore -> agile-filter -> layout, in one minute.

  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib

from repro.core import explorer
from repro.eda.flow import generate_layout

OUT = pathlib.Path("runs/quickstart")


def main() -> None:
    OUT.mkdir(parents=True, exist_ok=True)

    print("== 1. MOGA design-space exploration (16 kb array) ==")
    res = explorer.explore(16384, pop_size=192, generations=60)
    print(f"Pareto-frontier set: {len(res)} solutions")
    for row in sorted(res.to_rows(), key=lambda r: -r["tops"])[:5]:
        print(f"  H={row['h']:4d} W={row['w']:4d} L={row['l']:2d} "
              f"B={row['b_adc']} | {row['tops']:.3f} TOPS, "
              f"{row['tops_per_w']:.0f} TOPS/W, "
              f"{row['area_f2_per_bit']:.0f} F^2/bit, "
              f"SNR {row['snr_db']:.1f} dB")

    print("\n== 2. Agile user distillation (throughput >= 1 TOPS) ==")
    filt = res.filter(min_tops=1.0)
    print(f"{len(filt)} solutions survive")
    spec = filt.best("tops_per_w") if len(filt) else res.best("tops")
    print(f"selected: {spec}")

    print("\n== 3. Template-based layout generation ==")
    lr = generate_layout(spec)
    m = lr.metrics()
    print(f"layout: {m['layout_area_f2_per_bit']:.0f} F^2/bit "
          f"(model {m['estimator_area_f2_per_bit']:.0f}), "
          f"{m['routed_nets']} nets routed "
          f"({100 * m['route_success']:.0f}%), DRC clean={m['drc_clean']}, "
          f"{m['elapsed_s']:.1f}s")
    lr.to_json(OUT / "layout.json")
    res.to_json(OUT / "pareto.json")
    print(f"artifacts in {OUT}/")


if __name__ == "__main__":
    main()
