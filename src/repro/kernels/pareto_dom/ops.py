"""Public wrapper: pads the population to the tile size and strips it back.

Pad rows are +inf in every objective: they dominate nothing and real points
dominating them is irrelevant after slicing, so correctness is unaffected.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pareto_dom.kernel import dominance_matrix_kernel


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def dominance_matrix(f: jax.Array, *, block: int = 256,
                     interpret: bool | None = None) -> jax.Array:
    """f: (P, M) objectives (minimization).  Returns (P, P) bool."""
    if interpret is None:
        interpret = _should_interpret()
    p, m = f.shape
    block = min(block, max(8, p))
    pad = (-p) % block
    if pad:
        f = jnp.concatenate([f, jnp.full((pad, m), jnp.inf, f.dtype)], 0)
    d = dominance_matrix_kernel(f.T, block=block, interpret=interpret)
    return d[:p, :p].astype(jnp.bool_)
