"""End-to-end training convergence on the structured synthetic stream."""
import jax
import numpy as np
import pytest

from repro.configs import registry as creg
from repro.train.trainer import TrainerConfig, train


@pytest.mark.slow
def test_reduced_lm_learns(tmp_path):
    cfg = creg.reduced("qwen3_8b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tcfg = TrainerConfig(seq=128, global_batch=8, total_steps=60,
                         ckpt_every=1000, ckpt_dir=str(tmp_path), log_every=0)
    res = train(cfg, mesh, tcfg)
    first = float(np.mean(res.losses[:5]))
    last = float(np.mean(res.losses[-5:]))
    assert last < first - 0.25, (first, last)


@pytest.mark.slow
def test_microbatched_matches_full_batch(tmp_path):
    """Gradient accumulation is loss-equivalent to the monolithic batch."""
    cfg = creg.reduced("qwen2_5_3b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    runs = {}
    for mb in (1, 4):
        tcfg = TrainerConfig(seq=64, global_batch=8, total_steps=8,
                             ckpt_every=1000, microbatches=mb,
                             ckpt_dir=str(tmp_path / f"mb{mb}"), log_every=0)
        runs[mb] = train(cfg, mesh, tcfg).losses
    np.testing.assert_allclose(runs[1], runs[4], rtol=2e-2, atol=2e-2)
