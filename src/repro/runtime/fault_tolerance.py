"""Fault-tolerance runtime: preemption handling, failure simulation,
straggler monitoring, and the auto-restart supervisor loop.

Mechanisms (each exercised by tests):
  * PreemptionGuard — SIGTERM/SIGINT set a flag; the trainer checkpoints at
    the next step boundary and exits with RESTART_EXIT_CODE; the supervisor
    (launch/train.py --supervise) relaunches and training resumes from the
    atomic checkpoint, bitwise-identically (data pipeline is stateless).
  * StragglerMonitor — per-step wall-time EMA + deviation; steps slower
    than `threshold` x EMA are flagged; mitigation hook rebalances data
    shards away from slow hosts (on this single-process container the
    mitigation path is exercised with injected delays).
  * FailureInjector — deterministic fault schedule (by step) for tests:
    raises SimulatedNodeFailure to prove checkpoint/restart recovers.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable

RESTART_EXIT_CODE = 42


class SimulatedNodeFailure(RuntimeError):
    pass


class PreemptionGuard:
    """Installs SIGTERM/SIGINT handlers that request a clean stop."""

    def __init__(self) -> None:
        self._requested = False
        self._prev: dict[int, object] = {}

    def install(self) -> "PreemptionGuard":
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._prev[sig] = signal.signal(sig, self._handler)
        return self

    def _handler(self, signum, frame) -> None:  # noqa: ANN001
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self) -> None:   # tests trigger without a real signal
        self._requested = True

    def uninstall(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0       # x EMA counts as straggling
    ema_decay: float = 0.9
    ema: float | None = None
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.threshold * self.ema
        if is_straggler:
            self.events.append((step, dt, self.ema))
        else:
            # stragglers don't poison the EMA
            self.ema = dt if self.ema is None else \
                self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return is_straggler

    def mitigation_plan(self, n_hosts: int, slow_host: int) -> list[int]:
        """Return a data-shard -> host assignment that drains the slow host
        (its shards round-robin to the others) until it recovers."""
        return [h if h != slow_host else (h + 1) % n_hosts
                for h in range(n_hosts)]


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple[int, ...] = ()
    kind: str = "node"           # node | slow
    slow_seconds: float = 0.0

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps:
            if self.kind == "node":
                raise SimulatedNodeFailure(f"injected node failure at step {step}")
            time.sleep(self.slow_seconds)


def run_supervised(make_and_run: Callable[[], int], *, max_restarts: int = 5) -> int:
    """In-process supervisor: re-invokes the training function while it
    exits with RESTART_EXIT_CODE or dies with SimulatedNodeFailure."""
    restarts = 0
    while True:
        try:
            code = make_and_run()
        except SimulatedNodeFailure:
            code = RESTART_EXIT_CODE
        if code != RESTART_EXIT_CODE:
            return code
        restarts += 1
        if restarts > max_restarts:
            raise RuntimeError("restart budget exhausted")
