"""Behavioral macro model: exactness, multibit recoding, MC-vs-analytic SNR
(the simulation and the Eqs. 2-6 model validate each other)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import acim_numerics as an
from repro.core import estimator as est
from repro.core.acim_spec import MacroSpec, valid_spec


def _pm1(seed, shape):
    return jnp.where(jax.random.bernoulli(jax.random.key(seed), 0.5, shape),
                     1.0, -1.0)


class TestSpec:
    def test_constraints(self):
        assert valid_spec(128, 128, 2, 3)
        assert not valid_spec(128, 128, 2, 7)    # H/L=64 < 2^7
        assert not valid_spec(16, 16, 32, 1)     # L > H
        with pytest.raises(ValueError):
            MacroSpec(64, 64, 3, 2)              # L must divide H

    def test_sar_groups_binary_ratioed(self):
        spec = MacroSpec(128, 128, 2, 3)
        groups = spec.sar_groups()
        assert groups[:4] == [1, 1, 2, 4]
        assert sum(groups) == spec.n_caps


class TestIdealPath:
    def test_exact_when_delta_divides(self):
        spec = MacroSpec(256, 16, 2, 7)          # N=128, delta=2
        x = _pm1(0, (8, 256))
        w = _pm1(1, (256, 16))
        y = an.acim_matmul_ref(x, w, spec)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x @ w))

    def test_quantization_error_bounded_by_half_delta_per_chunk(self):
        spec = MacroSpec(128, 16, 2, 3)          # N=64, delta=16
        x = _pm1(2, (32, 128))                   # 2 chunks
        w = _pm1(3, (128, 16))
        y = an.acim_matmul_ref(x, w, spec)
        err = jnp.abs(y - x @ w)
        assert float(jnp.max(err)) <= 2 * (2 * 64 / 8) / 2 + 1e-6

    def test_zero_padding_matches_hardware_semantics(self):
        spec = MacroSpec(128, 8, 2, 5)
        x = _pm1(4, (4, 100))                    # K=100 pads to 128
        w = _pm1(5, (100, 8))
        y = an.acim_matmul_ref(x, w, spec)
        xp = jnp.pad(x, ((0, 0), (0, 28)))
        wp = jnp.pad(w, ((0, 28), (0, 0)))
        y2 = an.acim_matmul_ref(xp, wp, spec)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


class TestMultibit:
    @pytest.mark.parametrize("bx,bw", [(2, 2), (4, 4), (3, 5)])
    def test_bit_serial_recoding_exact(self, bx, bw):
        spec = MacroSpec(256, 64, 2, 7)          # N=128, delta=2: exact planes
        xi = jax.random.randint(jax.random.key(6), (4, 128),
                                -(2 ** (bx - 1)), 2 ** (bx - 1))
        wi = jax.random.randint(jax.random.key(7), (128, 8),
                                -(2 ** (bw - 1)), 2 ** (bw - 1))
        y = an.acim_matmul_multibit_ref(xi, wi, spec, bx, bw)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray((xi @ wi).astype(jnp.float32)),
                                   atol=1e-3)


class TestSNRModelVsMC:
    @pytest.mark.parametrize("h,l,b", [(128, 2, 3), (128, 2, 5), (512, 8, 4),
                                       (256, 2, 6)])
    def test_mc_matches_analytic(self, h, l, b):
        """Tolerance note: 1b x 1b sums live on an even-integer lattice, so
        the quantization error is discrete (var up to 2 vs the continuous
        model's delta^2/12) — the MC sits up to 10*log10(2/(4/3)) = 1.76 dB
        below Eqs. 2-6 at mid B.  The paper's model is continuous; we keep
        it faithful and document the lattice effect (EXPERIMENTS.md)."""
        from benchmarks.snr_mc import mc_snr_db

        spec = MacroSpec(h, 64, l, b)
        ana = float(est.snr_total_db(h, l, b))
        mc = mc_snr_db(spec, rows=256, cols=64)
        assert abs(mc - ana) < 2.0, (h, l, b, ana, mc)

    def test_noise_injection_degrades_high_precision_point(self):
        # at B=8 the ADC is fine enough that analog noise is visible
        spec = MacroSpec(1024, 2, 2, 8)
        from benchmarks.snr_mc import mc_snr_db

        clean = mc_snr_db(spec, noisy=False)
        noisy = mc_snr_db(spec, noisy=True)
        assert noisy <= clean + 0.5


class TestQuantHelpers:
    def test_symmetric_quant_roundtrip(self):
        x = jax.random.normal(jax.random.key(8), (64, 64))
        q, scale = an.quantize_symmetric(x, 8)
        err = jnp.abs(q * scale - x)
        assert float(jnp.max(err)) <= float(scale) * 0.5 + 1e-6

    def test_binarize(self):
        x = jax.random.normal(jax.random.key(9), (128,))
        b, s = an.binarize(x)
        assert set(np.unique(np.asarray(b))) <= {-1.0, 1.0}
        assert s > 0
