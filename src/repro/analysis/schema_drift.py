"""Schema-drift pass: serialized shapes cannot change without a bump.

The repo stamps three wire formats with integer schema versions:

  * ``ARTIFACT_SCHEMA`` (`repro.api.session`) — `DesignArtifact.to_dict`
    payloads plus the `Provenance` dataclass columns;
  * ``TRACE_SCHEMA`` (`repro.telemetry.spans`) — `TraceExport.to_dict`
    Chrome-trace envelopes;
  * ``METRICS_SCHEMA`` (`repro.telemetry.metrics`) — registry snapshot
    envelopes and per-metric dicts.

Historically the bump was manual (PR 7 moved artifacts to schema 4 when
routing provenance columns landed).  This pass extracts each format's
*field set* straight from the AST — every string key of a dict literal
or ``d["k"] = v`` store inside the serializer, and every dataclass
field — and diffs it against the committed manifest
(`src/repro/analysis/schema_manifest.json`):

  * fields changed while the version constant did not -> **schema-drift**
    (bump the constant, then regenerate);
  * version constant changed but the manifest still records the old
    version -> **manifest-stale** (regenerate via
    ``tools/repro_lint.py --update-manifest``).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib

from repro.analysis.core import Finding, Module

MANIFEST_PATH = "src/repro/analysis/schema_manifest.json"


@dataclasses.dataclass(frozen=True)
class Spec:
    key: str                 # manifest key
    module: str              # dotted module holding the format
    version_const: str       # module-level int constant
    sources: tuple[str, ...]  # "Class.method" (dict keys) or "Class" (fields)


SPECS = (
    Spec("artifact", "repro.api.session", "ARTIFACT_SCHEMA",
         ("DesignArtifact.to_dict", "Provenance")),
    Spec("trace", "repro.telemetry.spans", "TRACE_SCHEMA",
         ("TraceExport.to_dict", "TraceExport.to_events")),
    Spec("metrics", "repro.telemetry.metrics", "METRICS_SCHEMA",
         ("MetricsRegistry.snapshot", "Counter.to_dict",
          "Histogram.to_dict")),
)


def _class_node(mod: Module, name: str) -> ast.ClassDef | None:
    for node in mod.tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _method_node(cls: ast.ClassDef, name: str) -> ast.FunctionDef | None:
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == name:
            return node
    return None


def _dict_keys(fn: ast.FunctionDef) -> set[str]:
    """Every literal string key the serializer emits: dict-literal keys
    plus ``d["k"] = v`` subscript stores (nested dicts included — a
    nested field is as much wire format as a top-level one)."""
    keys: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    keys.add(k.value)
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            tgts = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in tgts:
                if isinstance(t, ast.Subscript) and \
                        isinstance(t.slice, ast.Constant) and \
                        isinstance(t.slice.value, str):
                    keys.add(t.slice.value)
    return keys


def _dataclass_fields(cls: ast.ClassDef) -> set[str]:
    return {n.target.id for n in cls.body
            if isinstance(n, ast.AnnAssign) and isinstance(n.target, ast.Name)}


def _version_const(mod: Module, name: str) -> tuple[int | None, int]:
    """(value, line) of a module-level integer constant."""
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                node.targets[0].id == name and \
                isinstance(node.value, ast.Constant) and \
                isinstance(node.value.value, int):
            return node.value.value, node.lineno
    return None, 1


def extract(modules: dict[str, Module]) -> dict[str, dict]:
    """Live schema state: {key: {"version": int, "fields": [..]}}."""
    out: dict[str, dict] = {}
    for spec in SPECS:
        mod = modules.get(spec.module)
        if mod is None:
            continue
        version, _ = _version_const(mod, spec.version_const)
        fields: set[str] = set()
        for src in spec.sources:
            cls_name, _, meth_name = src.partition(".")
            cls = _class_node(mod, cls_name)
            if cls is None:
                continue
            if meth_name:
                fn = _method_node(cls, meth_name)
                if fn is not None:
                    fields |= {f"{src}:{k}" for k in _dict_keys(fn)}
            else:
                fields |= {f"{src}:{k}" for k in _dataclass_fields(cls)}
        out[spec.key] = {"version": version, "fields": sorted(fields)}
    return out


def load_manifest(root: pathlib.Path) -> dict | None:
    path = root / MANIFEST_PATH
    if not path.exists():
        return None
    return json.loads(path.read_text())


def write_manifest(root: pathlib.Path,
                   modules: dict[str, Module]) -> pathlib.Path:
    path = root / MANIFEST_PATH
    path.write_text(json.dumps(extract(modules), indent=2,
                               sort_keys=True) + "\n")
    return path


def run(modules: dict[str, Module], *,
        root: pathlib.Path) -> list[Finding]:
    manifest = load_manifest(root)
    findings: list[Finding] = []
    if manifest is None:
        findings.append(Finding(
            "manifest-stale", MANIFEST_PATH, 1,
            "schema manifest missing; generate it with "
            "tools/repro_lint.py --update-manifest"))
        return findings
    live = extract(modules)
    for spec in SPECS:
        mod = modules.get(spec.module)
        if mod is None:
            continue
        state = live.get(spec.key, {})
        version, line = state.get("version"), 1
        _, line = _version_const(mod, spec.version_const)
        committed = manifest.get(spec.key)
        if version is None:
            findings.append(Finding(
                "schema-drift", mod.rel, 1,
                f"{spec.version_const} constant not found in "
                f"{spec.module}; schema formats must carry a version"))
            continue
        if committed is None:
            findings.append(Finding(
                "manifest-stale", MANIFEST_PATH, 1,
                f"manifest has no entry for {spec.key!r}; regenerate "
                f"with --update-manifest"))
            continue
        if version != committed.get("version"):
            findings.append(Finding(
                "manifest-stale", mod.rel, line,
                f"{spec.version_const}={version} but the committed "
                f"manifest records version {committed.get('version')}; "
                f"regenerate with tools/repro_lint.py --update-manifest"))
            continue
        added = sorted(set(state["fields"]) - set(committed["fields"]))
        removed = sorted(set(committed["fields"]) - set(state["fields"]))
        if added or removed:
            delta = "; ".join(
                s for s in (f"added {added}" if added else "",
                            f"removed {removed}" if removed else "") if s)
            findings.append(Finding(
                "schema-drift", mod.rel, line,
                f"serialized fields of {spec.key!r} changed without a "
                f"{spec.version_const} bump ({delta}); bump the version "
                f"and rerun --update-manifest"))
    return findings
