"""EasyACIM core: the paper's contribution (estimation model Eqs. 2-11,
NSGA-II design-space explorer, ACIM numerics, codesign loop), in JAX."""
from repro.core.acim_spec import MacroSpec, valid_spec
from repro.core.constants import CAL28, CalibConstants

__all__ = ["MacroSpec", "valid_spec", "CAL28", "CalibConstants"]
