"""Trace-purity pass: no host-side effects reachable from traced code.

Consumes the `repro.analysis.callgraph` graph: every function reachable
from a traced root (``jax.jit`` / ``vmap`` / ``lax.scan`` /
``pallas_call`` region) is checked for

  * **host-call** — wall clock (``time.*``), host RNG (stdlib
    ``random.*``, ``numpy.random.*``), console / filesystem
    (``print`` / ``input`` / ``breakpoint`` / ``open``), environment
    (``os.environ`` / ``os.getenv``), device sync (``.item()``, and
    ``float()`` / ``int()`` wrapped directly around an array-producing
    call) — all of which either crash under a tracer or silently bake a
    trace-time value into the compiled program;
  * **inplace-store** — ``x[i] = v`` / ``x[i] += v`` subscript stores
    (JAX arrays need ``x.at[i].set(v)``; a store that *works* under a
    trace is mutating host state, a retrace-count hazard);
  * **set-iteration** — iterating a set (literal or ``set(...)``) in
    traced code, where Python's unordered iteration makes trace
    structure run-to-run nondeterministic;
  * **host-guard** — the `kernels/*/ops.py` dispatch contract from
    `docs/kernels.md`: every call into a host engine module
    (``frontier`` / ``oracle``) must sit *behind* a raising
    ``if _traced(...)`` fence.

Statements lexically after such a fence are host-only and exempt (see
`callgraph` for the pruning rule).
"""
from __future__ import annotations

import ast

from repro.analysis.callgraph import CallGraph, FuncInfo, _is_trace_guard
from repro.analysis.core import Finding, Module, dotted

# Normalized dotted prefixes that are host-side effects under a trace.
_HOST_PREFIXES = (
    "time.", "random.", "numpy.random.", "os.environ", "os.getenv",
    "os.urandom", "os.system", "subprocess.", "socket.",
)
_HOST_BUILTINS = {"print", "input", "breakpoint", "open"}
# float(jnp.sum(x)) / int(lax.argmax(...)) force a device sync and bake
# the traced value into a Python scalar.  Plain numpy is deliberately
# absent: int(np.ceil(...)) over static shapes is trace-time constant
# math, not a sync.
_ARRAY_PRODUCERS = ("jax.numpy.", "jnp.", "jax.lax.", "lax.", "jax.")
# Host engine modules under kernels/*: calls into them from an ops
# dispatcher must be fenced by a raising trace check.
_HOST_ENGINE_MODULES = {"frontier", "oracle", "host", "bfs"}


def _short(fid: str) -> str:
    mod, _, qual = fid.partition(":")
    return f"{mod.rsplit('.', 1)[-1]}.{qual}"


def _call_findings(info: FuncInfo, why: str) -> list[Finding]:
    out: list[Finding] = []
    for site in info.calls:
        if site.host_only:
            continue
        raw = dotted(site.node.func) or ""
        norm = site.norm or raw
        hit = None
        if norm in _HOST_BUILTINS:
            hit = f"{norm}()"
        elif norm.startswith(_HOST_PREFIXES):
            hit = f"{norm}()"
        elif raw.endswith(".item") and site.fid is None:
            hit = ".item()"
        elif norm in ("float", "int", "bool") and site.node.args:
            arg = site.node.args[0]
            if isinstance(arg, ast.Call):
                inner = dotted(arg.func) or ""
                if inner.startswith(_ARRAY_PRODUCERS):
                    hit = f"{norm}({inner}(...))"
        if hit is not None:
            out.append(Finding(
                "host-call", info.module.rel, site.node.lineno,
                f"{hit} in {_short(info.fid)}, reachable from traced "
                f"code ({why})"))
    return out


def _body_findings(info: FuncInfo, why: str) -> list[Finding]:
    """inplace-store / set-iteration inside one reachable function,
    honouring trace-guard fencing; nested defs are their own units."""
    out: list[Finding] = []
    node = info.node
    if isinstance(node, ast.Lambda):
        return out
    # Pallas kernels *must* write through their Ref params
    # (``o_ref[...] = x`` is the output idiom, not a host mutation).
    ref_params: set[str] = set()
    if info.traced_root and "pallas_call" in info.traced_root:
        ref_params = {a.arg for a in node.args.args}

    def visit_block(stmts: list[ast.stmt], fenced: bool) -> None:
        for stmt in stmts:
            if not fenced:
                check_stmt(stmt)
            visit_children(stmt, fenced)
            if _is_trace_guard(stmt):
                fenced = True

    def visit_children(node: ast.AST, fenced: bool) -> None:
        for field in ("body", "orelse", "finalbody"):
            block = getattr(node, field, None)
            if (isinstance(block, list) and block
                    and isinstance(block[0], ast.stmt)):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef, ast.Lambda)):
                    continue           # separate function unit
                visit_block(block, fenced)
        for h in getattr(node, "handlers", ()):
            visit_block(h.body, fenced)

    def check_stmt(stmt: ast.stmt) -> None:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        for t in targets:
            for sub in ast.walk(t):
                if not isinstance(sub, ast.Subscript):
                    continue
                base = dotted(sub.value) or "<expr>"
                if base in ref_params:
                    continue          # pallas Ref store idiom
                # d["k"] = v builds a host dict (params pytrees are
                # assembled this way at trace time — deterministic);
                # d["k"] += v is read-modify-write of live host state.
                if (isinstance(stmt, ast.Assign)
                        and isinstance(sub.slice, ast.Constant)
                        and isinstance(sub.slice.value, str)):
                    continue
                out.append(Finding(
                    "inplace-store", info.module.rel, stmt.lineno,
                    f"subscript store {base}[...] in "
                    f"{_short(info.fid)}, reachable from traced code "
                    f"({why}); use .at[].set() for arrays"))
        for it in _iter_exprs(stmt):
            if isinstance(it, ast.Set) or (
                    isinstance(it, ast.Call)
                    and dotted(it.func) in ("set", "frozenset")):
                out.append(Finding(
                    "set-iteration", info.module.rel, it.lineno,
                    f"iteration over an unordered set in "
                    f"{_short(info.fid)}, reachable from traced code "
                    f"({why}); sort it for a stable trace"))

    def _iter_exprs(stmt: ast.stmt):
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            yield stmt.iter
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.comprehension):
                yield sub.iter

    visit_block(node.body, False)
    return out


def _host_guard_findings(graph: CallGraph, mod: Module) -> list[Finding]:
    """Enforce the ops dispatch contract in `repro.kernels.*.ops`."""
    out: list[Finding] = []
    if not (mod.name.startswith("repro.kernels.")
            and mod.name.endswith(".ops")):
        return out
    for info in graph.functions.values():
        if info.module is not mod:
            continue
        for site in info.calls:
            target = site.norm or ""
            if site.fid:
                target = site.fid.partition(":")[0]
            owner = target.rpartition(".")[0] if site.fid is None \
                else target
            parts = owner.split(".")
            if not parts or parts[-1] not in _HOST_ENGINE_MODULES:
                continue
            if not site.host_only:
                callee = dotted(site.node.func) or target
                out.append(Finding(
                    "host-guard", mod.rel, site.node.lineno,
                    f"host engine call {callee}() in {_short(info.fid)} "
                    f"is not behind a raising 'if _traced(...)' check "
                    f"(ops dispatch contract, docs/kernels.md)"))
    return out


def run(modules: dict[str, Module],
        graph: CallGraph | None = None) -> list[Finding]:
    graph = graph or CallGraph(modules)
    findings: list[Finding] = []
    for fid, why in sorted(graph.traced_reachable().items()):
        info = graph.functions[fid]
        findings.extend(_call_findings(info, why))
        findings.extend(_body_findings(info, why))
    for mod in modules.values():
        findings.extend(_host_guard_findings(graph, mod))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
