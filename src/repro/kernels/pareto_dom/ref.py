"""Pure-jnp oracle for the pareto_dom kernel: `repro.core.pareto.dominance_matrix`."""
from repro.core.pareto import dominance_matrix as dominance_matrix_ref

__all__ = ["dominance_matrix_ref"]
