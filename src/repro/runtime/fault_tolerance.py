"""Fault-tolerance runtime: preemption handling, failure simulation,
straggler monitoring, and the auto-restart supervisor loop.

Mechanisms (each exercised by tests):
  * PreemptionGuard — SIGTERM/SIGINT set a flag; the consumer stops at
    the next safe boundary.  Two consumers today: the trainer
    checkpoints and exits with RESTART_EXIT_CODE (the supervisor,
    launch/train.py --supervise, relaunches and training resumes
    bitwise-identically), and `repro.serve.design_service.DesignService`
    drains its in-flight stages and journals unfinished tickets to a
    WAL (`repro.api.artifact_cache.TicketJournal`) for replay by a
    restarted service.  Usable as a context manager; `install()` on an
    already-installed guard raises instead of silently clobbering the
    saved handlers, and `uninstall()` restores them exactly once.
  * StragglerMonitor — wall-time EMA + deviation per unit of work
    (train steps, layout buckets); units slower than `threshold` x EMA
    are flagged; `stuck(dt)` answers the same question for an
    *in-flight* unit, which is what the design service's shed policy
    polls (re-queue the stuck bucket to a peer worker, first
    completion wins).
  * FailureInjector — deterministic fault schedule for tests: by train
    step (`fail_at_steps`, the legacy trainer shape) or by
    stage-keyed unit index (`fail_at={"layout": [2]}`), with kinds
    `node` (raise SimulatedNodeFailure), `slow` (sleep
    `slow_seconds`), and `preempt` (request preemption on the attached
    guard) — so retry, shed, and journal/replay paths are all
    testable without real signals.
  * run_supervised — in-process restart loop with a capped exponential
    backoff between restarts (injectable `sleep` for tests), so a
    crash-looping worker cannot hot-spin through its restart budget.
    Generalized beyond the trainer: `restart_on` names the exception
    types that count as a restartable crash.
"""
from __future__ import annotations

import dataclasses
import random
import signal
import time
from typing import Callable

RESTART_EXIT_CODE = 42


class SimulatedNodeFailure(RuntimeError):
    pass


def capped_backoff(attempt: int, *, base_s: float, cap_s: float,
                   jitter_frac: float = 0.0,
                   rng: random.Random | None = None) -> float:
    """Delay before retry number `attempt` (1-based): exponential from
    `base_s`, capped at `cap_s`, with up to `jitter_frac` uniform jitter
    added so a fleet of workers retrying the same dead dependency does
    not thunder back in lockstep."""
    if attempt < 1:
        raise ValueError("attempt is 1-based")
    delay = min(cap_s, base_s * (2.0 ** (attempt - 1)))
    if jitter_frac > 0.0:
        delay *= 1.0 + (rng or random).uniform(0.0, jitter_frac)
    return delay


class PreemptionGuard:
    """Installs SIGTERM/SIGINT handlers that request a clean stop.

    `install()`/`uninstall()` pair exactly once (double-install raises —
    it would leak the original handlers); the guard is also a context
    manager.  Tests trigger preemption without a real signal via
    `request()`, which never needs `install()` at all.
    """

    def __init__(self) -> None:
        self._requested = False
        self._prev: dict[int, object] | None = None   # None = not installed

    @property
    def installed(self) -> bool:
        return self._prev is not None

    def install(self) -> "PreemptionGuard":
        if self._prev is not None:
            raise RuntimeError(
                "PreemptionGuard.install() called twice; the second install "
                "would clobber the saved handlers and leak the originals — "
                "uninstall() first (or use one guard per scope)")
        self._prev = {sig: signal.signal(sig, self._handler)
                      for sig in (signal.SIGTERM, signal.SIGINT)}
        return self

    def _handler(self, signum, frame) -> None:  # noqa: ANN001
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self) -> None:   # tests trigger without a real signal
        self._requested = True

    def uninstall(self) -> None:
        """Restore the saved handlers exactly once.  Idempotent: a second
        (or unpaired) `uninstall()` is a no-op rather than re-restoring
        stale handlers over someone else's."""
        prev, self._prev = self._prev, None
        if prev is None:
            return
        for sig, handler in prev.items():
            signal.signal(sig, handler)

    def __enter__(self) -> "PreemptionGuard":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0       # x EMA counts as straggling
    ema_decay: float = 0.9
    ema: float | None = None
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.threshold * self.ema
        if is_straggler:
            self.events.append((step, dt, self.ema))
        else:
            # stragglers don't poison the EMA
            self.ema = dt if self.ema is None else \
                self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return is_straggler

    def stuck(self, dt: float) -> bool:
        """Whether an *in-flight* unit already running for `dt` seconds
        counts as straggling (no EMA yet -> never: there is no baseline
        to judge against).  Unlike `observe` this neither records an
        event nor updates the EMA — the shed watchdog polls it."""
        return self.ema is not None and dt > self.threshold * self.ema

    def mitigation_plan(self, n_hosts: int, slow_host: int) -> list[int]:
        """Return a data-shard -> host assignment that drains the slow host
        (its shards round-robin to the others) until it recovers."""
        return [h if h != slow_host else (h + 1) % n_hosts
                for h in range(n_hosts)]


@dataclasses.dataclass
class FailureInjector:
    """Deterministic fault schedule for tests and chaos benchmarks.

    Two addressing modes:

      * by train step (the legacy trainer shape): `fail_at_steps` +
        `kind`, fired from `maybe_fail(step)`;
      * by (stage, unit index): `fail_at` maps a stage name to a
        sequence of unit indices — plain ints fire the injector-level
        `kind`, `(index, kind)` pairs override it per entry.  Fired
        from `fire(stage, unit)`, where `unit` is the caller's
        monotonically increasing per-stage counter (so a retried unit
        gets a *new* index and an injected failure fires exactly once).

    Kinds: `node` raises SimulatedNodeFailure (the retry/isolation
    path), `slow` sleeps `slow_seconds` (the straggler/shed path),
    `preempt` calls `guard.request()` (the journal/replay path —
    `guard` must be attached).
    """

    fail_at_steps: tuple[int, ...] = ()
    kind: str = "node"           # node | slow | preempt
    slow_seconds: float = 0.0
    fail_at: dict = dataclasses.field(default_factory=dict)
    guard: PreemptionGuard | None = None
    fired: list = dataclasses.field(default_factory=list)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps:
            self._fire("train", step, self.kind)

    def fire(self, stage: str, unit: int) -> None:
        for entry in self.fail_at.get(stage, ()):
            index, kind = (entry if isinstance(entry, tuple)
                           else (entry, self.kind))
            if index == unit:
                self._fire(stage, unit, kind)

    def _fire(self, stage: str, unit: int, kind: str) -> None:
        self.fired.append((stage, unit, kind))
        if kind == "node":
            raise SimulatedNodeFailure(
                f"injected {stage} failure at unit {unit}")
        if kind == "slow":
            time.sleep(self.slow_seconds)
        elif kind == "preempt":
            if self.guard is None:
                raise ValueError("FailureInjector kind='preempt' needs an "
                                 "attached PreemptionGuard (guard=...)")
            self.guard.request()
        else:
            raise ValueError(f"unknown failure kind {kind!r} "
                             f"(expected node|slow|preempt)")


def run_supervised(make_and_run: Callable[[], int], *,
                   max_restarts: int = 5,
                   restart_on: tuple[type[BaseException], ...]
                   = (SimulatedNodeFailure,),
                   backoff_s: float = 0.1, backoff_cap_s: float = 30.0,
                   sleep: Callable[[float], None] = time.sleep,
                   on_restart: Callable[[int], None] | None = None) -> int:
    """In-process supervisor: re-invokes the worker function while it
    exits with RESTART_EXIT_CODE or dies with one of the `restart_on`
    exception types (default: SimulatedNodeFailure — the trainer
    contract; stage workers pass `(Exception,)`).

    Restarts are spaced by a capped exponential backoff
    (`capped_backoff(n, base_s=backoff_s, cap_s=backoff_cap_s)`), so a
    worker that crashes instantly cannot burn its whole restart budget
    in milliseconds.  `sleep` is injectable so tests assert the delays
    without waiting them out; `on_restart(n)` (if given) is called
    before each restart — the design service counts these into its
    stats."""
    restarts = 0
    while True:
        try:
            code = make_and_run()
        except restart_on:
            code = RESTART_EXIT_CODE
        if code != RESTART_EXIT_CODE:
            return code
        restarts += 1
        if restarts > max_restarts:
            raise RuntimeError("restart budget exhausted")
        if backoff_s > 0.0:
            sleep(capped_backoff(restarts, base_s=backoff_s,
                                 cap_s=backoff_cap_s))
        if on_restart is not None:
            on_restart(restarts)
