"""Naive oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (BH, S, Dh); k/v: (BH, T, Dh)."""
    dh = q.shape[-1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    if causal:
        sq, t = s.shape[1], s.shape[2]
        mask = jnp.arange(t)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)
