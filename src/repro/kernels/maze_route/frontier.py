"""Frontier-bucketed (Dial-style) wavefront engine for the maze router.

The jnp reference and the Pallas kernel both relax the *full* H×W grid
every iteration, so a net whose wavefront only ever touches a thin
corridor still pays O(H·W) per sweep.  This module implements the
classic alternative: keep the active frontier as an explicit bucket of
cell indices and expand exactly those cells, so each BFS level costs
O(|frontier|) and the whole field costs O(cells reached), which is what
"per-iteration work proportional to the active frontier" means in
ROADMAP item 2.  With unit edge weights Dial's bucket queue degenerates
to one bucket per BFS level — `level` below *is* the bucket index, and
the per-level `np.unique` is the bucket dedupe.

It is a host/numpy engine on purpose: the frontier is data-dependent
and ragged, which is exactly what XLA's static shapes are bad at, while
the batched layout flow calls the wavefront from host code anyway
(`repro.eda.batched_flow`'s concurrent-net scheduler).  On TPU the
grid-batched Pallas kernel remains the production path; `ops
.wavefront_distance` keeps all of them behind one dispatch contract.

Layout of the working arrays (the "frontier-bucket contract", also
documented in `docs/kernels.md`):

  * every lane (= one routing grid) lives on a bordered canvas of
    (H+2)×(W+2) cells flattened to one axis; the 1-cell border is
    permanently blocked, so the four neighbour offsets are the plain
    strides ``(+S, -S, +1, -1)`` with ``S = W + 2`` and never need a
    bounds check — border cells read `INF` forever, which is exactly
    the out-of-bounds semantics of `repro.eda.router`;
  * `dist` is int32, `INF` (= `ref.INF`) marks unassigned/unreachable;
    seeds are written 0 and form bucket 0 even when their cell is
    occupied (hub exception, same as ref/kernel/BFS oracle);
  * bucket k+1 = unique free, still-`INF` neighbours of bucket k;
    termination: the next bucket is empty (field exhausted) or, when an
    early-exit predicate is given, every lane reports resolved —
    because levels complete atomically, every assigned distance is
    final the moment it is written.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.maze_route.ref import INF

# Neighbour order (down, up, right, left) == `repro.eda.router.NEIGHBORS`.
# On the flat bordered canvas these are index strides; row stride is W+2.
def strides(stride: int) -> np.ndarray:
    return np.array([stride, -stride, 1, -1], np.int64)


def expand_buckets(free, dist, lane0, idx0, stride, resolved=None) -> int:
    """Run the bucketed wavefront to termination, in place.

    free:  (L, C) bool  — traversable canvas cells (border rows False).
    dist:  (L, C) int32 — `INF`-filled; seeds already written 0.
    lane0, idx0: int64 arrays — bucket 0 (the seeds), as (lane, flat
        canvas index) pairs.
    stride: canvas row stride (W + 2).
    resolved: optional () -> (L,) bool callback, checked after each
        bucket commits; lanes reporting True stop expanding (their
        remaining `INF` cells simply stay `INF` — callers only rely on
        distances at/below the resolution level, which are final).

    Returns the number of levels (buckets) expanded.
    """
    ncells = free.shape[1]
    offs = strides(stride)
    f_lane, f_idx = lane0, idx0
    level = 0
    while f_idx.size:
        level += 1
        # Bucket k -> candidate cells of bucket k+1: the 4-neighbourhood.
        n_lane = np.repeat(f_lane, 4)
        n_idx = (f_idx[:, None] + offs[None, :]).ravel()
        keep = free[n_lane, n_idx] & (dist[n_lane, n_idx] == INF)
        n_lane, n_idx = n_lane[keep], n_idx[keep]
        if not n_idx.size:
            break
        # Dedupe within the bucket (two frontier cells proposing the
        # same neighbour) — one fused key so np.unique runs once.
        key = np.unique(n_lane * ncells + n_idx)
        n_lane, n_idx = key // ncells, key % ncells
        dist[n_lane, n_idx] = level
        if resolved is not None:
            done = resolved()
            if done.any():
                alive = ~done[n_lane]
                n_lane, n_idx = n_lane[alive], n_idx[alive]
        f_lane, f_idx = n_lane, n_idx
    return level


def canvas_free(occ: np.ndarray) -> np.ndarray:
    """(L, H, W) blocked-mask -> (L, (H+2)*(W+2)) flat traversable mask
    with the 1-cell blocked border of the frontier-bucket contract."""
    l, h, w = occ.shape
    free = np.zeros((l, h + 2, w + 2), bool)
    free[:, 1:-1, 1:-1] = ~occ
    return free.reshape(l, (h + 2) * (w + 2))


def canvas_index(y, x, stride: int):
    """Grid (y, x) -> flat bordered-canvas index."""
    return (np.asarray(y, np.int64) + 1) * stride + np.asarray(x) + 1


def wavefront_distance_frontier(occ, seed) -> np.ndarray:
    """Full BFS distance field(s) via the bucketed frontier engine.

    occ, seed: (H, W) or (B, H, W) bool array-likes.  Returns int32
    distances of the same shape — exactly `wavefront_distance_ref` /
    `wavefront_kernel` / the BFS oracle, but computed on host with
    per-level work proportional to the frontier.
    """
    occ = np.asarray(occ, bool)
    seed = np.asarray(seed, bool)
    squeeze = occ.ndim == 2
    if squeeze:
        occ, seed = occ[None], seed[None]
    b, h, w = occ.shape
    stride = w + 2
    free = canvas_free(occ)
    dist = np.full((b, (h + 2) * stride), INF, np.int32)
    sl, sy, sx = np.nonzero(seed)
    sidx = canvas_index(sy, sx, stride)
    sl = sl.astype(np.int64)
    dist[sl, sidx] = 0
    expand_buckets(free, dist, sl, sidx, stride)
    out = dist.reshape(b, h + 2, stride)[:, 1:-1, 1:-1]
    return out[0] if squeeze else out
